#!/bin/sh
# Docs-consistency check (run by `make check-docs` and CI; pure grep/sed,
# no toolchain needed):
#
#   1. Every `DESIGN.md §X` / `PROTOCOL.md §X` / `EXPERIMENTS.md §X`
#      citation anywhere in the source tree resolves to a heading in that
#      document — so code can cite the spec instead of restating it
#      without the references rotting.
#   2. Every wire field `rust/src/serve/job.rs` actually serializes — the
#      request-side KNOWN key list and the response-side `to_json` inserts
#      — is documented in PROTOCOL.md (as `` `field` ``). No undocumented
#      wire fields, in either direction.
#   3. Every control-frame op the server dispatches on (the match arms in
#      `serve::net::control_frame`), every reply/notice op it emits, and
#      the stats-reply keys new wire consumers depend on (`queue_depth`,
#      the cancel ack shape) are documented in PROTOCOL.md.
#   4. The cluster layer stays spec-anchored: every `rust/src/cluster/*.rs`
#      module carries at least one PROTOCOL.md §-citation (whose
#      resolution check 1 already covers), and every `[cluster]` config
#      key in the `kpynq init-config` EXAMPLE is documented in README.md.
#   5. Every metric name the obs registry registers (the canonical
#      `pub mod names` block in rust/src/obs/metrics.rs) is documented —
#      backticked — in README.md or PROTOCOL.md. No mystery metrics.
#   6. The distance-kernel seam holds (DESIGN.md §5): no algorithm file
#      under rust/src/kmeans/ except kernel.rs calls the raw
#      `sq_dist(`/`dist(` primitives directly — every point↔centroid
#      distance goes through `kmeans::kernel`.
set -eu
cd "$(dirname "$0")/.."
fail=0

# ---- 1. section citations resolve --------------------------------------
for doc in DESIGN.md PROTOCOL.md EXPERIMENTS.md; do
    if [ ! -f "$doc" ]; then
        echo "FAIL: cited document $doc does not exist"
        fail=1
        continue
    fi
    # Pass 1: the canonical `DOC §X` form. Pass 2: bare `§X` tokens on any
    # line that names exactly one of the three documents — catches forms
    # like "PROTOCOL.md (§3 requests, §4 responses)" that pass 1 misses.
    refs=$( {
        grep -rhoE "$doc §[A-Za-z0-9][A-Za-z0-9.-]*" \
            rust examples python README.md Makefile 2>/dev/null \
        | sed "s/^$doc §//"
        grep -rhE "$doc" rust examples python README.md Makefile 2>/dev/null \
        | while IFS= read -r line; do
            ndocs=$(printf '%s\n' "$line" \
                    | grep -oE '(DESIGN|PROTOCOL|EXPERIMENTS)\.md' | sort -u | wc -l)
            [ "$ndocs" -eq 1 ] || continue
            printf '%s\n' "$line" | grep -oE '§[A-Za-z0-9][A-Za-z0-9.-]*' | sed 's/^§//'
        done
    } | sed 's/\.$//' | sort -u)
    for ref in $refs; do
        case "$ref" in
            *[!0-9]*) pat="^##* .*$ref" ;;        # named section (e.g. §Perf)
            *)        pat="^##* *$ref\." ;;       # numbered section (e.g. §2 -> "## 2.")
        esac
        if ! grep -Eq "$pat" "$doc"; then
            echo "FAIL: citation '$doc §$ref' does not resolve to a heading in $doc"
            fail=1
        fi
    done
done

# ---- 2. serve wire fields are documented in PROTOCOL.md -----------------
job_rs=rust/src/serve/job.rs
req_keys=$(sed -n '/const KNOWN/,/];/p' "$job_rs" | grep -oE '"[a-z_]+"' | tr -d '"' | sort -u)
# Response keys are uniformly inserted as `"key".into()` map keys.
resp_keys=$(sed -n '/fn to_json/,/^    }$/p' "$job_rs" \
            | grep -oE '"[a-z_]+"\.into\(\)' | sed 's/"\.into()$//;s/^"//' | sort -u)
if [ -z "$req_keys" ] || [ -z "$resp_keys" ]; then
    echo "FAIL: could not extract wire fields from $job_rs (layout changed?)"
    fail=1
fi
for key in $req_keys $resp_keys; do
    if ! grep -q "\`$key\`" PROTOCOL.md; then
        echo "FAIL: wire field \`$key\` (serialized by serve::job) is undocumented in PROTOCOL.md"
        fail=1
    fi
done

# ---- 3. control-frame surface is documented in PROTOCOL.md --------------
net_rs=rust/src/serve/net.rs
# Request ops: the match arms of control_frame ("ping" => ...).
req_ops=$(sed -n '/fn control_frame/,/^}$/p' "$net_rs" \
          | grep -oE '"[a-z_-]+" =>' | sed 's/" =>$//;s/^"//' | sort -u)
if [ -z "$req_ops" ]; then
    echo "FAIL: could not extract control-frame ops from $net_rs (layout changed?)"
    fail=1
fi
# Reply/notice ops and stats keys the cluster layer (and any other wire
# consumer) depends on; extend this list when the control surface grows.
emitted="pong cancelled shutdown-ack idle-timeout queue_depth shards shards_alive partial partial_done uptime_ms queue_lanes peek format body tenants queued size capacity cleared"
for tok in $req_ops $emitted; do
    # Ops appear JSON-quoted ("ping", inside example frames or tables),
    # stats keys as backticked `queue_depth`.
    if ! grep -q -e "\"$tok\"" -e "\`$tok\`" PROTOCOL.md; then
        echo "FAIL: control-frame token '$tok' (serve::net wire surface) is undocumented in PROTOCOL.md"
        fail=1
    fi
done

# ---- 4. cluster layer: §-citations present + [cluster] keys in README ---
for f in rust/src/cluster/*.rs; do
    if ! grep -q "PROTOCOL\.md §" "$f"; then
        echo "FAIL: $f cites no PROTOCOL.md section (cluster modules must anchor to the spec)"
        fail=1
    fi
done
# The [cluster] section of config.rs's EXAMPLE is the authoritative key
# list; each key must appear backticked in README.md.
cluster_keys=$(sed -n '/^\[cluster\]/,/^"#/p' rust/src/config.rs | grep -oE '^[a-z_]+' | sort -u)
if [ -z "$cluster_keys" ]; then
    echo "FAIL: could not extract [cluster] keys from rust/src/config.rs (EXAMPLE layout changed?)"
    fail=1
fi
for key in $cluster_keys; do
    if ! grep -q "\`$key\`" README.md; then
        echo "FAIL: [cluster] config key '$key' is undocumented in README.md"
        fail=1
    fi
done
# Same rule for the [serve] section (scheduling/caching knobs live there);
# the range ends at the blank line before [serve.net].
serve_keys=$(sed -n '/^\[serve\]$/,/^$/p' rust/src/config.rs | grep -oE '^[a-z_]+' | sort -u)
if [ -z "$serve_keys" ]; then
    echo "FAIL: could not extract [serve] keys from rust/src/config.rs (EXAMPLE layout changed?)"
    fail=1
fi
for key in $serve_keys; do
    if ! grep -q "\`$key\`" README.md; then
        echo "FAIL: [serve] config key '$key' is undocumented in README.md"
        fail=1
    fi
done

# ---- 5. obs metric names are documented ---------------------------------
metrics_rs=rust/src/obs/metrics.rs
metric_names=$(sed -n '/pub mod names/,/^}/p' "$metrics_rs" \
               | grep -oE '"[a-z][a-z_.]+"' | tr -d '"' | sort -u)
if [ -z "$metric_names" ]; then
    echo "FAIL: could not extract metric names from $metrics_rs (names block layout changed?)"
    fail=1
fi
for name in $metric_names; do
    if ! grep -q "\`$name\`" README.md PROTOCOL.md; then
        echo "FAIL: metric name '$name' (obs::metrics::names) is undocumented in README.md/PROTOCOL.md"
        fail=1
    fi
done
# The label vocabulary is part of the wire contract (series keys and the
# Prometheus exposition both carry it), so each LABEL_KEYS entry must be
# backticked in PROTOCOL.md specifically — not just anywhere in the docs.
label_keys=$(sed -n '/pub const LABEL_KEYS/,/];/p' "$metrics_rs" \
             | grep -oE '"[a-z_]+"' | tr -d '"' | sort -u)
if [ -z "$label_keys" ]; then
    echo "FAIL: could not extract LABEL_KEYS from $metrics_rs (const layout changed?)"
    fail=1
fi
for key in $label_keys; do
    if ! grep -q "\`$key\`" PROTOCOL.md; then
        echo "FAIL: metric label key '$key' (obs::metrics::names::LABEL_KEYS) is undocumented in PROTOCOL.md"
        fail=1
    fi
done
# The scrape surface must be discoverable from the README: the endpoint
# and the two flags that turn it (and per-phase profiling) on.
for tok in 'GET /metrics' '--metrics-listen' '--profile'; do
    if ! grep -qF -e "$tok" README.md; then
        echo "FAIL: README.md does not mention '$tok' (observability surface undocumented)"
        fail=1
    fi
done

# ---- 6. the distance-kernel seam: no raw sq_dist/dist outside kernel.rs -
# kernel.rs is the one module allowed to call the matrix primitives; every
# other kmeans module must route point<->centroid distances through it
# (DESIGN.md §5). Comments are stripped so prose mentioning `sq_dist(` does
# not trip the gate; the pattern rejects a call not preceded by an
# identifier character, so `kernel::sq_dist_pair(`/`sq_dists_to(` pass.
for f in rust/src/kmeans/*.rs; do
    case "$f" in
        */kernel.rs) continue ;;
    esac
    hits=$(sed 's@//.*@@' "$f" | grep -nE '(^|[^_A-Za-z0-9])(sq_dist|dist)\(' || true)
    if [ -n "$hits" ]; then
        echo "FAIL: $f calls raw sq_dist()/dist() — route distances through kmeans::kernel (DESIGN.md §5):"
        printf '%s\n' "$hits" | sed 's/^/    /'
        fail=1
    fi
done

if [ "$fail" -eq 0 ]; then
    echo "docs-consistency: OK (citations resolve; wire fields documented; kernel seam holds)"
fi
exit "$fail"
