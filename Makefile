# KPynq reproduction — build orchestration.
#
# The Rust side is plain cargo; this Makefile exists for the cross-layer
# steps: AOT-exporting the Layer-1/2 kernels (needs jax) and running the
# python test suite. `make artifacts` treats the manifest as the stamp:
# unchanged inputs are a no-op.

PYTHON      ?= python3
ARTIFACTS   := artifacts
PY_SOURCES  := $(wildcard python/compile/*.py python/compile/kernels/*.py)

.PHONY: all build test serve-test serve-net-test cluster-test cluster-remote-test mapreduce-test obs-test profile-test qos-test kernel-test check-docs bench-compile examples doc artifacts artifacts-quick pytest clean

all: build

build:
	cargo build --release

test: build
	cargo test -q

# The serve subsystem's end-to-end acceptance test on its own — for
# iterating on the serving layer without the full suite. `make test`
# already covers it (serve_integration is a registered test target), so
# it is deliberately NOT a dependency of `test`.
serve-test:
	cargo test -q --test serve_integration

# The daemon front-end's loopback acceptance test (bit-identity over the
# wire, concurrent clients, protocol edges) — see PROTOCOL.md.
serve-net-test:
	cargo test -q --test serve_net

# The cross-process cluster's acceptance test: 2-shard bit-identity vs a
# single daemon, shard-kill recovery with exactly-once replies, router
# policy pins. Spawns real `kpynq serve --listen unix:` child processes.
cluster-test:
	cargo test -q --test cluster

# The remote-shards (multi-host) mode: chaos tests against deterministic
# fake-shard doubles (scripted disconnects/stalls/garbling — no child
# processes, no signals) plus the PROTOCOL.md §4–§6 conformance vectors
# run against both the real daemon and the double.
cluster-remote-test:
	cargo test -q --test cluster_remote --test protocol_conformance

# Map-reduce fits (PROTOCOL.md §10): the partition-equivalence property
# battery (sliced fit == solo fit, bit for bit, for every algorithm x
# shard count) plus the mapreduce unit tests in the library.
mapreduce-test:
	cargo test -q --test mapreduce
	cargo test -q --lib mapreduce

# The observability layer (PROTOCOL.md §11): the obs unit tests (metrics
# registry, trace ring, log sink) plus the wire suites that assert the
# trace/metrics control frames, trace_id propagation and the
# work-efficiency counters end to end.
obs-test:
	cargo test -q --lib obs
	cargo test -q --test serve_net trace_and_metrics_surface_over_the_wire
	cargo test -q --test serve_net http_metrics_sidecar_serves_a_prometheus_scrape
	cargo test -q --test cluster cluster_fit_yields_metrics_trace_and_work_counters

# The profiling non-perturbation contract (DESIGN.md §2): a fit with the
# per-phase timers on is bit-identical — assignments, centroid bits, §8
# fingerprint — to the same fit with them off, for all four algorithms.
profile-test:
	cargo test -q --test profile

# The QoS layer (PROTOCOL.md §7–§8): weighted-fair scheduling, per-tenant
# quotas and the submission-anchored deadline/queue-wait clocks
# (serve::queue unit + property tests), the result cache's replay/LRU
# unit tests, and the end-to-end acceptance — blocked-submitter deadline
# shed, two-tenant overload fairness, cache replays proven byte-identical
# over a daemon socket and through a 2-shard cluster front.
qos-test:
	cargo test -q --lib serve::queue
	cargo test -q --lib serve::cache
	cargo test -q --test serve_integration a_blocked_submitter_sheds_on_deadline_instead_of_waiting_forever
	cargo test -q --test serve_integration a_flooding_tenant_is_quota_shed_while_the_light_tenant_completes
	cargo test -q --test serve_net cache_hits_replay_byte_identical_results_over_the_wire
	cargo test -q --test cluster duplicate_fits_replay_from_the_front_cache_bit_identically

# The distance micro-kernel's equivalence battery (DESIGN.md §5): kernel
# vs naive bit-identity across tile-boundary shapes, all four algorithms
# (and both backends) bit-identical on golden fixtures, work-efficiency
# counters pinned — plus the kernel's own unit tests.
kernel-test:
	cargo test -q --test kernel_equivalence
	cargo test -q --lib kmeans::kernel

# Docs consistency: DESIGN.md/PROTOCOL.md/EXPERIMENTS.md §-citations in the
# source must resolve, and every serve::job wire field must be documented
# in PROTOCOL.md. Pure grep — needs no Rust toolchain.
check-docs:
	sh tools/check-docs.sh

# Compiles every registered bench, serve_throughput + serve_net included.
bench-compile:
	cargo bench --no-run

examples:
	cargo build --examples

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# ---- layers 1–2 ---------------------------------------------------------

$(ARTIFACTS)/manifest.json: $(PY_SOURCES)
	cd python && $(PYTHON) -m compile.aot --outdir ../$(ARTIFACTS)

artifacts: $(ARTIFACTS)/manifest.json

# NOTE: the quick export writes the same manifest stamp, so a later
# `make artifacts` sees it up to date and stays quick — run
# `make -B artifacts` to upgrade to the full variant grid.
artifacts-quick:
	cd python && $(PYTHON) -m compile.aot --outdir ../$(ARTIFACTS) --quick

pytest:
	cd python && $(PYTHON) -m pytest tests -q

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
