//! Image segmentation — one of the K-means applications the paper's intro
//! motivates ("unlabeled data clustering, image segmentation, and feature
//! learning").
//!
//! ```bash
//! cargo run --release --example image_segmentation
//! ```
//!
//! Builds a synthetic RGB test image (smooth color regions + noise, a
//! deterministic stand-in for a photo), clusters its pixels in 5-D
//! (r, g, b, x, y) feature space on the simulated KPynq accelerator, and
//! writes the segmented result as a PPM next to the original so the
//! segmentation can be inspected with any image viewer. Reports the
//! simulated accelerator cost for a realistic "interactive segmentation"
//! workload.

use std::io::Write as _;
use std::path::PathBuf;

use kpynq::coordinator::{KpynqSystem, SystemConfig};
use kpynq::data::Dataset;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::matrix::Matrix;
use kpynq::util::rng::Rng;

const W: usize = 256;
const H: usize = 192;

/// Deterministic synthetic photo: three smooth radial color fields + noise.
fn synth_image(seed: u64) -> Vec<[f32; 3]> {
    let mut rng = Rng::new(seed);
    let mut img = vec![[0.0f32; 3]; W * H];
    // Random blob centers with associated colors.
    let blobs: Vec<([f32; 2], [f32; 3])> = (0..5)
        .map(|_| {
            (
                [rng.next_f32() * W as f32, rng.next_f32() * H as f32],
                [rng.next_f32(), rng.next_f32(), rng.next_f32()],
            )
        })
        .collect();
    for y in 0..H {
        for x in 0..W {
            let mut color = [0.15f32, 0.18, 0.22]; // background
            let mut weight = 1.0f32;
            for (c, rgb) in &blobs {
                let dx = x as f32 - c[0];
                let dy = y as f32 - c[1];
                let w = (-((dx * dx + dy * dy) / 3000.0)).exp();
                for ch in 0..3 {
                    color[ch] += w * rgb[ch];
                }
                weight += w;
            }
            for (ch, c) in color.iter_mut().enumerate() {
                *c = (*c / weight + rng.normal_f32(0.0, 0.015)).clamp(0.0, 1.0);
                let _ = ch;
            }
            img[y * W + x] = color;
        }
    }
    img
}

fn write_ppm(path: &PathBuf, pixels: &[[f32; 3]]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "P6\n{W} {H}\n255")?;
    let mut buf = Vec::with_capacity(W * H * 3);
    for p in pixels {
        for ch in p {
            buf.push((ch * 255.0).round().clamp(0.0, 255.0) as u8);
        }
    }
    f.write_all(&buf)
}

fn main() -> kpynq::Result<()> {
    let img = synth_image(0x1ACE);

    // Feature space: color (weighted heavier) + normalised position, the
    // classic 5-D segmentation embedding.
    let mut feats = Vec::with_capacity(W * H * 5);
    for y in 0..H {
        for x in 0..W {
            let p = img[y * W + x];
            feats.extend_from_slice(&[
                p[0],
                p[1],
                p[2],
                0.3 * x as f32 / W as f32,
                0.3 * y as f32 / H as f32,
            ]);
        }
    }
    let ds = Dataset::new("image", Matrix::from_vec(feats, W * H, 5)?);

    let k = 6;
    let sys = KpynqSystem::new(SystemConfig::default())?;
    let kcfg = KMeansConfig { k, seed: 99, max_iters: 40, ..Default::default() };
    let out = sys.cluster(&ds, &kcfg)?;

    println!(
        "segmented {}x{} image ({} pixels) into {k} regions: {} iters, \
         {} PL cycles = {:.2} ms at 100 MHz ({:.1} frames/s at this size)",
        W,
        H,
        W * H,
        out.fit.iterations,
        out.report.total_cycles,
        out.report.sim_seconds * 1e3,
        1.0 / out.report.sim_seconds
    );

    // Paint each pixel with its cluster's mean color.
    let mut segmented = vec![[0.0f32; 3]; W * H];
    for (i, &a) in out.fit.assignments.iter().enumerate() {
        let c = out.fit.centroids.row(a as usize);
        segmented[i] = [c[0], c[1], c[2]];
    }
    let dir = std::env::temp_dir();
    let orig = dir.join("kpynq_image_original.ppm");
    let seg = dir.join("kpynq_image_segmented.ppm");
    write_ppm(&orig, &img)?;
    write_ppm(&seg, &segmented)?;
    println!("wrote {} and {}", orig.display(), seg.display());

    // Region statistics.
    let mut counts = vec![0usize; k];
    for &a in &out.fit.assignments {
        counts[a as usize] += 1;
    }
    println!("region sizes: {counts:?}");
    assert!(counts.iter().all(|&c| c > 0), "no empty segments expected");
    Ok(())
}
