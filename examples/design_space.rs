//! Design-space exploration: the paper's configurability claim.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```
//!
//! KPynq §I: "much more scalable and highly configurable equipped with a
//! set of tunable parameters (e.g. degree of parallelism), which help to
//! handle various datasets". This example sweeps the lane count and MAC
//! width on both supported parts, prices every configuration against the
//! LUT/FF/DSP/BRAM budget and simulates the fitting ones on two contrasting
//! datasets — showing where performance saturates and which resource binds.

use kpynq::data::normalize;
use kpynq::data::synth;
use kpynq::harness;
use kpynq::hw::ZynqPart;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::Table;

fn main() -> kpynq::Result<()> {
    let kcfg = KMeansConfig { k: 16, seed: 3, max_iters: 40, ..Default::default() };
    let mut low_d = synth::uci("kegg", 11).unwrap().subsample(20_000, 1);
    let mut high_d = synth::uci("gassensor", 11).unwrap();
    normalize::min_max(&mut low_d);
    normalize::min_max(&mut high_d);

    for part in [ZynqPart::xc7z020(), ZynqPart::zu7ev()] {
        println!("== part {} ==", part.name);
        for ds in [&low_d, &high_d] {
            println!("dataset {} (n={}, d={}):", ds.name, ds.n(), ds.d());
            let mut t = Table::new(&[
                "lanes", "width", "DSP", "BRAM", "fits", "cycles", "ms @100MHz", "speedup vs P=1",
            ]);
            let mut base: Option<f64> = None;
            for &(lanes, width) in &[
                (1u64, 4u64),
                (2, 4),
                (4, 4),
                (8, 4),
                (16, 4),
                (8, 8),
                (16, 8),
                (32, 8),
            ] {
                let p = harness::parallelism_point(ds, &kcfg, lanes, width, &part)?;
                let (cyc, ms, spd) = match (p.cycles, p.seconds) {
                    (Some(c), Some(s)) => {
                        if base.is_none() && lanes == 1 {
                            base = Some(s);
                        }
                        let spd = base.map(|b| format!("{:.2}x", b / s)).unwrap_or_default();
                        (c.to_string(), format!("{:.2}", s * 1e3), spd)
                    }
                    _ => ("-".into(), "-".into(), "-".into()),
                };
                t.row(vec![
                    lanes.to_string(),
                    width.to_string(),
                    p.dsp.to_string(),
                    p.bram.to_string(),
                    if p.fits { "yes".into() } else { "NO".into() },
                    cyc,
                    ms,
                    spd,
                ]);
            }
            t.print();
        }
    }
    println!(
        "reading: once the AXIS link or the filter stage dominates, extra lanes stop \
         paying — the knee is the per-dataset design point the paper tunes for."
    );
    Ok(())
}
