//! Quickstart: cluster synthetic blobs on the simulated KPynq accelerator.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the minimal public-API path: generate data → build a
//! system → cluster → read the fit and the hardware report.

use kpynq::coordinator::{KpynqSystem, SystemConfig};
use kpynq::data::{normalize, synth};
use kpynq::kmeans::KMeansConfig;

fn main() -> kpynq::Result<()> {
    // 10k points in 16 dimensions around 8 modes, min-max normalised the
    // way the fixed-point datapath expects.
    let mut ds = synth::blobs(10_000, 16, 8, 0xC0FFEE);
    normalize::min_max(&mut ds);

    let sys = KpynqSystem::new(SystemConfig::default())?; // simulated Pynq-Z1
    let kcfg = KMeansConfig { k: 8, seed: 42, ..Default::default() };
    let out = sys.cluster(&ds, &kcfg)?;

    println!("kpynq quickstart — {} points x {} dims, k = {}", ds.n(), ds.d(), kcfg.k);
    println!(
        "  converged: {} after {} iterations, inertia {:.4}",
        out.fit.converged, out.fit.iterations, out.fit.inertia
    );
    println!(
        "  simulated: {} PL cycles = {:.3} ms at 100 MHz",
        out.report.total_cycles,
        out.report.sim_seconds * 1e3
    );
    println!(
        "  filter effectiveness: {:.1}% of standard K-means distance work",
        out.fit.stats.work_ratio(ds.n(), kcfg.k) * 100.0
    );

    // Cluster sizes (the blobs are balanced, so these should be ~equal).
    let mut counts = vec![0usize; kcfg.k];
    for &a in &out.fit.assignments {
        counts[a as usize] += 1;
    }
    println!("  cluster sizes: {counts:?}");

    // Recovery check against the generator's ground truth.
    if let Some(labels) = &ds.labels {
        let mut map = std::collections::HashMap::new();
        let mut agree = 0usize;
        for i in 0..ds.n() {
            let e = map.entry(labels[i]).or_insert(out.fit.assignments[i]);
            if *e == out.fit.assignments[i] {
                agree += 1;
            }
        }
        println!(
            "  ground-truth agreement: {:.2}% (up to relabelling)",
            100.0 * agree as f64 / ds.n() as f64
        );
    }
    Ok(())
}
