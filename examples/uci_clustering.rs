//! End-to-end driver: the paper's evaluation on the six UCI-equivalent
//! datasets (EXPERIMENTS.md records a full run).
//!
//! ```bash
//! cargo run --release --example uci_clustering            # full-size datasets
//! KPYNQ_MAX_POINTS=5000 cargo run --release --example uci_clustering
//! ```
//!
//! For every dataset this runs:
//!   1. the simulated KPynq accelerator (multi-level filter, Pynq-Z1 cycle
//!      model) — the paper's system;
//!   2. the CPU-model standard K-means baseline (same iteration count, so
//!      the trajectory is shared and the comparison isolates architecture);
//!   3. prints the T1 (speedup) + T2 (energy-efficiency) table.
//!
//! It then proves all three layers compose by re-running one dataset
//! through the XLA backend — the AOT-compiled Pallas kernel via PJRT —
//! and checking the clustering agrees exactly with the software result.

use kpynq::coordinator::driver::run_with_engine;
use kpynq::harness::{self, render_speedup_table};
use kpynq::hw::AccelConfig;
use kpynq::kmeans::{self, Algorithm, KMeansConfig};
use kpynq::runtime::xla::XlaEngine;
use std::path::PathBuf;

fn main() -> kpynq::Result<()> {
    let cap: usize = std::env::var("KPYNQ_MAX_POINTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0); // 0 = full size
    let seed = 2019; // the paper's year; any seed reproduces the shape

    println!("== KPynq end-to-end evaluation (six UCI-equivalent datasets) ==");
    if cap > 0 {
        println!("   (subsampled to {cap} points per dataset via KPYNQ_MAX_POINTS)");
    }
    let suite = harness::bench_suite(seed, cap);
    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 100, ..Default::default() };
    let acfg = AccelConfig::default();
    let cpu = harness::default_cpu();

    let mut rows = Vec::new();
    for ds in &suite {
        let t0 = std::time::Instant::now();
        let row = harness::speedup_energy_row(ds, &kcfg, &acfg, &cpu)?;
        println!(
            "  {:<12} n={:<7} d={:<4} -> speedup {:.2}x, energy-eff {:.1}x, work {:.1}%  \
             ({:.1}s host wall)",
            row.dataset,
            row.n,
            row.d,
            row.speedup,
            row.energy_efficiency,
            row.work_ratio * 100.0,
            t0.elapsed().as_secs_f64()
        );
        rows.push(row);
    }

    println!("\n== Table 1 + 2: KPynq (simulated Pynq-Z1) vs optimized CPU standard K-means ==");
    print!("{}", render_speedup_table(&rows));
    println!(
        "paper reports: avg 2.95x speedup (max 4.2x), avg 150.90x energy-efficiency (max 218x)"
    );

    // ---- Layer-composition proof: XLA backend on one dataset ----
    println!("\n== Full-stack check: AOT Pallas kernel via PJRT (layer 1+2+3) ==");
    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut ds = kpynq::data::synth::uci("kegg", seed).unwrap().subsample(20_000, seed);
    kpynq::data::normalize::min_max(&mut ds);
    let kcfg2 = KMeansConfig { k: 16, seed: 7, ..Default::default() };
    match XlaEngine::new(&artifact_dir) {
        Ok(mut eng) => {
            let t0 = std::time::Instant::now();
            let out = run_with_engine(&mut eng, &ds, &kcfg2)?;
            let wall = t0.elapsed().as_secs_f64();
            let direct = kmeans::fit(Algorithm::Lloyd, &ds, &kcfg2)?;
            // The Pallas kernel computes distances in matmul form
            // (|x|^2 + |c|^2 - 2 x.c); its f32 rounding differs from the
            // native diff-and-square, so near-tie assignments can flip and
            // diverge the trajectory. The correctness bar for a
            // cross-numerics backend is therefore statistical: near-total
            // assignment agreement and matching clustering quality.
            let agree = direct
                .assignments
                .iter()
                .zip(&out.fit.assignments)
                .filter(|(a, b)| a == b)
                .count() as f64
                / ds.n() as f64;
            let inertia_rel =
                (direct.inertia - out.fit.inertia).abs() / direct.inertia.max(1e-12);
            println!(
                "  kegg@20000 on xla-pjrt: {} iters, {:.3}s wall, {} tiles \
                 | agreement with Lloyd {:.3}%, inertia rel-diff {:.2e}",
                out.fit.iterations,
                wall,
                out.report.tiles_dispatched,
                agree * 100.0,
                inertia_rel
            );
            assert!(agree > 0.99, "XLA backend must match Lloyd on >99% of points");
            assert!(inertia_rel < 1e-3, "clustering quality must match");
        }
        Err(e) => println!("  skipped (artifacts not built?): {e}"),
    }
    Ok(())
}
