//! Serving quickstart: a multi-tenant job mix through the sharded pool.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```
//!
//! Builds the same stream a `kpynq serve` NDJSON file would describe —
//! coalescable native jobs, an incompatible-dimension tenant, a simulated
//! FPGA tenant, priorities and one already-expired deadline — serves it on
//! two worker shards, and prints the NDJSON responses plus the
//! `ServeReport`. The equivalent CLI session is printed at the end.

use kpynq::kmeans::KMeansConfig;
use kpynq::serve::{FitRequest, JobStatus, Priority, ServeConfig, Server};

fn main() -> kpynq::Result<()> {
    let mut jobs = Vec::new();
    // Four native blobs tenants (same d=16 → coalesce into micro-batches).
    for id in 1..=4u64 {
        jobs.push(FitRequest {
            id,
            max_points: 2_000,
            data_seed: 100 + id,
            kmeans: KMeansConfig { k: 4 + id as usize, seed: id, ..Default::default() },
            ..Default::default()
        });
    }
    // A kegg tenant (d=20): compatible with nobody above, runs solo.
    jobs.push(FitRequest {
        id: 5,
        dataset: "kegg".into(),
        max_points: 3_000,
        kmeans: KMeansConfig { k: 8, seed: 5, ..Default::default() },
        priority: Priority::High,
        ..Default::default()
    });
    // A simulated-FPGA tenant: always solo, reports cycles not wall-clock.
    jobs.push(FitRequest {
        id: 6,
        backend_name: "fpga-sim".into(),
        max_points: 1_500,
        kmeans: KMeansConfig { k: 4, seed: 6, ..Default::default() },
        ..Default::default()
    });
    // A tenant that stopped waiting before we even started.
    jobs.push(FitRequest {
        id: 7,
        max_points: 2_000,
        deadline_ms: Some(0),
        priority: Priority::Low,
        ..Default::default()
    });

    let server = Server::new(ServeConfig { workers: 2, ..Default::default() })?;
    let outcome = server.run(jobs)?;

    println!("-- responses (NDJSON, what `kpynq serve` writes to stdout) --");
    for resp in &outcome.responses {
        println!("{}", resp.to_json().to_string());
    }
    println!("\n-- report --\n{}", outcome.report.render());

    let ok = outcome.responses.iter().filter(|r| r.status == JobStatus::Ok).count();
    let shed = outcome.responses.iter().filter(|r| r.status == JobStatus::Shed).count();
    assert_eq!(ok, 6, "six live tenants must complete");
    assert_eq!(shed, 1, "the expired-deadline tenant must be shed, not run");

    println!("equivalent CLI session:");
    println!("  kpynq serve --jobs jobs.ndjson --workers 2 --batch 8");
    println!("  (jobs.ndjson: one {{\"id\":…}} object per line; `kpynq serve --help` lists keys)");
    Ok(())
}
