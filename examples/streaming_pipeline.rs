//! Streaming pipeline: host-side double buffering against the XLA engine.
//!
//! ```bash
//! cargo run --release --example streaming_pipeline
//! ```
//!
//! On the board, DMA ping-pongs tiles into BRAM while the PL crunches the
//! previous tile. On the host the same structure overlaps tile *prep*
//! (gather/pad — memory-bound) with kernel execution (PJRT — compute-
//! bound). This example streams one dataset through both the serial and
//! the double-buffered path, verifies identical results, and reports the
//! overlap gain — the software analogue of the `fig_dma_breakdown`
//! overlap measurement.

use std::path::PathBuf;
use std::time::Instant;

use kpynq::coordinator::buffer::pipelined;
use kpynq::coordinator::scheduler;
use kpynq::data::{normalize, synth};
use kpynq::kmeans::{init, KMeansConfig};
use kpynq::runtime::native::NativeEngine;
use kpynq::runtime::xla::XlaEngine;
use kpynq::runtime::Engine;

fn main() -> kpynq::Result<()> {
    let mut ds = synth::uci("uscensus", 5).unwrap().subsample(50_000, 5);
    normalize::min_max(&mut ds);
    let kcfg = KMeansConfig { k: 16, seed: 9, ..Default::default() };
    let cents = init::initialize(&ds, &kcfg)?;
    let tiles = scheduler::partition(ds.n(), 256);
    println!(
        "streaming {} points x {} dims through {} tiles of 256",
        ds.n(),
        ds.d(),
        tiles.len()
    );

    // ---- native engine: serial vs double-buffered ----
    let t0 = Instant::now();
    let mut serial_idx: Vec<u32> = Vec::with_capacity(ds.n());
    for t in &tiles {
        let pts = ds.points.gather_rows(&t.indices);
        serial_idx.extend(NativeEngine.assign_tile(&pts, &cents)?.idx);
    }
    let serial_s = t0.elapsed().as_secs_f64();

    let points = &ds.points;
    let cents_ref = &cents;
    let t0 = Instant::now();
    let (chunks, timing) = pipelined(
        tiles.clone(),
        move |t| points.gather_rows(&t.indices),
        |tile_pts| NativeEngine.assign_tile(&tile_pts, cents_ref).unwrap().idx,
    );
    let overlapped_s = t0.elapsed().as_secs_f64();
    let overlapped_idx: Vec<u32> = chunks.into_iter().flatten().collect();
    assert_eq!(serial_idx, overlapped_idx, "overlap must not change results");
    println!(
        "native engine: serial {:.1} ms, double-buffered {:.1} ms ({:.2}x) — \
         producer blocked {:.1} ms, consumer blocked {:.1} ms",
        serial_s * 1e3,
        overlapped_s * 1e3,
        serial_s / overlapped_s,
        timing.producer_blocked.as_secs_f64() * 1e3,
        timing.consumer_blocked.as_secs_f64() * 1e3,
    );

    // ---- XLA engine: the AOT Pallas kernel behind the same pipeline ----
    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match XlaEngine::new(&artifact_dir) {
        Ok(mut eng) => {
            // Warm the executable cache outside the timed region (compile
            // happens once per variant; the request path never recompiles).
            let warm = ds.points.gather_rows(&tiles[0].indices);
            eng.assign_tile(&warm, &cents)?;

            let t0 = Instant::now();
            let mut xla_idx: Vec<u32> = Vec::with_capacity(ds.n());
            for t in &tiles {
                let pts = ds.points.gather_rows(&t.indices);
                xla_idx.extend(eng.assign_tile(&pts, &cents)?.idx);
            }
            let xla_s = t0.elapsed().as_secs_f64();
            assert_eq!(serial_idx, xla_idx, "XLA engine must agree with native");
            let tput = ds.n() as f64 / xla_s / 1e6;
            println!(
                "xla-pjrt engine: {:.1} ms for {} tiles ({:.2} Mpoints/s), \
                 parity with native: ok",
                xla_s * 1e3,
                eng.tiles_executed,
                tput
            );
        }
        Err(e) => println!("xla engine skipped (run `make artifacts`): {e}"),
    }
    Ok(())
}
