"""AOT export: HLO text well-formedness, manifest schema, determinism."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(outdir), "--quick"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
    )
    return outdir


def test_manifest_schema(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["tile_n"] == aot.TILE_N
    assert len(manifest["artifacts"]) == 2  # assign + group_min for d4k16
    for rec in manifest["artifacts"]:
        for key in ("name", "file", "entry", "tile_n", "d", "k", "g",
                    "inputs", "outputs", "sha256"):
            assert key in rec, f"manifest record missing {key}"
        assert (exported / rec["file"]).exists()


def test_hlo_text_is_parseable_shape(exported):
    manifest = json.loads((exported / "manifest.json").read_text())
    for rec in manifest["artifacts"]:
        text = (exported / rec["file"]).read_text()
        assert text.startswith("HloModule"), "must be HLO text, not proto"
        assert "ENTRY" in text
        # Input signature embedded in the entry layout must match manifest.
        for inp in rec["inputs"]:
            dims = ",".join(str(x) for x in inp["shape"])
            assert f"{inp['dtype']}[{dims}]" in text


def test_sha_matches_content(exported):
    import hashlib
    manifest = json.loads((exported / "manifest.json").read_text())
    for rec in manifest["artifacts"]:
        text = (exported / rec["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == rec["sha256"]


def test_export_is_deterministic(tmp_path):
    """Two exports of the same entry must produce byte-identical HLO —
    the Makefile's no-op stamp logic depends on this."""
    from compile import model
    eps = model.entry_points(aot.TILE_N, 4, 16, 8, 2)
    fn, args = eps["assign"]
    r1 = aot.export_entry("a", fn, args, str(tmp_path), {"entry": "assign"})
    r2 = aot.export_entry("a", fn, args, str(tmp_path), {"entry": "assign"})
    assert r1["sha256"] == r2["sha256"]


def test_variant_grid_covers_demo():
    assert aot.DEMO_VARIANT in aot.VARIANTS
    for d, k, g in aot.VARIANTS:
        assert k >= 1 and d >= 1 and g >= 1
        assert g <= k, "never more groups than centroids"
