"""L1 correctness: group-filter kernel vs. oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, groupmin, ref

TILE = distance.DEFAULT_TILE_N


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(1, 96),
    k=st.integers(1, 32),
    g=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_group_min_matches_ref(d, k, g, seed):
    rng = np.random.RandomState(seed)
    pts = rng.randn(TILE, d).astype(np.float32)
    cents = rng.randn(k, d).astype(np.float32)
    gids = rng.randint(0, g, size=k).astype(np.int32)
    got = np.asarray(groupmin.group_min(
        jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(gids), g))
    want = np.asarray(ref.group_min_dist(
        jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(gids), g))
    # Empty groups are +inf in both; compare finite entries numerically.
    assert (np.isinf(got) == np.isinf(want)).all()
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-4, atol=1e-4)


def test_single_group_equals_global_min(rng):
    pts = rng.randn(TILE, 12).astype(np.float32)
    cents = rng.randn(8, 12).astype(np.float32)
    gids = np.zeros(8, dtype=np.int32)
    got = np.asarray(groupmin.group_min(
        jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(gids), 1))[:, 0]
    d = np.asarray(ref.pairwise_sq_dist(jnp.asarray(pts), jnp.asarray(cents)))
    np.testing.assert_allclose(got, d.min(axis=1), rtol=1e-4, atol=1e-4)


def test_empty_group_is_inf(rng):
    pts = rng.randn(TILE, 6).astype(np.float32)
    cents = rng.randn(4, 6).astype(np.float32)
    gids = np.zeros(4, dtype=np.int32)  # group 1 of 2 is empty
    got = np.asarray(groupmin.group_min(
        jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(gids), 2))
    assert np.isfinite(got[:, 0]).all()
    assert np.isinf(got[:, 1]).all()


def test_group_min_lower_bounds_member_distances(rng):
    """Invariant: out[n, g] <= d(n, c) for every centroid c in group g."""
    pts = rng.randn(TILE, 10).astype(np.float32)
    cents = rng.randn(12, 10).astype(np.float32)
    gids = (np.arange(12) % 3).astype(np.int32)
    gm = np.asarray(groupmin.group_min(
        jnp.asarray(pts), jnp.asarray(cents), jnp.asarray(gids), 3))
    d = np.asarray(ref.pairwise_sq_dist(jnp.asarray(pts), jnp.asarray(cents)))
    for c in range(12):
        assert (gm[:, gids[c]] <= d[:, c] + 1e-3).all()
