"""Shared pytest fixtures for the KPynq build-time test suite.

Run from the ``python/`` directory (``cd python && pytest tests/``) so the
``compile`` package resolves. The suite is hermetic: every random input is
derived from a fixed seed or from hypothesis's managed entropy.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture
def rng():
    return np.random.RandomState(0xC0FFEE)


def make_blobs(rng, n, d, k, spread=0.05, sep=4.0):
    """Well-separated Gaussian blobs + the true centers that generated them."""
    centers = rng.randn(k, d).astype(np.float32) * sep
    labels = rng.randint(0, k, size=n)
    pts = centers[labels] + rng.randn(n, d).astype(np.float32) * spread
    return pts.astype(np.float32), centers, labels
