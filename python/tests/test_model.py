"""L2 correctness: the JAX K-means graphs vs. the oracle and vs. physics
(inertia monotonicity, convergence on separable data)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import distance, ref
from tests.conftest import make_blobs

TILE = distance.DEFAULT_TILE_N


@settings(max_examples=15, deadline=None)
@given(d=st.integers(2, 80), k=st.integers(2, 32), seed=st.integers(0, 2**31 - 1))
def test_kmeans_step_matches_oracle(d, k, seed):
    rng = np.random.RandomState(seed)
    pts = jnp.asarray(rng.randn(TILE, d).astype(np.float32))
    cents = jnp.asarray(rng.randn(k, d).astype(np.float32))
    new_c, idx, counts, inertia = model.kmeans_step(pts, cents)
    ref_c, ref_idx, ref_counts, ref_inertia = ref.lloyd_step(pts, cents)
    # Assignment near-ties can flip a point; tolerate by comparing where
    # assignments agree and requiring the overall inertia to match closely.
    agree = np.asarray(idx) == np.asarray(ref_idx)
    assert agree.mean() > 0.99
    if agree.all():
        np.testing.assert_allclose(np.asarray(new_c), np.asarray(ref_c),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref_counts))
    np.testing.assert_allclose(float(inertia), float(ref_inertia),
                               rtol=1e-3, atol=1e-3)


def test_empty_cluster_keeps_centroid(rng):
    pts, _, _ = make_blobs(rng, TILE, 8, 2)
    # Put one centroid impossibly far away: it must receive no points and
    # stay exactly where it was.
    far = np.full((1, 8), 1e6, dtype=np.float32)
    near = pts[:2].copy()
    cents = jnp.asarray(np.concatenate([near, far]))
    new_c, _idx, counts, _ = model.kmeans_step(jnp.asarray(pts), cents)
    assert float(counts[2]) == 0.0
    np.testing.assert_array_equal(np.asarray(new_c)[2], far[0])


def test_kmeans_run_inertia_monotone(rng):
    pts, centers, _ = make_blobs(rng, TILE, 16, 4, spread=0.5)
    init = jnp.asarray(pts[:4].copy())
    _, _, inertias = model.kmeans_run(jnp.asarray(pts), init, 12)
    traj = np.asarray(inertias)
    assert (np.diff(traj) <= 1e-2 * np.abs(traj[:-1]) + 1e-3).all(), \
        f"inertia must be non-increasing, got {traj}"


def test_kmeans_run_converges_on_separable_blobs(rng):
    pts, centers, labels = make_blobs(rng, TILE, 8, 4, spread=0.02, sep=10.0)
    # Seed with one true member per cluster so Lloyd provably recovers them.
    seeds = np.stack([pts[labels == j][0] for j in range(4)])
    final_c, idx, _ = model.kmeans_run(jnp.asarray(pts), jnp.asarray(seeds), 10)
    final_c = np.asarray(final_c)
    # Each recovered centroid must be near a distinct true center.
    d = np.linalg.norm(final_c[:, None, :] - centers[None], axis=-1)
    matched = d.argmin(axis=1)
    assert len(set(matched.tolist())) == 4
    assert d.min(axis=1).max() < 0.1
    # And assignments must reproduce the generating labels up to the match.
    remap = {j: matched[j] for j in range(4)}
    got = np.array([remap[int(a)] for a in np.asarray(idx)])
    assert (got == labels).mean() == 1.0


def test_kmeans_step_fixed_point(rng):
    """At a converged solution, one more step must be a no-op."""
    pts, _, _ = make_blobs(rng, TILE, 8, 4, spread=0.05, sep=8.0)
    c = jnp.asarray(pts[:4].copy())
    for _ in range(20):
        c, _, _, _ = model.kmeans_step(jnp.asarray(pts), c)
    c2, _, _, _ = model.kmeans_step(jnp.asarray(pts), c)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c), rtol=1e-5, atol=1e-5)


def test_entry_points_table_is_complete():
    eps = model.entry_points(TILE, 8, 4, 2, 3)
    assert set(eps) == {"assign", "group_min", "kmeans_step", "kmeans_run"}
    for _name, (fn, args) in eps.items():
        # Every entry must be traceable with its own example args.
        import jax
        jax.eval_shape(fn, *args)
