"""L1 correctness: Pallas distance/assign kernels vs. the pure-jnp oracle.

This is the CORE correctness signal for Layer 1 (DESIGN.md §6): hypothesis
sweeps the kernel's shape space and asserts allclose against ``ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, ref

TILE = distance.DEFAULT_TILE_N


def _tolerant_assign_check(pts, cents, idx_k, best_k, second_k):
    """Assignments must agree with the oracle except at float near-ties,
    where the kernel's pick must be within tolerance of the oracle's best."""
    idx_r, best_r, second_r = ref.assign(jnp.asarray(pts), jnp.asarray(cents))
    idx_r, best_r, second_r = map(np.asarray, (idx_r, best_r, second_r))
    np.testing.assert_allclose(best_k, best_r, rtol=1e-4, atol=1e-4)
    if cents.shape[0] > 1:
        finite = np.isfinite(second_r)
        np.testing.assert_allclose(second_k[finite], second_r[finite],
                                   rtol=1e-4, atol=1e-4)
    mismatch = idx_k != idx_r
    if mismatch.any():
        # Every mismatch must be a near-tie: the kernel's chosen centroid is
        # within float tolerance of the oracle's best distance.
        d_full = np.asarray(ref.pairwise_sq_dist(jnp.asarray(pts),
                                                 jnp.asarray(cents)))
        chosen = d_full[np.arange(len(idx_k)), idx_k]
        scale = np.maximum(1.0, np.abs(best_r[mismatch]))
        assert np.all(np.abs(chosen[mismatch] - best_r[mismatch])
                      <= 1e-3 * scale), "non-tie assignment mismatch"


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    d=st.integers(1, 130),
    k=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_sq_dist_matches_ref(n_tiles, d, k, seed):
    rng = np.random.RandomState(seed)
    pts = rng.randn(n_tiles * TILE, d).astype(np.float32)
    cents = rng.randn(k, d).astype(np.float32)
    got = np.asarray(distance.pairwise_sq_dist(jnp.asarray(pts), jnp.asarray(cents)))
    want = np.asarray(ref.pairwise_sq_dist(jnp.asarray(pts), jnp.asarray(cents)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert (got >= 0).all(), "squared distances must be clamped non-negative"


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 130),
    k=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_assign_matches_ref(d, k, seed, scale):
    rng = np.random.RandomState(seed)
    pts = (rng.randn(TILE, d) * scale).astype(np.float32)
    cents = (rng.randn(k, d) * scale).astype(np.float32)
    idx, best, second = distance.assign(jnp.asarray(pts), jnp.asarray(cents))
    idx, best, second = map(np.asarray, (idx, best, second))
    # Normalise tolerance by the scale^2 of the squared distances.
    _tolerant_assign_check(pts / scale, cents / scale,
                           idx, best / scale**2, second / scale**2)


def test_assign_k1_second_is_inf(rng):
    pts = rng.randn(TILE, 8).astype(np.float32)
    cents = rng.randn(1, 8).astype(np.float32)
    idx, best, second = distance.assign(jnp.asarray(pts), jnp.asarray(cents))
    assert (np.asarray(idx) == 0).all()
    assert np.isinf(np.asarray(second)).all()


def test_point_on_centroid_has_zero_distance(rng):
    cents = rng.randn(4, 16).astype(np.float32)
    pts = np.tile(cents, (TILE // 4, 1)).astype(np.float32)
    idx, best, _ = distance.assign(jnp.asarray(pts), jnp.asarray(cents))
    np.testing.assert_allclose(np.asarray(best), 0.0, atol=1e-4)
    assert (np.asarray(idx) == np.tile(np.arange(4), TILE // 4)).all()


def test_multi_tile_grid_consistent(rng):
    """A 3-tile input must equal three independent 1-tile calls."""
    pts = rng.randn(3 * TILE, 24).astype(np.float32)
    cents = rng.randn(16, 24).astype(np.float32)
    full = np.asarray(distance.pairwise_sq_dist(jnp.asarray(pts), jnp.asarray(cents)))
    for t in range(3):
        part = np.asarray(distance.pairwise_sq_dist(
            jnp.asarray(pts[t * TILE:(t + 1) * TILE]), jnp.asarray(cents)))
        np.testing.assert_array_equal(full[t * TILE:(t + 1) * TILE], part)


def test_non_multiple_tile_rejected(rng):
    pts = rng.randn(100, 8).astype(np.float32)
    cents = rng.randn(4, 8).astype(np.float32)
    with pytest.raises(ValueError, match="multiple of tile_n"):
        distance.pairwise_sq_dist(jnp.asarray(pts), jnp.asarray(cents))


def test_vmem_budget_of_exported_variants():
    """Every AOT variant must fit the 16 MiB VMEM budget (DESIGN.md §Perf)."""
    from compile import aot
    for d, k, _g in aot.VARIANTS:
        assert distance.vmem_bytes(TILE, d, k) < 16 * 2**20


def test_mxu_flops_accounting():
    assert distance.mxu_flops(256, 64, 16) == 2 * 256 * 64 * 16
