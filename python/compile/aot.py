"""AOT driver: lower the Layer-2 graphs to HLO text + manifest.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every exported graph becomes one ``artifacts/<name>.hlo.txt`` plus an entry
in ``artifacts/manifest.json`` describing its geometry and I/O signature —
the Rust runtime (``rust/src/runtime``) reads the manifest, compiles each
module once on the PJRT CPU client, and dispatches tiles to the variant
whose padded geometry matches.

Usage::

    python -m compile.aot --outdir ../artifacts [--quick]

``--quick`` exports only the smallest variant (used by fast CI loops).
The Makefile treats the manifest as the stamp: unchanged inputs = no-op.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import distance

# Variant grid. The coordinator pads (d, k) up to the nearest exported
# variant and slices the results back, so this grid bounds the padding
# waste, not the supported problem sizes. TILE_N is fixed at the kernel
# default: it is the unit of DMA bursts and double buffering on the Rust
# side, mirroring the point-slab BRAM on the FPGA.
TILE_N = distance.DEFAULT_TILE_N
VARIANTS = [
    # (d, k, n_groups)
    (4, 16, 8),
    (32, 16, 8),
    (64, 16, 8),
    (128, 16, 8),
    (64, 64, 16),
]
# Entries exported for every variant vs. only the demo variant.
TILE_ENTRIES = ("assign", "group_min")
DEMO_VARIANT = (32, 16, 8)
DEMO_ENTRIES = ("kmeans_step", "kmeans_run")
DEMO_ITERS = 20

_DTYPES = {"float32": "f32", "int32": "s32"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals) -> list[dict]:
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": _DTYPES[str(a.dtype)]})
    return out


def export_entry(name, fn, example_args, outdir, meta):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *example_args)
    flat, _ = jax.tree.flatten(out_avals)
    record = {
        "name": name,
        "file": fname,
        "inputs": _sig(example_args),
        "outputs": _sig(flat),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        **meta,
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="export only the smallest variant")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    variants = VARIANTS[:1] if args.quick else VARIANTS
    records = []
    for d, k, g in variants:
        entries = model.entry_points(TILE_N, d, k, g, DEMO_ITERS)
        names = TILE_ENTRIES
        if (d, k, g) == DEMO_VARIANT and not args.quick:
            names = TILE_ENTRIES + DEMO_ENTRIES
        for entry in names:
            fn, example_args = entries[entry]
            name = f"{entry}_n{TILE_N}_d{d}_k{k}"
            meta = {"entry": entry, "tile_n": TILE_N, "d": d, "k": k, "g": g}
            if entry == "kmeans_run":
                meta["n_iters"] = DEMO_ITERS
            rec = export_entry(name, fn, example_args, args.outdir, meta)
            records.append(rec)
            print(f"  exported {rec['file']}  "
                  f"({len(rec['inputs'])} in / {len(rec['outputs'])} out)")

    manifest = {"version": 1, "tile_n": TILE_N, "artifacts": records}
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(records)} artifacts + manifest to {args.outdir}")


if __name__ == "__main__":
    main()
