"""L1/L2 performance analysis: VMEM footprint, MXU utilisation estimate and
HLO op census for every exported variant.

Interpret-mode Pallas gives CPU-numpy timings only — not a TPU proxy — so
the kernel is optimised *structurally* (DESIGN.md §Perf): block shapes are
sized against the 16 MiB VMEM budget and the arithmetic is arranged so the
dominant term is a single MXU-shaped matmul. This tool quantifies both and
is quoted in EXPERIMENTS.md §Perf.

Usage::

    python -m compile.analysis [--outdir ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import os
import re

from .kernels import distance

# MXU-efficiency model: the matmul term issues ceil(TILE_N/128)*ceil(K/128)
# *ceil(D/128) 128x128x128 MXU passes; utilisation is useful MACs over
# issued MACs (padding waste), the same accounting as the FPGA pipeline
# model in rust/src/hw/pipeline.rs.
MXU_DIM = 128


def mxu_utilization(tile_n: int, d: int, k: int) -> float:
    def up(x: int) -> int:
        return -(-x // MXU_DIM) * MXU_DIM

    useful = tile_n * d * k
    issued = up(tile_n) * up(d) * up(k)
    return useful / issued


def hlo_census(path: str) -> dict:
    """Count the op kinds in an HLO text module (rough L2 profile: what did
    XLA keep after fusion/CSE of the lowered Pallas + model graph)."""
    ops: dict[str, int] = {}
    entry = False
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line.startswith("ENTRY"):
                entry = True
            m = re.match(r"(?:ROOT )?%?[\w.\-]+ = \S+ ([a-z\-]+)\(", line)
            if m:
                ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    _ = entry
    return ops


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    manifest = json.load(open(os.path.join(args.outdir, "manifest.json")))

    print(f"{'variant':<28} {'VMEM KiB':>9} {'of 16MiB':>9} {'MXU util':>9} {'HLO ops':>8}")
    for rec in manifest["artifacts"]:
        if rec["entry"] not in ("assign", "group_min"):
            continue
        tn, d, k = rec["tile_n"], rec["d"], rec["k"]
        vmem = distance.vmem_bytes(tn, d, k)
        util = mxu_utilization(tn, d, k)
        ops = hlo_census(os.path.join(args.outdir, rec["file"]))
        print(
            f"{rec['name']:<28} {vmem / 1024:>9.1f} {vmem / (16 * 2**20):>8.2%} "
            f"{util:>8.1%} {sum(ops.values()):>8}"
        )
    print(
        "\nMXU utilisation = useful MACs / issued 128^3-pass MACs (padding waste);\n"
        "K=16 variants pad the K axis 8x on a real MXU -> the K=64 variant is the\n"
        "TPU-preferred shape; the coordinator's variant picker already prefers the\n"
        "tightest dominating geometry."
    )


if __name__ == "__main__":
    main()
