"""Layer-1 Pallas kernels: the KPynq Distance Calculator, re-thought for TPU.

On the Pynq-Z1 the paper implements the distance calculator as P parallel
DSP48 MAC pipelines fed from BRAM at initiation interval 1. A TPU has no
per-lane dataflow pipeline; its throughput lives in the MXU systolic array.
The adaptation (DESIGN.md §Hardware-Adaptation):

  * the P-lane MAC tree becomes a tiled matmul: with row norms precomputed,
    ``d(x, c)^2 = |x|^2 + |c|^2 - 2 x·c^T`` and the ``x·c^T`` term is a
    (TILE_N × D) @ (D × K) MXU matmul;
  * BRAM double-buffering becomes the Pallas ``BlockSpec`` HBM→VMEM block
    schedule: each grid step streams one TILE_N slab of points into VMEM
    while the full centroid block (K × D — small, the paper's K ≤ 64)
    stays resident, exactly like the centroid BRAM bank on the FPGA;
  * the per-point filter branch does NOT live here — filtering is batch
    compaction in the Rust coordinator; the kernel only ever sees dense
    survivor tiles.

All kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime loads AOT. Block shapes are still chosen as if for real VMEM (see
``vmem_bytes``) so the schedule is hardware-honest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile of points per grid step. 256 × 128 f32 = 128 KiB of VMEM for
# the point slab; with K ≤ 64 the centroid slab and the output tile are far
# smaller, leaving headroom under the ~16 MiB VMEM budget (see vmem_bytes).
DEFAULT_TILE_N = 256


def vmem_bytes(tile_n: int, d: int, k: int) -> int:
    """Estimated VMEM footprint of one grid step of the assign kernel.

    points slab + resident centroids + centroid norms + distance tile +
    the three output slices. Used by the AOT driver to sanity-check block
    shapes against the 16 MiB/core budget, and quoted in DESIGN.md §Perf.
    """
    f32 = 4
    return (
        tile_n * d * f32      # x tile
        + k * d * f32         # centroids (resident)
        + k * f32             # |c|^2 (resident)
        + tile_n * k * f32    # distance tile
        + tile_n * (4 + f32 + f32)  # assign (i32) + best + second
    )


def mxu_flops(n: int, d: int, k: int) -> int:
    """MAC-tree / MXU work of one dense assign pass: the 2·N·K·D matmul
    term dominates; norm and reduction terms are O(N·K + N·D)."""
    return 2 * n * k * d


def _sq_dist_tile(x, c, csq):
    """Distance tile in matmul form — the MXU-shaped inner loop.

    x: (TN, D) point slab, c: (K, D) centroids, csq: (K,) centroid norms.
    Returns (TN, K) squared distances, clamped at 0 (f32 cancellation).
    """
    xsq = jnp.sum(x * x, axis=1)  # (TN,)
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # MXU term
    return jnp.maximum(xsq[:, None] + csq[None, :] - 2.0 * xc, 0.0)


def _dist_kernel(x_ref, c_ref, csq_ref, o_ref):
    o_ref[...] = _sq_dist_tile(x_ref[...], c_ref[...], csq_ref[...])


def _assign_kernel(x_ref, c_ref, csq_ref, idx_ref, best_ref, second_ref):
    d = _sq_dist_tile(x_ref[...], c_ref[...], csq_ref[...])  # (TN, K)
    k = d.shape[1]
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    best = jnp.min(d, axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    masked = jnp.where(col == idx[:, None], jnp.inf, d)
    second = jnp.min(masked, axis=1) if k > 1 else jnp.full_like(best, jnp.inf)
    idx_ref[...] = idx
    best_ref[...] = best
    second_ref[...] = second


def _grid_and_specs(n: int, d: int, k: int, tile_n: int):
    if n % tile_n != 0:
        raise ValueError(f"n={n} must be a multiple of tile_n={tile_n}; "
                         "the coordinator pads tiles before dispatch")
    grid = (n // tile_n,)
    x_spec = pl.BlockSpec((tile_n, d), lambda i: (i, 0))
    # Centroids + norms are resident across the whole grid (the FPGA's
    # centroid BRAM bank): every step maps to block (0, 0)/(0,).
    c_spec = pl.BlockSpec((k, d), lambda i: (0, 0))
    csq_spec = pl.BlockSpec((k,), lambda i: (0,))
    return grid, x_spec, c_spec, csq_spec


@functools.partial(jax.jit, static_argnames=("tile_n",))
def pairwise_sq_dist(points, centroids, tile_n: int = DEFAULT_TILE_N):
    """Pallas pairwise squared distances: f32[N,D] × f32[K,D] → f32[N,K].

    Oracle: ``ref.pairwise_sq_dist``.
    """
    n, d = points.shape
    k = centroids.shape[0]
    csq = jnp.sum(centroids * centroids, axis=1)
    grid, x_spec, c_spec, csq_spec = _grid_and_specs(n, d, k, tile_n)
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[x_spec, c_spec, csq_spec],
        out_specs=pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(points, centroids, csq)


@functools.partial(jax.jit, static_argnames=("tile_n",))
def assign(points, centroids, tile_n: int = DEFAULT_TILE_N):
    """Pallas assign tile: nearest centroid + best/second squared distances.

    This is the kernel the AOT path exports for the Rust accelerator's
    survivor tiles. Oracle: ``ref.assign``.

    Returns (assign i32[N], best f32[N], second f32[N]).
    """
    n, d = points.shape
    k = centroids.shape[0]
    csq = jnp.sum(centroids * centroids, axis=1)
    grid, x_spec, c_spec, csq_spec = _grid_and_specs(n, d, k, tile_n)
    row_spec = pl.BlockSpec((tile_n,), lambda i: (i,))
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[x_spec, c_spec, csq_spec],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids, csq)
