"""Layer-1 Pallas kernel: group-level filter bounds (KPynq Group Filter).

The paper's Group-level Filter keeps, per point, a lower bound on the
distance to every *group* of centroids (centroids are clustered into G
groups once at init, Yinyang-style). When a group's bound proves no member
can beat the current assignment, the whole group is skipped.

On the FPGA this is a compare/accumulate unit sitting in front of the
distance pipeline. On TPU we compute the per-group minima as a dense
masked reduction over the full (TILE_N × K) distance tile — the tile is
already paid for by the MXU matmul, so the group reduction is almost free
(O(N·K) VPU work after the O(N·K·D) MXU work).

The group mask is passed as a dense f32 (G × K) membership matrix with
+inf off-group sentinels pre-added by the host, which keeps the kernel
free of gathers (TPU-hostile) and of int comparisons in the reduction.

Oracle: ``ref.group_min_dist``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import distance as _dist


def group_penalty_matrix(group_of_centroid, n_groups: int):
    """Build the (G, K) penalty matrix: 0 where centroid k is in group g,
    +inf elsewhere. Host-side helper shared with the AOT driver."""
    k = group_of_centroid.shape[0]
    gids = jnp.asarray(group_of_centroid, dtype=jnp.int32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n_groups, k), 0)
    return jnp.where(rows == gids[None, :], 0.0, jnp.inf).astype(jnp.float32)


def _group_min_kernel(x_ref, c_ref, csq_ref, pen_ref, o_ref):
    d = _dist._sq_dist_tile(x_ref[...], c_ref[...], csq_ref[...])  # (TN, K)
    pen = pen_ref[...]  # (G, K): 0 in-group, +inf off-group
    # out[n, g] = min_k (d[n, k] + pen[g, k])  — a (TN, G) masked min.
    o_ref[...] = jnp.min(d[:, None, :] + pen[None, :, :], axis=-1)


@functools.partial(jax.jit, static_argnames=("n_groups", "tile_n"))
def group_min(points, centroids, group_of_centroid, n_groups: int,
              tile_n: int = _dist.DEFAULT_TILE_N):
    """Per-point, per-group minimum squared distance: f32[N, G].

    Used once per Yinyang run to initialise the group lower bounds, and by
    the accelerator model whenever a point fails the group filter for all
    groups (full refresh).
    """
    n, d = points.shape
    k = centroids.shape[0]
    csq = jnp.sum(centroids * centroids, axis=1)
    pen = group_penalty_matrix(group_of_centroid, n_groups)
    grid, x_spec, c_spec, csq_spec = _dist._grid_and_specs(n, d, k, tile_n)
    pen_spec = pl.BlockSpec((n_groups, k), lambda i: (0, 0))
    return pl.pallas_call(
        _group_min_kernel,
        grid=grid,
        in_specs=[x_spec, c_spec, csq_spec, pen_spec],
        out_specs=pl.BlockSpec((tile_n, n_groups), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_groups), jnp.float32),
        interpret=True,
    )(points, centroids, csq, pen)
