"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
plain ``jax.numpy`` with no Pallas, no tiling and no tricks. The pytest
suite (``python/tests/``) sweeps shapes/dtypes with hypothesis and asserts
``allclose`` between kernel and oracle — this file is the single source of
numerical truth for Layer 1.

All distances are *squared* Euclidean distances, clamped at zero (the
matmul-form expansion ``|x|^2 + |c|^2 - 2 x.c`` can go slightly negative in
f32; the hardware model and the bound maintenance in the Rust coordinator
both assume non-negative squared distances).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dist(points: jax.Array, centroids: jax.Array) -> jax.Array:
    """Squared Euclidean distance between every point and every centroid.

    Args:
      points:    f32[N, D]
      centroids: f32[K, D]

    Returns:
      f32[N, K] with ``out[n, k] = max(0, |points[n] - centroids[k]|^2)``.
    """
    diff = points[:, None, :] - centroids[None, :, :]  # (N, K, D)
    return jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)


def assign(points: jax.Array, centroids: jax.Array):
    """Nearest-centroid assignment with first- and second-best distances.

    This is the oracle for the accelerator's assign tile: the Rust
    coordinator needs, per point, the winning centroid index, the winning
    squared distance (the Hamerly/Yinyang *upper bound* before sqrt) and the
    runner-up squared distance (the *lower bound*).

    Returns:
      (assign i32[N], best f32[N], second f32[N])
    """
    d = pairwise_sq_dist(points, centroids)
    best_idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    best = jnp.min(d, axis=1)
    k = d.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    masked = jnp.where(col == best_idx[:, None], jnp.inf, d)
    second = jnp.min(masked, axis=1) if k > 1 else jnp.full_like(best, jnp.inf)
    return best_idx, best, second


def group_min_dist(points: jax.Array, centroids: jax.Array,
                   group_of_centroid: jax.Array, n_groups: int) -> jax.Array:
    """Per-point minimum squared distance to each *group* of centroids.

    Oracle for the group-level filter: ``out[n, g] = min over centroids c in
    group g of |points[n] - c|^2``. Groups with no centroid get ``+inf``.

    Args:
      points:            f32[N, D]
      centroids:         f32[K, D]
      group_of_centroid: i32[K] in [0, n_groups)
      n_groups:          static int
    """
    d = pairwise_sq_dist(points, centroids)  # (N, K)
    onehot = jax.nn.one_hot(group_of_centroid, n_groups, dtype=jnp.bool_)  # (K, G)
    # min over each group: mask non-members with +inf then reduce.
    masked = jnp.where(onehot.T[None, :, :], d[:, None, :], jnp.inf)  # (N, G, K)
    return jnp.min(masked, axis=-1)


def centroid_update(points: jax.Array, assign_idx: jax.Array, k: int):
    """Accumulate per-cluster sums and counts (the M-step).

    Returns (sums f32[K, D], counts f32[K]). Empty-cluster policy (keep the
    old centroid) is applied by the caller, matching the Rust implementation.
    """
    onehot = jax.nn.one_hot(assign_idx, k, dtype=points.dtype)  # (N, K)
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def lloyd_step(points: jax.Array, centroids: jax.Array):
    """One full Lloyd iteration — the oracle for ``model.kmeans_step``.

    Returns (new_centroids, assign_idx, counts, inertia).
    """
    idx, best, _ = assign(points, centroids)
    sums, counts = centroid_update(points, idx, centroids.shape[0])
    safe = jnp.maximum(counts, 1.0)[:, None]
    new_c = jnp.where(counts[:, None] > 0, sums / safe, centroids)
    inertia = jnp.sum(best)
    return new_c, idx, counts, inertia
