"""Layer-2 JAX compute graphs for KPynq.

These are the graphs the AOT driver (``aot.py``) lowers to HLO text and the
Rust runtime executes through PJRT. Python never runs on the request path:
each graph is traced once per (tile_n, d, k) variant at build time.

Graphs:

  * ``assign_tile``   — the accelerator's hot tile: nearest centroid plus
    first/second-best squared distances for one dense survivor tile. This
    is what the Rust coordinator dispatches after the multi-level filter
    has compacted the surviving points (DESIGN.md §Hardware-Adaptation).
  * ``group_min_tile``— group-filter bound initialisation for one tile.
  * ``kmeans_step``   — a full Lloyd iteration (assign + centroid update +
    inertia) for tile-sized problems; used by the quickstart path and as
    the L2-level correctness anchor against ``ref.lloyd_step``.

All graphs call the Layer-1 Pallas kernels so the kernels lower into the
same HLO module the Rust side loads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import distance, groupmin, ref


def assign_tile(points, centroids):
    """Assign one dense tile of points to their nearest centroids.

    Args:
      points:    f32[N, D] (N a multiple of the kernel tile)
      centroids: f32[K, D]

    Returns:
      (assign i32[N], best f32[N], second f32[N]) — squared distances.
    """
    return distance.assign(points, centroids)


def group_min_tile(points, centroids, group_of_centroid, n_groups: int):
    """Group-filter bounds for one tile: f32[N, G] min squared distance."""
    return groupmin.group_min(points, centroids, group_of_centroid, n_groups)


def kmeans_step(points, centroids):
    """One full Lloyd iteration over a tile-sized problem.

    The assignment leg runs through the Pallas kernel; the update leg is the
    one-hot matmul segment-sum (MXU-friendly, no scatters). Empty clusters
    keep their previous centroid, matching the Rust implementation and the
    oracle ``ref.lloyd_step``.

    Returns (new_centroids f32[K,D], assign i32[N], counts f32[K],
    inertia f32[]).
    """
    k = centroids.shape[0]
    idx, best, _ = distance.assign(points, centroids)
    sums, counts = ref.centroid_update(points, idx, k)
    safe = jnp.maximum(counts, 1.0)[:, None]
    new_c = jnp.where(counts[:, None] > 0, sums / safe, centroids)
    inertia = jnp.sum(best)
    return new_c, idx, counts, inertia


def kmeans_run(points, centroids, n_iters: int):
    """``n_iters`` Lloyd iterations as a single scanned graph.

    Scan (not unroll) keeps the HLO module size O(1) in the iteration count
    — the L2 perf note in DESIGN.md §Perf. Returns the final centroids, the
    final assignment and the per-iteration inertia trace.
    """
    def body(c, _):
        new_c, _idx, _counts, inertia = kmeans_step(points, c)
        return new_c, inertia

    final_c, inertias = jax.lax.scan(body, centroids, None, length=n_iters)
    idx, best, _ = distance.assign(points, final_c)
    return final_c, idx, inertias


# ---------------------------------------------------------------------------
# AOT entry points: name -> (traceable, example-arg builder). The builder
# receives the variant geometry and returns the ShapeDtypeStruct tuple that
# jax.jit(...).lower() is called with. Kept here (not in aot.py) so the
# model and its export surface evolve together.
# ---------------------------------------------------------------------------

def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points(tile_n: int, d: int, k: int, n_groups: int, n_iters: int):
    """The export table for one (tile_n, d, k, g) variant."""
    return {
        "assign": (
            assign_tile,
            (_sds((tile_n, d)), _sds((k, d))),
        ),
        "group_min": (
            lambda p, c, g: group_min_tile(p, c, g, n_groups),
            (_sds((tile_n, d)), _sds((k, d)), _sds((k,), jnp.int32)),
        ),
        "kmeans_step": (
            kmeans_step,
            (_sds((tile_n, d)), _sds((k, d))),
        ),
        "kmeans_run": (
            lambda p, c: kmeans_run(p, c, n_iters),
            (_sds((tile_n, d)), _sds((k, d))),
        ),
    }
