//! The non-perturbation contract, held empirically (DESIGN.md §2,
//! `make profile-test`): a fit with per-phase profiling on is
//! bit-identical — assignments, centroid bits, exact inertia, §8 FNV
//! fingerprint — to the same fit with it off, across all four
//! algorithms. Profiling is pure annotation: `Some(PhaseTotals)` on,
//! `None` off, and nothing else about the result may move.
//!
//! Everything lives in ONE `#[test]` fn on purpose: `profile::set_enabled`
//! is process-global and the test harness runs `#[test]` fns on parallel
//! threads — two fns toggling the flag would race each other.

use kpynq::data::synth;
use kpynq::kmeans::{self, Algorithm, KMeansConfig};
use kpynq::obs::profile;
use kpynq::serve::job::assignments_checksum;

#[test]
fn profiling_is_provably_non_perturbing_across_all_four_algorithms() {
    let ds = synth::blobs(2_000, 16, 4, 99);
    let cfg = KMeansConfig { k: 5, seed: 17, max_iters: 40, ..Default::default() };
    for algo in [Algorithm::Lloyd, Algorithm::Hamerly, Algorithm::Elkan, Algorithm::Yinyang] {
        profile::set_enabled(false);
        let off = kmeans::fit(algo, &ds, &cfg).expect("fit with profiling off");
        profile::set_enabled(true);
        let on = kmeans::fit(algo, &ds, &cfg).expect("fit with profiling on");
        profile::set_enabled(false);

        // The only permitted difference: totals exist exactly when the
        // timer was on.
        assert_eq!(
            off.stats.phases, None,
            "{}: a profiling-off fit must carry no phase totals",
            algo.name()
        );
        let phases = on
            .stats
            .phases
            .unwrap_or_else(|| panic!("{}: a profiling-on fit must carry totals", algo.name()));
        assert!(
            phases.total_ms() > 0.0,
            "{}: a 40-iteration fit attributes some wall time",
            algo.name()
        );

        // Bit-for-bit identity of everything that matters.
        assert_eq!(on.assignments, off.assignments, "{}: assignments diverge", algo.name());
        assert_eq!(
            assignments_checksum(&on.assignments),
            assignments_checksum(&off.assignments),
            "{}: §8 fingerprints diverge",
            algo.name()
        );
        let off_bits: Vec<u64> = off.centroids.as_slice().iter().map(|v| v.to_bits()).collect();
        let on_bits: Vec<u64> = on.centroids.as_slice().iter().map(|v| v.to_bits()).collect();
        assert_eq!(on_bits, off_bits, "{}: centroid bits diverge", algo.name());
        assert_eq!(
            on.inertia.to_bits(),
            off.inertia.to_bits(),
            "{}: inertia bits diverge",
            algo.name()
        );
        assert_eq!(on.iterations, off.iterations, "{}: iteration counts diverge", algo.name());
        assert_eq!(on.converged, off.converged, "{}: convergence flags diverge", algo.name());
    }
}
