//! End-to-end serving tests: the acceptance surface of `kpynq::serve`.
//!
//! The load-bearing claim: a served fit — queued, prioritised, possibly
//! coalesced into a micro-batch, executed on a shard's long-lived engine —
//! is **bit-identical** to a direct `coordinator` run of the same request.
//! Serving changes scheduling, never results.

use kpynq::coordinator::{KpynqSystem, SystemConfig, SystemOutput};
use kpynq::kmeans::KMeansConfig;
use kpynq::runtime::native::NativeEngine;
use kpynq::serve::job::assignments_checksum;
use kpynq::serve::{FitRequest, JobStatus, ServeConfig, Server, ShedPolicy};
use kpynq::util::json::Json;

/// The reference: run the request directly through the coordinator, no
/// serving layer involved.
fn direct(req: &FitRequest) -> SystemOutput {
    let rc = req.to_run_config().unwrap();
    let ds = rc.load_dataset().unwrap();
    KpynqSystem::new(SystemConfig { backend: rc.backend(), verify: false })
        .unwrap()
        .cluster(&ds, &req.kmeans)
        .unwrap()
}

#[test]
fn served_ndjson_jobs_are_bit_identical_to_direct_runs() {
    // The acceptance criterion: ≥ 2 concurrent line-delimited JSON jobs,
    // mixed tenants — coalescable native jobs, a different-d tenant, a
    // simulated-FPGA tenant — through a 2-shard pool.
    let lines = [
        r#"{"id": 1, "dataset": "blobs", "max_points": 1500, "k": 4, "seed": 11}"#,
        r#"{"id": 2, "dataset": "blobs", "max_points": 1500, "k": 6, "seed": 22}"#,
        r#"{"id": 3, "dataset": "kegg", "max_points": 1500, "k": 5, "seed": 33, "priority": "high"}"#,
        r#"{"id": 4, "dataset": "blobs", "max_points": 900, "k": 3, "seed": 44, "backend": "fpga-sim"}"#,
    ];
    let jobs: Vec<FitRequest> =
        lines.iter().map(|l| FitRequest::from_json_line(l).unwrap()).collect();

    let server =
        Server::new(ServeConfig { workers: 2, max_batch: 8, ..Default::default() }).unwrap();
    let outcome = server.run(jobs.clone()).unwrap();

    assert_eq!(outcome.responses.len(), 4);
    assert_eq!(outcome.report.completed, 4);
    for (req, resp) in jobs.iter().zip(&outcome.responses) {
        assert_eq!(req.id, resp.id);
        assert_eq!(resp.status, JobStatus::Ok, "job {}: {}", resp.id, resp.detail);
        let served = resp.fit.as_ref().unwrap();
        let want = direct(req);
        assert_eq!(served.assignments, want.fit.assignments, "job {}", req.id);
        assert_eq!(served.centroids, want.fit.centroids, "job {}", req.id);
        assert_eq!(served.iterations, want.fit.iterations, "job {}", req.id);
        assert_eq!(served.inertia, want.fit.inertia, "job {}", req.id);
    }
    // The fpga-sim tenant reports simulated cycles; engine tenants report
    // dispatch counters — both surfaces flow through the serve rollup.
    let sim = outcome.responses[3].report.as_ref().unwrap();
    assert!(sim.total_cycles > 0);
    let native = outcome.responses[0].report.as_ref().unwrap();
    assert!(native.tiles_dispatched > 0);
}

#[test]
fn coalesced_lockstep_batches_are_bit_identical_to_solo_fits() {
    // Deterministic batching proof (no scheduler races): drive the same
    // micro-batch executor the workers use, then compare against direct
    // coordinator runs of each member.
    let reqs: Vec<FitRequest> = (0..3)
        .map(|i| FitRequest {
            id: i as u64,
            max_points: 1200 - 200 * i,
            data_seed: 50 + i as u64,
            kmeans: KMeansConfig { k: 3 + i, seed: 5 + i as u64, ..Default::default() },
            ..Default::default()
        })
        .collect();
    let datasets: Vec<_> = reqs.iter().map(|r| r.load_dataset().unwrap()).collect();
    let pairs: Vec<(&kpynq::data::Dataset, &KMeansConfig)> =
        datasets.iter().zip(reqs.iter().map(|r| &r.kmeans)).collect();

    let batched =
        kpynq::serve::batch::fit_lockstep(&mut NativeEngine, "native", &pairs).unwrap();

    for (req, out) in reqs.iter().zip(&batched) {
        let want = direct(req);
        assert_eq!(out.fit.assignments, want.fit.assignments, "job {}", req.id);
        assert_eq!(out.fit.centroids, want.fit.centroids, "job {}", req.id);
        assert_eq!(out.fit.iterations, want.fit.iterations, "job {}", req.id);
        assert_eq!(
            out.report.tiles_dispatched, want.report.tiles_dispatched,
            "job {}",
            req.id
        );
    }
}

#[test]
fn expired_deadlines_shed_instead_of_executing() {
    let mut jobs = Vec::new();
    for id in 1..=2u64 {
        jobs.push(FitRequest {
            id,
            max_points: 600,
            kmeans: KMeansConfig { k: 3, seed: id, ..Default::default() },
            ..Default::default()
        });
    }
    jobs.push(FitRequest {
        id: 3,
        max_points: 600,
        deadline_ms: Some(0), // expired the moment it is admitted
        ..Default::default()
    });
    let outcome = Server::new(ServeConfig::default()).unwrap().run(jobs).unwrap();
    assert_eq!(outcome.responses[0].status, JobStatus::Ok);
    assert_eq!(outcome.responses[1].status, JobStatus::Ok);
    assert_eq!(outcome.responses[2].status, JobStatus::Shed);
    assert!(outcome.responses[2].detail.contains("deadline"));
    assert_eq!(outcome.report.shed, 1);
    assert_eq!(outcome.report.shed_deadline, 1);
    assert_eq!(outcome.report.completed, 2);
}

#[test]
fn a_blocked_submitter_sheds_on_deadline_instead_of_waiting_forever() {
    // The overload-clock fix: under `ShedPolicy::Block` the queue-wait
    // clock used to start only at admission, so a job whose deadline
    // expired while its submitter was parked on a full queue neither
    // shed on time nor reported the blocked wait. The clock now starts
    // at submission: the expired job sheds while the queue is *still*
    // full, and its reported wait covers the blocked time.
    let heavy = |id: u64| FitRequest {
        id,
        max_points: 8000,
        kmeans: KMeansConfig { k: 12, seed: id, ..Default::default() },
        ..Default::default()
    };
    let jobs = vec![
        heavy(1), // occupies the single worker for a long while
        heavy(2), // fills the one-slot queue behind it
        FitRequest {
            id: 3,
            max_points: 600,
            deadline_ms: Some(60), // expires while the submitter is blocked
            ..Default::default()
        },
    ];
    let outcome = Server::new(ServeConfig {
        workers: 1,
        max_batch: 1,
        queue_capacity: 1,
        ..Default::default() // Block policy
    })
    .unwrap()
    .run(jobs)
    .unwrap();
    assert_eq!(outcome.responses[0].status, JobStatus::Ok);
    assert_eq!(outcome.responses[1].status, JobStatus::Ok);
    let blocked = &outcome.responses[2];
    assert_eq!(blocked.status, JobStatus::Shed, "detail: {}", blocked.detail);
    assert!(blocked.detail.contains("deadline"), "detail: {}", blocked.detail);
    assert!(
        blocked.detail.contains("blocked"),
        "the reason names the blocked wait: {}",
        blocked.detail
    );
    assert!(
        blocked.queue_seconds >= 0.05,
        "queue-wait is measured from submission, got {}s",
        blocked.queue_seconds
    );
    assert_eq!(outcome.report.shed_deadline, 1);
    assert_eq!(outcome.report.completed, 2);
}

#[test]
fn a_flooding_tenant_is_quota_shed_while_the_light_tenant_completes() {
    // Two-tenant overload acceptance: a flooder that submits faster than
    // the pool drains takes the per-tenant quota shed; the light tenant,
    // weighted 4:1 and far under its own quota, completes everything.
    let mut weights = std::collections::BTreeMap::new();
    weights.insert("light".to_string(), 4u32);
    weights.insert("flood".to_string(), 1u32);
    let job = |id: u64, tenant: &str, pts: usize| FitRequest {
        id,
        tenant: tenant.into(),
        max_points: pts,
        kmeans: KMeansConfig { k: 4, seed: id, ..Default::default() },
        ..Default::default()
    };
    let mut jobs = Vec::new();
    for id in 1..=16 {
        jobs.push(job(id, "flood", 2000));
    }
    jobs.push(job(90, "light", 400));
    jobs.push(job(91, "light", 400));
    let outcome = Server::new(ServeConfig {
        workers: 1,
        max_batch: 1,
        shed_policy: ShedPolicy::ShedArrivals,
        tenant_weights: weights,
        tenant_queue_cap: 2,
        ..Default::default()
    })
    .unwrap()
    .run(jobs)
    .unwrap();

    let light: Vec<_> = outcome.responses.iter().filter(|r| r.tenant == "light").collect();
    assert_eq!(light.len(), 2);
    for r in &light {
        assert_eq!(r.status, JobStatus::Ok, "light job {}: {}", r.id, r.detail);
    }
    let flood_shed: Vec<_> = outcome
        .responses
        .iter()
        .filter(|r| r.tenant == "flood" && r.status == JobStatus::Shed)
        .collect();
    assert!(!flood_shed.is_empty(), "a 16-deep flood against a 2-slot quota must shed");
    for r in &flood_shed {
        assert_eq!(r.detail, "tenant queue quota exceeded", "flood job {}", r.id);
    }
    let flood_ok =
        outcome.responses.iter().filter(|r| r.tenant == "flood" && r.status == JobStatus::Ok);
    assert_eq!(
        flood_ok.count() + flood_shed.len(),
        16,
        "every flood job answers exactly once, ok or shed"
    );
    assert_eq!(outcome.report.completed as usize, 18 - flood_shed.len());
    assert_eq!(outcome.report.shed as usize, flood_shed.len());
}

#[test]
fn protocol_edge_lines_fail_loudly_without_panicking() {
    // Table of malformed NDJSON job lines (the parser half of PROTOCOL.md
    // §5's error-reply contract; the wire half lives in serve_net.rs).
    // Every entry must produce an Err — never a panic, never a silently
    // defaulted job — and mention the offending fragment.
    let cases: Vec<(&str, &str)> = vec![
        ("", "unexpected character"),
        ("not json at all", "invalid literal"),
        (r#"{"id": 1,}"#, "expected"),
        (r#"{"id": 1"#, "expected"),
        (r#"[{"id": 1}]"#, "must be a JSON object"),
        (r#""just a string""#, "must be a JSON object"),
        (r#"{"dataset": "blobs"}"#, "missing key 'id'"),
        (r#"{"id": -3}"#, "expected non-negative integer"),
        (r#"{"id": 1.5}"#, "expected non-negative integer"),
        (r#"{"id": 1, "k": "many"}"#, "expected number"),
        (r#"{"id": 1, "deadline_ms": -20}"#, "expected non-negative integer"),
        (r#"{"id": 1, "unknown_field": true}"#, "unknown job key"),
        (r#"{"id": 1, "backend": "tpu"}"#, "unknown backend"),
        (r#"{"id": 1, "normalize": "sigmoid"}"#, "unknown normalize"),
        (r#"{"id": 1, "priority": "asap"}"#, "unknown priority"),
        (r#"{"id": 1} {"id": 2}"#, "trailing characters"),
    ];
    for (line, expect) in cases {
        let err = FitRequest::from_json_line(line)
            .expect_err(&format!("line {line:?} must be rejected"));
        let msg = err.to_string();
        assert!(msg.contains(expect), "line {line:?}: got {msg:?}, wanted {expect:?}");
    }
}

#[test]
fn response_ndjson_surface_round_trips() {
    let jobs = vec![FitRequest {
        id: 9,
        max_points: 600,
        kmeans: KMeansConfig { k: 3, seed: 1, ..Default::default() },
        ..Default::default()
    }];
    let outcome = Server::new(ServeConfig::default()).unwrap().run(jobs).unwrap();
    let resp = &outcome.responses[0];
    let line = resp.to_json().to_string();
    let parsed = Json::parse(&line).unwrap();
    assert_eq!(parsed.get("id").unwrap().as_usize().unwrap(), 9);
    assert_eq!(parsed.get("status").unwrap().as_str().unwrap(), "ok");
    // The checksum on the wire matches the in-memory clustering.
    let fit = resp.fit.as_ref().unwrap();
    assert_eq!(
        parsed.get("assignments_fnv").unwrap().as_str().unwrap(),
        format!("{:016x}", assignments_checksum(&fit.assignments))
    );
    assert_eq!(
        parsed.get("iterations").unwrap().as_usize().unwrap(),
        fit.iterations
    );
}
