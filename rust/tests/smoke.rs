//! Smoke test: the crate's headline contract on one small, fast instance.
//!
//! `kmeans/mod.rs` promises that every filtered algorithm is *exact*: given
//! the same initialisation it produces the same assignments and centroids
//! as Lloyd's algorithm at every iteration. This file checks that contract
//! for the paper's algorithm (yinyang) on a small synthetic blob dataset —
//! deliberately minimal so it runs in well under a second and fails first
//! (and loudest) if the workspace is miswired. The exhaustive
//! random-instance versions live in `equivalence.rs`.

use kpynq::data::synth;
use kpynq::kmeans::{self, init, Algorithm, KMeansConfig};

#[test]
fn yinyang_matches_lloyd_on_small_blobs() {
    let ds = synth::blobs(400, 8, 4, 0xBEEF);
    let cfg = KMeansConfig { k: 4, seed: 7, ..Default::default() };

    let c0 = init::initialize(&ds, &cfg).unwrap();
    let lloyd = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
    let yinyang = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0).unwrap();

    // The exactness contract: identical trajectory, not merely similar
    // quality.
    assert_eq!(lloyd.assignments, yinyang.assignments, "assignments must be identical");
    assert_eq!(lloyd.centroids, yinyang.centroids, "centroids must be identical");
    assert_eq!(lloyd.iterations, yinyang.iterations, "iteration counts must match");
    assert!(lloyd.converged && yinyang.converged, "easy blobs must converge");

    // And the whole point of the filter: strictly less distance work than
    // the n·k·iters yardstick (the first full-scan iteration is shared).
    assert!(
        yinyang.stats.total_dist_comps() < lloyd.stats.total_dist_comps(),
        "yinyang did {} distance comps, lloyd {}",
        yinyang.stats.total_dist_comps(),
        lloyd.stats.total_dist_comps()
    );
}

#[test]
fn smoke_covers_both_init_methods() {
    use kpynq::kmeans::InitMethod;
    let ds = synth::blobs(200, 5, 3, 0xF00D);
    for init_method in [InitMethod::KMeansPlusPlus, InitMethod::RandomPoints] {
        let cfg = KMeansConfig { k: 3, seed: 11, init: init_method, ..Default::default() };
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let y = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0).unwrap();
        assert_eq!(l.assignments, y.assignments, "{init_method:?}");
    }
}
