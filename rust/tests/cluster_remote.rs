//! Chaos tests for the remote-shards cluster mode (`remote_shards` /
//! `--remote`): a front door attached to *unsupervised* daemons, with
//! every failure mode scripted deterministically by the
//! `support/fake_shard.rs` harness — no child processes, no signals.
//!
//! The acceptance claims (ISSUE 5 / DESIGN.md §2):
//!
//! * a 2-remote-shard cluster returns **bit-identical** replies —
//!   PROTOCOL.md §8 FNV fingerprints included — to a single daemon,
//!   which in turn matches direct engine runs;
//! * a remote link lost mid-reply is survivable: the front reconnects
//!   under the shared `ReconnectPolicy`, requeues the link's unanswered
//!   tickets, and the external client still receives every reply exactly
//!   once;
//! * a permanently dead remote (reconnects refused) is abandoned and its
//!   tickets are re-homed to the survivors;
//! * a stalled link (socket open, nothing answered) trips the hung-link
//!   watchdog into the same recovery path;
//! * framing poison (a garbled frame) reads as link loss; stray replies
//!   under unknown wire ids are ignored without drama.
//!
//! The map-reduce additions (ISSUE 6 / PROTOCOL.md §10): a single fit
//! sliced across remote shards by [`MapReduceFit`] must stay
//! **bit-identical** to the solo in-process fit even when a shard stalls
//! mid-reduction (straggler watchdog), tears a `centroid_sync` reply, or
//! dies mid-iteration and is re-dispatched with the §10 `history`
//! replay — and a fit whose shard keeps dying must fail loudly once the
//! re-dispatch budget runs out, never return a wrong answer.

#[allow(dead_code)]
#[path = "support/fake_shard.rs"]
mod fake_shard;

use std::collections::BTreeMap;
use std::time::Duration;

use fake_shard::{FakeShard, Fault};
use kpynq::cluster::{
    Cluster, ClusterConfig, ClusterHandle, ClientConn, FitMode, MapReduceFit, ReconnectPolicy,
};
use kpynq::coordinator::{KpynqSystem, SystemConfig, SystemOutput};
use kpynq::kmeans::{self, Algorithm, FitResult, KMeansConfig};
use kpynq::serve::job::assignments_checksum;
use kpynq::serve::net::{Daemon, NetConfig};
use kpynq::serve::{FitRequest, FitResponse, JobStatus, ServeConfig, ServeReport};

/// Generous safety net: nothing here should take anywhere near this
/// long, but a wedged cluster must fail the test, not hang CI.
const TEST_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// A reconnect shape tuned for tests: quick retries, sub-second budget —
/// a refused remote is abandoned in well under a second.
fn fast_reconnect() -> ReconnectPolicy {
    ReconnectPolicy {
        attempts: 5,
        base_delay: Duration::from_millis(5),
        max_delay: Duration::from_millis(50),
        total_wait: Duration::from_secs(2),
    }
}

fn start_remote_cluster_with(
    addrs: Vec<String>,
    health_timeout: Duration,
    max_restarts: u32,
) -> (String, ClusterHandle, std::thread::JoinHandle<ServeReport>) {
    let cfg = ClusterConfig {
        remote_shards: addrs,
        reconnect: fast_reconnect(),
        health_timeout,
        max_restarts,
        serve: ServeConfig { workers: 1, ..Default::default() },
        ..Default::default()
    };
    let cluster =
        Cluster::start("127.0.0.1:0", NetConfig::default(), cfg).expect("remote cluster start");
    let addr = cluster.local_addr();
    let handle = cluster.handle();
    let thread = std::thread::spawn(move || cluster.run().expect("cluster run"));
    (addr, handle, thread)
}

fn start_remote_cluster(
    addrs: Vec<String>,
    health_timeout: Duration,
) -> (String, ClusterHandle, std::thread::JoinHandle<ServeReport>) {
    start_remote_cluster_with(addrs, health_timeout, 3)
}

fn connect(addr: &str) -> ClientConn {
    let c = ClientConn::connect(addr).expect("connect");
    c.set_read_timeout(Some(TEST_READ_TIMEOUT)).expect("set timeout");
    c
}

fn job(id: u64, dataset: &str, data_seed: u64, k: usize, seed: u64) -> FitRequest {
    FitRequest {
        id,
        dataset: dataset.into(),
        data_seed,
        max_points: 500,
        kmeans: kpynq::kmeans::KMeansConfig { k, seed, ..Default::default() },
        ..Default::default()
    }
}

/// The ground truth: the same request straight through the coordinator —
/// no serving, no socket, no cluster.
fn direct(req: &FitRequest) -> SystemOutput {
    let rc = req.to_run_config().unwrap();
    let ds = rc.load_dataset().unwrap();
    KpynqSystem::new(SystemConfig { backend: rc.backend(), verify: false })
        .unwrap()
        .cluster(&ds, &req.kmeans)
        .unwrap()
}

fn collect_by_id(c: &mut ClientConn, n: usize) -> BTreeMap<u64, FitResponse> {
    let mut by_id = BTreeMap::new();
    for _ in 0..n {
        let r = c.recv_response().expect("response");
        assert!(
            by_id.insert(r.id, r).is_none(),
            "duplicate reply for one id: exactly-once delivery is broken"
        );
    }
    by_id
}

fn assert_all_ok_and_bit_identical(jobs: &[FitRequest], replies: &BTreeMap<u64, FitResponse>) {
    for j in jobs {
        let r = &replies[&j.id];
        assert_eq!(r.status, JobStatus::Ok, "job {}: {}", j.id, r.detail);
        let want = direct(j);
        let s = r.summary.expect("ok replies carry a summary");
        assert_eq!(
            s.assignments_fnv,
            assignments_checksum(&want.fit.assignments),
            "job {} fingerprint must match a direct fit even across faults/requeues",
            j.id
        );
        assert_eq!(s.inertia, want.fit.inertia, "job {} inertia", j.id);
        assert_eq!(s.iterations, want.fit.iterations, "job {} iterations", j.id);
    }
}

#[test]
fn two_remote_shard_cluster_matches_single_daemon_and_direct_runs() {
    // A job mix spanning two BatchKeys (blobs d=16, kegg d=20) so the
    // router spreads work across both remotes.
    let jobs: Vec<FitRequest> = vec![
        job(1, "blobs", 100, 3, 41),
        job(2, "blobs", 101, 4, 42),
        job(3, "kegg", 102, 5, 43),
        job(4, "blobs", 103, 3, 44),
        job(5, "kegg", 104, 4, 45),
        job(6, "blobs", 105, 5, 46),
    ];

    // Reference: one plain in-process daemon.
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        NetConfig::default(),
        ServeConfig { workers: 2, ..Default::default() },
    )
    .expect("daemon bind");
    let daemon_addr = daemon.local_addr();
    let daemon_handle = daemon.handle();
    let daemon_thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
    let mut dc = connect(&daemon_addr);
    for j in &jobs {
        dc.submit(j).unwrap();
    }
    let daemon_replies = collect_by_id(&mut dc, jobs.len());
    daemon_handle.shutdown();
    daemon_thread.join().unwrap();

    // The system under test: a front attached to two remote doubles.
    let a = FakeShard::start(vec![]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) = start_remote_cluster(
        vec![a.addr(), b.addr()],
        Duration::from_secs(30),
    );
    let mut cc = connect(&addr);
    let g = cc.greeting();
    assert_eq!(g.get("shards").unwrap().as_usize().unwrap(), 2);
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let cluster_replies = collect_by_id(&mut cc, jobs.len());

    assert_all_ok_and_bit_identical(&jobs, &cluster_replies);
    for j in &jobs {
        assert_eq!(
            daemon_replies[&j.id].summary.unwrap().assignments_fnv,
            cluster_replies[&j.id].summary.unwrap().assignments_fnv,
            "job {}: single daemon and remote cluster disagree",
            j.id
        );
    }

    let stats = cc.stats().unwrap();
    assert_eq!(stats.submitted, jobs.len() as u64);
    assert_eq!(stats.queue_depth, 0, "everything answered");

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.submitted, jobs.len() as u64);
    assert_eq!(report.completed, jobs.len() as u64);
    assert_eq!(report.shard_restarts, 0, "no faults were scripted");
    assert_eq!(report.dropped_replies, 0);
    assert_eq!(
        a.answered() + b.answered(),
        jobs.len() as u64,
        "every job ran on exactly one remote"
    );
}

#[test]
fn front_scrape_merges_every_shard_registry_labeled_by_shard() {
    // PROTOCOL.md §11 fleet aggregation: one `GET /metrics` on the
    // front's scrape endpoint answers Prometheus text 0.0.4 holding the
    // front's own registry (`shard="front"`) *and* every live shard's
    // registry (`shard="0"`, `shard="1"`), scraped over the job links.
    use std::io::{Read, Write};
    let a = FakeShard::start(vec![]);
    let b = FakeShard::start(vec![]);
    let cfg = ClusterConfig {
        remote_shards: vec![a.addr(), b.addr()],
        reconnect: fast_reconnect(),
        health_timeout: Duration::from_secs(30),
        serve: ServeConfig { workers: 1, ..Default::default() },
        ..Default::default()
    };
    let cluster = Cluster::start(
        "127.0.0.1:0",
        NetConfig { metrics_listen: Some("127.0.0.1:0".into()), ..Default::default() },
        cfg,
    )
    .expect("remote cluster start");
    let addr = cluster.local_addr();
    let maddr = cluster.metrics_addr().expect("front scrape endpoint bound");
    let handle = cluster.handle();
    let thread = std::thread::spawn(move || cluster.run().expect("cluster run"));

    // Run real traffic so shard registries carry answered-job series.
    let jobs = vec![job(1, "blobs", 100, 3, 41), job(2, "kegg", 102, 4, 43)];
    let mut cc = connect(&addr);
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    let mut s = std::net::TcpStream::connect(&maddr).expect("connect scrape");
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n").expect("write scrape");
    let mut scrape = String::new();
    s.read_to_string(&mut scrape).expect("read scrape");
    assert!(scrape.starts_with("HTTP/1.1 200 OK\r\n"), "scrape status:\n{scrape}");
    assert!(
        scrape.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n"),
        "scrape content type:\n{scrape}"
    );
    let body = scrape.split("\r\n\r\n").nth(1).expect("scrape body");
    // The front's own series, relabeled as the "front" shard.
    assert!(
        body.contains("cluster_jobs_submitted{shard=\"front\"} 2"),
        "front series missing:\n{body}"
    );
    // Every live shard's registry, labeled by its index: the two jobs
    // land somewhere, but both shards report their submitted counter
    // (an idle shard's counters exist at zero).
    for shard in ["0", "1"] {
        assert!(
            body.contains(&format!("serve_jobs_submitted{{shard=\"{shard}\"}}")),
            "shard {shard} series missing:\n{body}"
        );
    }
    // No sample line escapes the per-shard labeling: every non-comment
    // line in a fleet scrape names its origin.
    for line in body.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        assert!(line.contains("shard=\""), "unlabeled fleet series: {line}");
    }

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.completed, jobs.len() as u64);
    assert_eq!(
        a.answered() + b.answered(),
        jobs.len() as u64,
        "every job ran on exactly one remote"
    );
}

#[test]
fn client_trace_id_survives_the_remote_round_trip_byte_identically() {
    // PROTOCOL.md §11: a client-supplied `trace_id` rides the forwarded
    // frame to the remote shard, comes back on the shard's reply, and is
    // handed to the external client unmodified — byte for byte. The
    // front's span ring must hold the admit→dispatch→reply chain for
    // exactly that id.
    let a = FakeShard::start(vec![]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr(), b.addr()], Duration::from_secs(30));
    let mut cc = connect(&addr);

    let mut traced = job(1, "blobs", 210, 3, 55);
    traced.trace_id = "00deadbeefcafe11".into();
    let plain = job(2, "blobs", 211, 4, 56);
    cc.submit(&traced).unwrap();
    cc.submit(&plain).unwrap();
    let replies = collect_by_id(&mut cc, 2);
    assert_all_ok_and_bit_identical(&[traced.clone(), plain], &replies);
    assert_eq!(
        replies[&1].trace_id, traced.trace_id,
        "the client's trace_id must survive front→shard→front unmodified"
    );

    let drained = cc.drain_trace().expect("trace drain");
    let events = drained.get("events").unwrap().as_arr().unwrap();
    let chain: Vec<&str> = events
        .iter()
        .filter(|e| {
            e.get("trace_id").and_then(|v| v.as_str()).map(str::to_owned).ok()
                == Some(traced.trace_id.clone())
        })
        .map(|e| e.get("event").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(
        chain,
        vec!["admit", "dispatch", "reply"],
        "one span chain at the front under the client's trace_id"
    );

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 2);
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn link_dropped_mid_reply_reconnects_with_exactly_once_replies() {
    // Shard 0's first connection answers one job, then severs the socket
    // halfway through the next reply; its second connection (the front's
    // reconnect) behaves. Same BatchKey throughout ⇒ the stream pins to
    // shard 0, so the fault lands on the busiest link.
    let a = FakeShard::start(vec![Fault::DropMidReply { after: 1 }]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr(), b.addr()], Duration::from_secs(30));
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=8).map(|i| job(i, "blobs", 200 + i, 3 + (i as usize % 3), 50 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    // The cluster is fully serviceable after the reconnect.
    assert_eq!(cc.ping().unwrap(), kpynq::serve::net::PROTO_VERSION);
    let post = job(99, "blobs", 999, 4, 99);
    cc.submit(&post).unwrap();
    let r = cc.recv_response().unwrap();
    assert_eq!((r.id, r.status), (99, JobStatus::Ok), "{}", r.detail);

    handle.shutdown();
    let report = thread.join().unwrap();
    assert!(report.shard_restarts >= 1, "the dropped link was re-dialed");
    assert_eq!(report.submitted, jobs.len() as u64 + 1);
    assert_eq!(report.completed, jobs.len() as u64 + 1, "every job answered exactly once");
    assert_eq!(report.dropped_replies, 0);
    assert!(a.accepted() >= 2, "shard 0 saw the original link and the reconnect");
}

#[test]
fn permanently_dead_remote_is_abandoned_and_tickets_rehome_to_survivors() {
    // Shard 0 tears its first connection down on the first job and then
    // refuses every reconnect (accept + instant close) — the
    // "daemon host went away for good" script. Its unanswered tickets
    // must re-home to shard 1 and be answered exactly once.
    let a = FakeShard::start(vec![Fault::DropMidReply { after: 0 }]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr(), b.addr()], Duration::from_secs(30));
    a.refuse_new_conns(); // future dials fail; the link already up stays up
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=6).map(|i| job(i, "blobs", 300 + i, 3 + (i as usize % 2), 70 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    assert_eq!(a.answered(), 0, "shard 0 never completed a reply");
    assert_eq!(b.answered(), jobs.len() as u64, "the survivor answered everything");

    // The abandoned shard is routed around, not resurrected: new work
    // still flows through the survivor.
    let post = job(50, "blobs", 888, 3, 88);
    cc.submit(&post).unwrap();
    let r = cc.recv_response().unwrap();
    assert_eq!((r.id, r.status), (50, JobStatus::Ok), "{}", r.detail);

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.completed, jobs.len() as u64 + 1, "exactly once despite the re-homing");
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn stalled_link_trips_the_watchdog_into_reconnect_and_requeue() {
    // Shard 0 goes silent on its first job with the socket held open —
    // the failure EOF detection cannot see. A short health timeout lets
    // the watchdog force the link closed; recovery then reconnects (the
    // fake's second connection behaves) and requeues everything.
    let a = FakeShard::start(vec![Fault::Stall {
        after: 0,
        dead_air: Duration::from_secs(20),
    }]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr(), b.addr()], Duration::from_millis(1_500));
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=5).map(|i| job(i, "blobs", 400 + i, 3, 90 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    handle.shutdown();
    let report = thread.join().unwrap();
    assert!(report.shard_restarts >= 1, "the watchdog re-dialed the stalled link");
    assert_eq!(report.completed, jobs.len() as u64);
    assert_eq!(report.dropped_replies, 0);
    assert!(a.accepted() >= 2, "the stalled connection was replaced");
}

#[test]
fn wedged_forever_remote_exhausts_its_budget_and_rehomes_to_the_survivor() {
    // Shard 0 is wedged-but-reachable: every connection greets, then
    // stalls on its first job. Because remote reconnects always consume
    // budget (re-dialing cannot heal the peer — see cluster::remote),
    // the watchdog cycle must converge: force-close → reconnect (1/1) →
    // stall again → force-close → budget exhausted → abandoned, with
    // every ticket re-homed to shard 1 and answered exactly once. With
    // the supervisor's budget-free kill rule this would livelock
    // forever, which is exactly the asymmetry under test.
    let wedged = Fault::Stall { after: 0, dead_air: Duration::from_secs(60) };
    let a = FakeShard::start(vec![wedged, wedged, wedged]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) = start_remote_cluster_with(
        vec![a.addr(), b.addr()],
        Duration::from_millis(1_200),
        1, // one reconnect, then abandonment
    );
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=4).map(|i| job(i, "blobs", 800 + i, 3, 150 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    assert_eq!(a.answered(), 0, "the wedged shard never completed a reply");
    assert_eq!(b.answered(), jobs.len() as u64, "the survivor answered everything");

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.shard_restarts, 1, "exactly the budgeted reconnect, then abandonment");
    assert_eq!(report.completed, jobs.len() as u64);
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn garbled_frame_reads_as_link_loss_and_recovery_keeps_exactly_once() {
    // A peer that emits non-protocol bytes cannot be resynced; the link
    // reader must treat the stream as poisoned (link down), and recovery
    // must still deliver every reply exactly once.
    let a = FakeShard::start(vec![Fault::GarbleReply { after: 0 }]);
    let b = FakeShard::start(vec![]);
    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr(), b.addr()], Duration::from_secs(30));
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=4).map(|i| job(i, "blobs", 500 + i, 3, 110 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    handle.shutdown();
    let report = thread.join().unwrap();
    assert!(report.shard_restarts >= 1, "framing poison must be treated as link loss");
    assert_eq!(report.completed, jobs.len() as u64);
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn stale_wire_id_replies_are_ignored_without_drama() {
    // A stray reply under a wire id nobody submitted must be dropped on
    // the floor: no crash, no mis-delivery, no spurious reconnect.
    let a = FakeShard::start(vec![Fault::StaleWireId { after: 0 }]);
    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr()], Duration::from_secs(30));
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=3).map(|i| job(i, "blobs", 600 + i, 3, 130 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    assert_all_ok_and_bit_identical(&jobs, &replies);

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.shard_restarts, 0, "a stray reply is noise, not a link failure");
    assert_eq!(report.completed, jobs.len() as u64);
}

#[test]
fn refused_handshake_is_retried_until_the_peer_speaks_revision_one() {
    // The fake's first two connections greet with protocol revision 99 —
    // the §2 version-skew refusal. A single connect fails with a revision
    // error (consuming fault one); the cluster's backoff loop eats fault
    // two and lands on the third (conforming) connection, so startup
    // still succeeds.
    let a = FakeShard::start(vec![Fault::RefuseHandshake, Fault::RefuseHandshake]);
    let err = ClientConn::connect(&a.addr()).unwrap_err().to_string();
    assert!(err.contains("protocol revision"), "{err}");

    let (addr, handle, thread) =
        start_remote_cluster(vec![a.addr()], Duration::from_secs(30));
    let mut cc = connect(&addr);
    let probe = job(1, "blobs", 700, 3, 140);
    cc.submit(&probe).unwrap();
    let r = cc.recv_response().unwrap();
    assert_eq!((r.id, r.status), (1, JobStatus::Ok), "{}", r.detail);
    assert!(a.accepted() >= 3, "refused greeting, cluster retry, then the front link");

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 1);
}

// ---------------------------------------------------------------------------
// Map-reduce mode (PROTOCOL.md §10): one fit's *points* sliced across the
// remotes, reduced each epoch, provably bit-identical to the solo fit even
// under scripted shard faults.
// ---------------------------------------------------------------------------

/// A map-reduce-sized job: small enough that every §10 frame (exact sums
/// at 160 hex chars per value, the slice's assignment vector) fits under
/// the 64 KiB line cap with lots of headroom.
fn mr_job(id: u64, data_seed: u64, k: usize, seed: u64) -> FitRequest {
    FitRequest {
        id,
        dataset: "blobs".into(),
        data_seed,
        max_points: 400,
        kmeans: KMeansConfig { k, seed, max_iters: 20, ..Default::default() },
        ..Default::default()
    }
}

/// The map-reduce ground truth: the same request fit solo, in process —
/// the exact run every sliced fit must reproduce bit for bit.
fn solo_fit(req: &FitRequest, algo: Algorithm) -> FitResult {
    let ds = req.to_run_config().unwrap().load_dataset().unwrap();
    kmeans::fit(algo, &ds, &req.kmeans).unwrap()
}

/// A wire driver tuned for tests: quick reconnects, generous watchdog
/// (individual tests shrink `shard_timeout` when the watchdog itself is
/// under test).
fn mapreduce(req: FitRequest, addrs: Vec<String>) -> MapReduceFit {
    let mut mr = MapReduceFit::new(req, addrs);
    mr.reconnect = fast_reconnect();
    mr.shard_timeout = Duration::from_secs(30);
    mr
}

fn assert_fit_bit_identical(tag: &str, solo: &FitResult, got: &FitResult) {
    assert_eq!(got.assignments, solo.assignments, "{tag}: assignments diverged");
    let solo_bits: Vec<u32> = solo.centroids.as_slice().iter().map(|v| v.to_bits()).collect();
    let got_bits: Vec<u32> = got.centroids.as_slice().iter().map(|v| v.to_bits()).collect();
    assert_eq!(got_bits, solo_bits, "{tag}: centroid bits diverged");
    assert_eq!(got.inertia.to_bits(), solo.inertia.to_bits(), "{tag}: inertia bits diverged");
    assert_eq!(got.iterations, solo.iterations, "{tag}: iteration count diverged");
    assert_eq!(got.converged, solo.converged, "{tag}: converged flag diverged");
    assert_eq!(
        assignments_checksum(&got.assignments),
        assignments_checksum(&solo.assignments),
        "{tag}: FNV fingerprint diverged"
    );
}

#[test]
fn map_reduce_over_the_wire_matches_the_solo_fit() {
    // No faults: the pure wire path — partial_fit fan-out, per-epoch
    // reduction, centroid_sync rebroadcast, done seal — against two
    // remote doubles running the real partial computations.
    let a = FakeShard::start(vec![]);
    let b = FakeShard::start(vec![]);
    let req = mr_job(1, 900, 4, 61);
    let solo = solo_fit(&req, Algorithm::Yinyang);
    let fit = mapreduce(req, vec![a.addr(), b.addr()]).run().expect("map-reduce fit");
    assert_fit_bit_identical("clean wire run", &solo, &fit);
    assert_eq!(a.accepted(), 1);
    assert_eq!(b.accepted(), 1);
}

#[test]
fn stalled_partial_trips_the_straggler_watchdog_and_recovery_is_bit_identical() {
    // Shard 0 goes silent before its epoch-1 partial with the socket held
    // open — dead air EOF detection cannot see. A short shard_timeout
    // lets the straggler watchdog force the link closed; the re-dispatch
    // replays (an empty) history on a fresh connection and the fit must
    // come out bit-identical anyway.
    let a = FakeShard::start(vec![Fault::StallPartial {
        at_epoch: 1,
        dead_air: Duration::from_secs(20),
    }]);
    let b = FakeShard::start(vec![]);
    let req = mr_job(2, 910, 4, 71);
    let solo = solo_fit(&req, Algorithm::Yinyang);
    let mut mr = mapreduce(req, vec![a.addr(), b.addr()]);
    mr.shard_timeout = Duration::from_millis(750);
    let fit = mr.run().expect("map-reduce fit survives a stalled reducer");
    assert_fit_bit_identical("stalled reducer epoch", &solo, &fit);
    assert!(a.accepted() >= 2, "the stalled link was force-closed and re-dialed");
}

#[test]
fn shard_death_mid_iteration_is_redispatched_with_history_replay() {
    // Shard 0 computes its epoch-2 partial and severs the socket instead
    // of answering — death *mid-fit*, after real reduction state existed.
    // The replacement connection starts from nothing, so recovery must
    // replay the §10 history (c_1) to land on exactly the epoch the dead
    // incarnation held. Replay is deterministic, hence idempotent, hence
    // the bits must not move.
    let a = FakeShard::start(vec![Fault::DieAtEpoch { at_epoch: 2 }]);
    let b = FakeShard::start(vec![]);
    let req = mr_job(3, 920, 5, 81);
    let solo = solo_fit(&req, Algorithm::Yinyang);
    assert!(
        solo.iterations >= 2,
        "the scripted death needs an epoch 2 — pick a different data_seed/seed"
    );
    let fit = mapreduce(req, vec![a.addr(), b.addr()])
        .run()
        .expect("map-reduce fit survives shard death");
    assert_fit_bit_identical("shard death at epoch 2", &solo, &fit);
    assert!(a.accepted() >= 2, "the dead shard's slice was re-dispatched");
}

#[test]
fn torn_centroid_sync_reply_is_recovered_bit_identically() {
    // Shard 0 answers the epoch-1 centroid_sync with half a reply line
    // and severs — a torn frame mid-barrier. The front must read the
    // truncated stream as link loss and re-dispatch with history.
    let a = FakeShard::start(vec![Fault::TearSync { at_epoch: 1 }]);
    let b = FakeShard::start(vec![]);
    let req = mr_job(4, 930, 4, 91);
    let solo = solo_fit(&req, Algorithm::Yinyang);
    let fit = mapreduce(req, vec![a.addr(), b.addr()])
        .run()
        .expect("map-reduce fit survives a torn sync reply");
    assert_fit_bit_identical("torn centroid_sync", &solo, &fit);
    assert!(a.accepted() >= 2, "the torn link was replaced");
}

#[test]
fn exhausted_redispatch_budget_fails_the_fit_loudly() {
    // Every connection to shard 0 dies at epoch 1 — original plus both
    // budgeted re-dispatches. A fit that cannot be completed must error,
    // never return a partial (and therefore wrong) answer.
    let die = Fault::DieAtEpoch { at_epoch: 1 };
    let a = FakeShard::start(vec![die, die, die]);
    let b = FakeShard::start(vec![]);
    let mut mr = mapreduce(mr_job(5, 940, 3, 101), vec![a.addr(), b.addr()]);
    mr.redispatch_budget = 2;
    let err = mr.run().unwrap_err().to_string();
    assert!(err.contains("re-dispatch budget exhausted"), "{err}");
    assert_eq!(a.accepted(), 3, "original connection plus exactly the budgeted re-dials");
}

#[test]
fn cluster_in_map_reduce_mode_answers_over_the_wire_bit_identically() {
    // The full stack: external client → cluster front with
    // `fit_mode = map-reduce` → every job sliced across both remote
    // doubles — §4 replies must carry the solo fit's fingerprint,
    // inertia and iteration count.
    let a = FakeShard::start(vec![]);
    let b = FakeShard::start(vec![]);
    let cfg = ClusterConfig {
        remote_shards: vec![a.addr(), b.addr()],
        reconnect: fast_reconnect(),
        health_timeout: Duration::from_secs(30),
        max_restarts: 3,
        fit_mode: FitMode::MapReduce,
        serve: ServeConfig { workers: 1, ..Default::default() },
        ..Default::default()
    };
    let cluster =
        Cluster::start("127.0.0.1:0", NetConfig::default(), cfg).expect("map-reduce cluster start");
    let addr = cluster.local_addr();
    let handle = cluster.handle();
    let thread = std::thread::spawn(move || cluster.run().expect("cluster run"));
    let mut cc = connect(&addr);

    let jobs: Vec<FitRequest> =
        (1..=3).map(|i| mr_job(i, 950 + i, 3 + (i as usize % 2), 170 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let replies = collect_by_id(&mut cc, jobs.len());
    for j in &jobs {
        let r = &replies[&j.id];
        assert_eq!(r.status, JobStatus::Ok, "job {}: {}", j.id, r.detail);
        let want = solo_fit(j, Algorithm::Yinyang);
        let s = r.summary.expect("ok replies carry a summary");
        assert_eq!(
            s.assignments_fnv,
            assignments_checksum(&want.assignments),
            "job {}: a sliced fit must carry the solo fingerprint",
            j.id
        );
        assert_eq!(s.inertia, want.inertia, "job {} inertia", j.id);
        assert_eq!(s.iterations, want.iterations, "job {} iterations", j.id);
    }

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.submitted, jobs.len() as u64);
    assert_eq!(report.completed, jobs.len() as u64, "every sliced fit answered exactly once");
    assert_eq!(report.dropped_replies, 0);
}
