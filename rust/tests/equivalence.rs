//! Cross-algorithm exactness: the core correctness property of the paper's
//! filter family. Every triangle-inequality algorithm must reproduce
//! Lloyd's trajectory exactly — same assignments, same centroids, same
//! iteration count — on arbitrary data, differing only in how much work it
//! skipped. Random-instance property tests via the in-crate driver.

use kpynq::data::Dataset;
use kpynq::hw::{AccelConfig, Accelerator};
use kpynq::kmeans::{self, init, Algorithm, InitMethod, KMeansConfig};
use kpynq::util::matrix::Matrix;
use kpynq::util::proptest::{run_cases, run_cases_n, small_instance};
use kpynq::util::rng::Rng;

fn make_dataset(rng: &mut Rng) -> (Dataset, KMeansConfig) {
    let (pts, n, d, k) = small_instance(rng);
    let ds = Dataset::new("prop", Matrix::from_vec(pts, n, d).unwrap());
    let groups = 1 + rng.next_below(k);
    let cfg = KMeansConfig {
        k,
        groups,
        max_iters: 25,
        tol: 1e-5,
        seed: rng.next_u64(),
        init: if rng.next_below(2) == 0 {
            InitMethod::KMeansPlusPlus
        } else {
            InitMethod::RandomPoints
        },
    };
    (ds, cfg)
}

/// Compare two fits allowing only genuine float near-ties to differ.
fn assert_equivalent(name: &str, a: &kmeans::FitResult, b: &kmeans::FitResult) -> Result<(), String> {
    if a.iterations != b.iterations {
        return Err(format!("{name}: iterations {} vs {}", a.iterations, b.iterations));
    }
    if a.assignments != b.assignments {
        let diff = a
            .assignments
            .iter()
            .zip(&b.assignments)
            .filter(|(x, y)| x != y)
            .count();
        return Err(format!("{name}: {diff} assignment mismatches"));
    }
    if a.centroids != b.centroids {
        return Err(format!("{name}: centroid mismatch"));
    }
    Ok(())
}

#[test]
fn hamerly_equals_lloyd_on_random_instances() {
    run_cases("hamerly == lloyd", 0xA11CE, |rng| {
        let (ds, cfg) = make_dataset(rng);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let h = kmeans::fit_from(Algorithm::Hamerly, &ds, &cfg, c0).unwrap();
        assert_equivalent("hamerly", &l, &h)
    });
}

#[test]
fn elkan_equals_lloyd_on_random_instances() {
    run_cases("elkan == lloyd", 0xB0B, |rng| {
        let (ds, cfg) = make_dataset(rng);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let e = kmeans::fit_from(Algorithm::Elkan, &ds, &cfg, c0).unwrap();
        assert_equivalent("elkan", &l, &e)
    });
}

#[test]
fn yinyang_equals_lloyd_on_random_instances() {
    run_cases("yinyang == lloyd", 0xCAFE, |rng| {
        let (ds, cfg) = make_dataset(rng);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let y = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0).unwrap();
        assert_equivalent("yinyang", &l, &y)
    });
}

#[test]
fn accelerator_equals_software_yinyang_on_random_instances() {
    // Fewer cases: each runs a full simulated fit.
    run_cases_n("accel == yinyang", 0xD00D, 40, |rng| {
        let (ds, cfg) = make_dataset(rng);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let sw = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0.clone()).unwrap();
        let hw = Accelerator::new(AccelConfig::default())
            .run_fit(&ds, &cfg, c0)
            .map_err(|e| e.to_string())?;
        assert_equivalent("accelerator", &sw, &hw.fit)?;
        if sw.stats.total_dist_comps() != hw.fit.stats.total_dist_comps() {
            return Err(format!(
                "work mismatch: sw {} vs hw {}",
                sw.stats.total_dist_comps(),
                hw.fit.stats.total_dist_comps()
            ));
        }
        Ok(())
    });
}

#[test]
fn coordinator_native_equals_lloyd_on_random_instances() {
    use kpynq::coordinator::driver::run_with_engine;
    use kpynq::runtime::native::NativeEngine;
    run_cases_n("coordinator == lloyd", 0xFEED, 40, |rng| {
        let (ds, cfg) = make_dataset(rng);
        let l = kmeans::fit(Algorithm::Lloyd, &ds, &cfg).unwrap();
        let out = run_with_engine(&mut NativeEngine, &ds, &cfg).map_err(|e| e.to_string())?;
        assert_equivalent("coordinator", &l, &out.fit)
    });
}

#[test]
fn filtered_algorithms_never_do_more_work_than_lloyd() {
    run_cases("work <= lloyd", 0x57A7, |rng| {
        let (ds, cfg) = make_dataset(rng);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let lloyd_work = l.stats.total_dist_comps();
        for algo in [Algorithm::Hamerly, Algorithm::Yinyang] {
            let f = kmeans::fit_from(algo, &ds, &cfg, c0.clone()).unwrap();
            // The k² inter-centroid distances are extra bookkeeping; allow
            // that overhead but no more.
            let overhead = (cfg.k * cfg.k * f.iterations) as u64;
            if f.stats.total_dist_comps() > lloyd_work + overhead {
                return Err(format!(
                    "{}: {} > lloyd {} + overhead {}",
                    algo.name(),
                    f.stats.total_dist_comps(),
                    lloyd_work,
                    overhead
                ));
            }
        }
        Ok(())
    });
}
