//! A deterministic, in-process PROTOCOL.md-speaking server double with
//! scriptable faults — the test backbone for the remote-shards cluster
//! mode.
//!
//! A remote peer cannot be SIGKILLed from a test the way
//! `rust/tests/cluster.rs` kills supervised children, so every remote
//! failure mode must be *scripted* instead: [`FakeShard`] is a real
//! listener speaking the real wire protocol (greeting + handshake,
//! control frames, §5 error replies — all built on the same
//! `serve::codec` framing both production peers use), whose connections
//! can be told to misbehave in precisely one way at precisely one point:
//!
//! * [`Fault::RefuseHandshake`] — greet with an unsupported protocol
//!   revision (the §2 version-skew connect failure);
//! * [`Fault::DropMidReply`] — answer `after` jobs, then write half a
//!   reply line and sever the socket;
//! * [`Fault::Stall`] — answer `after` jobs, then go silent with the
//!   socket open (the wedged-peer case only the watchdog can see);
//! * [`Fault::GarbleReply`] — answer one job with a non-JSON line
//!   (framing poison: a conformant client must treat the link as lost);
//! * [`Fault::StaleWireId`] — emit a stray reply under a wire id that
//!   was never submitted before the real one (a conformant front must
//!   ignore it and deliver exactly one reply);
//! * [`Fault::StallPartial`] — map-reduce (PROTOCOL.md §10): go silent
//!   before writing the `partial` reply for one reducer epoch (the
//!   stalled-reducer case the front's straggler watchdog must catch);
//! * [`Fault::TearSync`] — map-reduce: answer one `centroid_sync` with
//!   half a reply line, then sever (torn reply mid-barrier);
//! * [`Fault::DieAtEpoch`] — map-reduce: sever the socket instead of
//!   writing the `partial` reply for one epoch (shard death
//!   mid-iteration; the front must re-dispatch the slice with history).
//!
//! Faults are consumed one per accepted connection, in order — so "drop
//! the link mid-stream, then behave after the reconnect" is the script
//! `vec![Fault::DropMidReply { after: 1 }]`: connection 1 misbehaves,
//! connection 2 (the front's reconnect) runs fault-free. Every fault is
//! therefore deterministic in *what* happens and *where* in the stream,
//! with no process spawning, no signals and no timing dice.
//!
//! Jobs are answered by running the real fit through the library
//! (`FitRequest::to_run_config` → `KpynqSystem::cluster`, synchronously,
//! in submission order), so replies carry genuine §4 summaries and the
//! §8 FNV fingerprint — a cluster fronting fake shards can be held to
//! full bit-identity against direct engine runs. Map-reduce frames
//! (PROTOCOL.md §10 `partial_fit` / `centroid_sync`) run the real
//! library partial computations too, through the same connection-scoped
//! `PartialSession` the daemon uses, so the chaos tests hold faulted
//! map-reduce fits to bit-identity against solo runs. The same
//! conformance suite (`rust/tests/protocol_conformance.rs`) runs against
//! this double *and* the production daemon, which is what keeps the two
//! from diverging.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use kpynq::coordinator::{KpynqSystem, SystemConfig};
use kpynq::obs::expo::render_prometheus;
use kpynq::obs::metrics::{names, Registry};
use kpynq::serve::cache::fingerprint_of;
use kpynq::serve::codec::{write_line, LineEvent, LineReader, MAX_LINE_BYTES};
use kpynq::serve::job::{assignments_checksum, FitRequest};
use kpynq::serve::net::PROTO_VERSION;
use kpynq::serve::PartialSession;
use kpynq::util::json::Json;

/// Accept-poll tick for the fake's (non-blocking) listener loop.
const ACCEPT_TICK: Duration = Duration::from_millis(5);

/// Entries in the fake's fingerprint replay cache — the default the
/// production fronts ship with, so the §6 `cache` reply shape matches.
const CACHE_CAP: usize = 64;

/// Fingerprint-keyed replay cache (PROTOCOL.md §8): raw reply lines
/// keyed by the §8 request fingerprint, FIFO-bounded. Deliberately not
/// the production `ResultCache` — the double must hold the *wire*
/// surface (the `cached` key, identity rewrite, §6 `cache` frame) to the
/// documented shape from its own implementation, not a shared one.
struct ReplayCache {
    entries: HashMap<u64, Json>,
    order: VecDeque<u64>,
}

/// One scripted fault, consumed by one accepted connection.
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// Behave perfectly.
    None,
    /// Greet with an unsupported protocol revision, then close.
    RefuseHandshake,
    /// Answer `after` jobs, then write half of the next reply and sever
    /// the socket (the mid-reply connection loss).
    DropMidReply { after: usize },
    /// Answer `after` jobs, then hold the socket open and answer nothing
    /// for `dead_air` — long enough to trip a watchdog under test.
    Stall { after: usize, dead_air: Duration },
    /// Answer the job after `after` replies with a garbage non-JSON line
    /// instead of its reply.
    GarbleReply { after: usize },
    /// Before the job after `after` replies is answered, emit the same
    /// reply under a wire id that was never submitted; then answer
    /// properly.
    StaleWireId { after: usize },
    /// Map-reduce (PROTOCOL.md §10): before writing the `partial` reply
    /// whose epoch is `at_epoch`, go silent for `dead_air` with the
    /// socket open — the stalled reducer epoch only a straggler watchdog
    /// can see. Fires once per connection.
    StallPartial { at_epoch: usize, dead_air: Duration },
    /// Map-reduce: answer the `centroid_sync` carrying epoch `at_epoch`
    /// with half a reply line, then sever the socket (torn reply
    /// mid-barrier). Fires once per connection.
    TearSync { at_epoch: usize },
    /// Map-reduce: sever the socket instead of writing the `partial`
    /// reply whose epoch is `at_epoch` — shard death mid-iteration. The
    /// front must re-dispatch the slice with the §10 `history` replay.
    DieAtEpoch { at_epoch: usize },
}

/// Counters and control flags shared by the listener and every
/// connection thread.
struct SharedState {
    stop: AtomicBool,
    /// When set, accepted sockets are dropped before the greeting — the
    /// "daemon host went away for good" script.
    refuse_conns: AtomicBool,
    faults: Mutex<Vec<Fault>>,
    accepted: AtomicU64,
    active_conns: AtomicUsize,
    /// Jobs admitted over the fake's lifetime (the `stats` `submitted`).
    submitted: AtomicU64,
    /// Job replies fully written (ok + failed), across all connections.
    answered: AtomicU64,
    /// A real metrics registry under the canonical `names::*` series, so
    /// `{"op":"metrics"}` (both formats) answers with genuine data — a
    /// cluster front scraping this double gets mergeable shard series,
    /// not a hollow mock (PROTOCOL.md §11).
    registry: Registry,
    /// Result replay cache shared across connections, like the real
    /// fronts' (a duplicate fit hits even over a reconnect).
    cache: Mutex<ReplayCache>,
}

/// A running fake shard: one listener, real protocol, scripted faults.
pub struct FakeShard {
    addr: String,
    shared: Arc<SharedState>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl FakeShard {
    /// Bind an ephemeral loopback listener and start serving. `faults`
    /// are consumed one per accepted connection, in order; connections
    /// past the script's end behave perfectly.
    pub fn start(faults: Vec<Fault>) -> FakeShard {
        let listener = TcpListener::bind("127.0.0.1:0").expect("fake shard bind");
        listener.set_nonblocking(true).expect("fake shard nonblocking");
        let addr = listener.local_addr().expect("fake shard addr").to_string();
        // The script is consumed front-to-back; store reversed so `pop`
        // yields connection order.
        let shared = Arc::new(SharedState {
            stop: AtomicBool::new(false),
            refuse_conns: AtomicBool::new(false),
            faults: Mutex::new(faults.into_iter().rev().collect()),
            accepted: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            answered: AtomicU64::new(0),
            registry: Registry::new(),
            cache: Mutex::new(ReplayCache { entries: HashMap::new(), order: VecDeque::new() }),
        });
        // Like the real session, the canonical series exist from start —
        // an idle shard scrapes as zeros, not as an empty body.
        shared.registry.counter(names::SERVE_JOBS_SUBMITTED);
        shared.registry.histogram(names::SERVE_LATENCY_MS);
        shared.registry.counter(names::SERVE_CACHE_HITS);
        shared.registry.counter(names::SERVE_CACHE_MISSES);
        shared.registry.counter(names::SERVE_CACHE_EVICTIONS);
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::spawn(move || {
            while !accept_shared.stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if accept_shared.refuse_conns.load(Ordering::SeqCst) {
                            drop(stream); // connect succeeds, then instant EOF
                            continue;
                        }
                        accept_shared.accepted.fetch_add(1, Ordering::SeqCst);
                        let fault = accept_shared
                            .faults
                            .lock()
                            .expect("fault script poisoned")
                            .pop()
                            .unwrap_or(Fault::None);
                        let conn_shared = Arc::clone(&accept_shared);
                        conn_shared.active_conns.fetch_add(1, Ordering::SeqCst);
                        std::thread::spawn(move || {
                            serve_conn(stream, fault, &conn_shared);
                            conn_shared.active_conns.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                        ) =>
                    {
                        std::thread::sleep(ACCEPT_TICK)
                    }
                    Err(_) => break,
                }
            }
        });
        FakeShard { addr, shared, accept_thread: Some(accept_thread) }
    }

    /// The `host:port` this fake listens on.
    pub fn addr(&self) -> String {
        self.addr.clone()
    }

    /// Append a fault for a future connection.
    pub fn push_fault(&self, fault: Fault) {
        self.shared.faults.lock().expect("fault script poisoned").insert(0, fault);
    }

    /// From now on, accept and immediately drop every new connection —
    /// the permanently-dead-host script (reconnects fail until the
    /// caller's budget runs out).
    pub fn refuse_new_conns(&self) {
        self.shared.refuse_conns.store(true, Ordering::SeqCst);
    }

    /// Connections accepted (and served) so far.
    pub fn accepted(&self) -> u64 {
        self.shared.accepted.load(Ordering::SeqCst)
    }

    /// Job replies fully written so far, across all connections.
    pub fn answered(&self) -> u64 {
        self.shared.answered.load(Ordering::SeqCst)
    }
}

impl Drop for FakeShard {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Connection threads exit on their sockets' EOF; a stalling one
        // dies with the test process.
    }
}

/// Structured §5 error reply (mirrors `serve::net::error_reply`).
fn error_reply(lineno: u64, msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("status".to_string(), Json::Str("error".into()));
    m.insert("error".to_string(), Json::Str(msg.into()));
    if lineno > 0 {
        m.insert("line".to_string(), Json::Num(lineno as f64));
    }
    Json::Obj(m).to_string()
}

fn op_frame(pairs: &[(&str, Json)]) -> String {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert((*k).to_string(), v.clone());
    }
    Json::Obj(m).to_string()
}

/// Run the real fit and build the §4 reply line by hand — the double
/// constructs raw wire JSON on purpose, so the conformance suite checks
/// the documented shape itself, not a shared serializer.
fn job_reply_json(req: &FitRequest) -> Json {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(req.id as f64));
    m.insert("worker".to_string(), Json::Num(0.0));
    m.insert("batch_size".to_string(), Json::Num(1.0));
    m.insert("queue_ms".to_string(), Json::Num(0.0));
    m.insert("service_ms".to_string(), Json::Num(0.0));
    let run = req.to_run_config().and_then(|rc| {
        let ds = rc.load_dataset()?;
        KpynqSystem::new(SystemConfig { backend: rc.backend(), verify: false })?
            .cluster(&ds, &req.kmeans)
    });
    if !req.trace_id.is_empty() {
        // §3/§4: a client-supplied trace_id rides the reply byte-identically.
        m.insert("trace_id".to_string(), Json::Str(req.trace_id.clone()));
    }
    match run {
        Ok(out) => {
            m.insert("status".to_string(), Json::Str("ok".into()));
            m.insert("backend".to_string(), Json::Str(req.backend_name.clone()));
            m.insert("inertia".to_string(), Json::Num(out.fit.inertia));
            m.insert("iterations".to_string(), Json::Num(out.fit.iterations as f64));
            m.insert("converged".to_string(), Json::Bool(out.fit.converged));
            m.insert(
                "assignments_fnv".to_string(),
                Json::Str(format!("{:016x}", assignments_checksum(&out.fit.assignments))),
            );
        }
        Err(e) => {
            m.insert("status".to_string(), Json::Str("failed".into()));
            m.insert("detail".to_string(), Json::Str(e.to_string()));
            m.insert("backend".to_string(), Json::Str(req.backend_name.clone()));
        }
    }
    Json::Obj(m)
}

/// One connection's protocol loop (PROTOCOL.md §2–§6), with the
/// connection's scripted fault applied at its trigger point.
fn serve_conn(stream: TcpStream, fault: Fault, shared: &SharedState) {
    let _ = stream.set_nodelay(true);
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let out = Mutex::new(writer);

    // Greeting (§2) — or the scripted version-skew refusal.
    if matches!(fault, Fault::RefuseHandshake) {
        let _ = write_line(
            &out,
            &op_frame(&[
                ("kpynq", Json::Str("serve".into())),
                ("proto", Json::Num(99.0)),
                ("version", Json::Str("fake".into())),
            ]),
        );
        return;
    }
    let _ = write_line(
        &out,
        &op_frame(&[
            ("kpynq", Json::Str("serve".into())),
            ("proto", Json::Num(PROTO_VERSION as f64)),
            ("version", Json::Str("fake".into())),
            ("workers", Json::Num(1.0)),
            ("max_batch", Json::Num(1.0)),
            ("max_line_bytes", Json::Num(MAX_LINE_BYTES as f64)),
            (
                "backends",
                Json::Arr(vec![Json::Str("fpga-sim".into()), Json::Str("native".into())]),
            ),
        ]),
    );

    let mut reader = LineReader::new(stream);
    let mut lineno = 0u64;
    let mut answered_here = 0usize;
    // Connection-scoped map-reduce fit state (PROTOCOL.md §10), exactly
    // like the daemon: dropped with the connection, so a severed link
    // discards its partial fits and the front re-dispatches with history.
    let mut partial = PartialSession::new();
    let mut partial_fault_fired = false;
    loop {
        match reader.next_event() {
            LineEvent::Line(bytes) => {
                lineno += 1;
                let text = match std::str::from_utf8(&bytes) {
                    Ok(t) => t,
                    Err(_) => {
                        let _ = write_line(
                            &out,
                            &error_reply(lineno, "request line is not valid UTF-8"),
                        );
                        continue;
                    }
                };
                let line = text.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue; // §2: blank lines and comments are ignored
                }
                let parsed = match Json::parse(line) {
                    Ok(j) => j,
                    Err(e) => {
                        let _ =
                            write_line(&out, &error_reply(lineno, &format!("malformed JSON: {e}")));
                        continue;
                    }
                };
                if let Json::Obj(map) = &parsed {
                    if map.contains_key("op") {
                        if !control_frame(
                            map,
                            lineno,
                            &out,
                            shared,
                            &mut partial,
                            fault,
                            &mut partial_fault_fired,
                        ) {
                            return;
                        }
                        continue;
                    }
                    if map.contains_key("proto") && !map.contains_key("id") {
                        // Handshake (§2): a mismatched revision is refused
                        // and the connection closes.
                        match map.get("proto").map(|v| v.as_usize()) {
                            Some(Ok(v)) if v as u64 == PROTO_VERSION => continue,
                            _ => {
                                let _ = write_line(
                                    &out,
                                    &error_reply(
                                        lineno,
                                        &format!(
                                            "unsupported protocol revision \
                                             (server speaks {PROTO_VERSION})"
                                        ),
                                    ),
                                );
                                return;
                            }
                        }
                    }
                }
                match FitRequest::from_json(&parsed) {
                    Ok(req) => {
                        shared.submitted.fetch_add(1, Ordering::SeqCst);
                        shared.registry.counter(names::SERVE_JOBS_SUBMITTED).inc();
                        if !answer_job(&req, fault, &mut answered_here, &out, shared) {
                            return; // the fault severed the connection
                        }
                    }
                    Err(e) => {
                        let _ = write_line(&out, &error_reply(lineno, &e.to_string()));
                    }
                }
            }
            LineEvent::Oversized => {
                lineno += 1;
                let _ = write_line(
                    &out,
                    &error_reply(lineno, &format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                );
            }
            LineEvent::Tick => continue,
            LineEvent::Eof | LineEvent::Error(_) => return,
        }
    }
}

/// §6 control frames (plus the §10 map-reduce op pair); returns `false`
/// when the connection should close.
#[allow(clippy::too_many_arguments)]
fn control_frame(
    map: &BTreeMap<String, Json>,
    lineno: u64,
    out: &Mutex<TcpStream>,
    shared: &SharedState,
    partial: &mut PartialSession,
    fault: Fault,
    fault_fired: &mut bool,
) -> bool {
    let op = match map.get("op").map(|v| v.as_str()) {
        Some(Ok(op)) => op,
        _ => {
            let _ = write_line(out, &error_reply(lineno, "control frame 'op' must be a string"));
            return true;
        }
    };
    match op {
        "ping" => {
            let _ = write_line(
                out,
                &op_frame(&[
                    ("op", Json::Str("pong".into())),
                    ("proto", Json::Num(PROTO_VERSION as f64)),
                ]),
            );
            true
        }
        "stats" => {
            // The fake executes synchronously, so nothing is ever queued:
            // every gauge a router might read is an honest zero.
            let _ = write_line(
                out,
                &op_frame(&[
                    ("op", Json::Str("stats".into())),
                    ("submitted", Json::Num(shared.submitted.load(Ordering::SeqCst) as f64)),
                    ("queue_depth", Json::Num(0.0)),
                    ("shed_full", Json::Num(0.0)),
                    ("shed_deadline", Json::Num(0.0)),
                    ("peak_queue_depth", Json::Num(0.0)),
                    ("connections", Json::Num(shared.accepted.load(Ordering::SeqCst) as f64)),
                    ("active_conns", Json::Num(shared.active_conns.load(Ordering::SeqCst) as f64)),
                    ("pending_here", Json::Num(0.0)),
                    ("uptime_ms", Json::Num(0.0)),
                    (
                        "queue_lanes",
                        Json::Arr(vec![Json::Num(0.0), Json::Num(0.0), Json::Num(0.0)]),
                    ),
                    // The fake keeps no per-tenant table — an honest
                    // empty object (§6: `tenants` is always present).
                    ("tenants", Json::Obj(BTreeMap::new())),
                ]),
            );
            true
        }
        "trace" => {
            // The fake keeps no span ring — an honest empty drain (§11).
            // `peek:true` answers identically: on an empty ring the
            // non-destructive read and the drain are indistinguishable.
            let _ = write_line(
                out,
                &op_frame(&[
                    ("op", Json::Str("trace".into())),
                    ("events", Json::Arr(Vec::new())),
                    ("dropped", Json::Num(0.0)),
                ]),
            );
            true
        }
        "metrics" => {
            // Real registry, both formats — mirrors the daemon's §6/§11
            // dispatch (including its error strings) so the conformance
            // suite can hold the two to the same wire shape.
            let snapshot = shared.registry.snapshot();
            match map.get("format").map(|v| v.as_str()) {
                None | Some(Ok("json")) => {
                    let section = |key: &str| {
                        snapshot.get(key).cloned().unwrap_or_else(|_| Json::Obj(BTreeMap::new()))
                    };
                    let _ = write_line(
                        out,
                        &op_frame(&[
                            ("op", Json::Str("metrics".into())),
                            ("counters", section("counters")),
                            ("gauges", section("gauges")),
                            ("histograms", section("histograms")),
                        ]),
                    );
                }
                Some(Ok("prometheus")) => {
                    let _ = write_line(
                        out,
                        &op_frame(&[
                            ("op", Json::Str("metrics".into())),
                            ("format", Json::Str("prometheus".into())),
                            ("body", Json::Str(render_prometheus(&snapshot))),
                        ]),
                    );
                }
                Some(Ok(other)) => {
                    let _ = write_line(
                        out,
                        &error_reply(
                            lineno,
                            &format!("unknown metrics format '{other}' (json, prometheus)"),
                        ),
                    );
                }
                Some(Err(_)) => {
                    let _ =
                        write_line(out, &error_reply(lineno, "metrics 'format' must be a string"));
                }
            }
            true
        }
        "cancel" => {
            let id = match map.get("id").map(|v| v.as_usize()) {
                Some(Ok(id)) => id as u64,
                _ => {
                    let _ = write_line(
                        out,
                        &error_reply(lineno, "cancel needs a non-negative integer 'id'"),
                    );
                    return true;
                }
            };
            // Synchronous execution means the job either already answered
            // or is answering right now — `false` is always the truth.
            let _ = write_line(
                out,
                &op_frame(&[
                    ("op", Json::Str("cancelled".into())),
                    ("id", Json::Num(id as f64)),
                    ("cancelled", Json::Bool(false)),
                ]),
            );
            true
        }
        "cache" => {
            // §6 cache frame — same `clear` validation and reply shape
            // as the production fronts (`serve::cache::cache_json`).
            let clear = match map.get("clear") {
                None => false,
                Some(Json::Bool(b)) => *b,
                Some(_) => {
                    let _ =
                        write_line(out, &error_reply(lineno, "cache 'clear' must be a boolean"));
                    return true;
                }
            };
            let mut cache = shared.cache.lock().expect("fake cache poisoned");
            let mut pairs = vec![("op", Json::Str("cache".into()))];
            if clear {
                let n = cache.entries.len();
                cache.entries.clear();
                cache.order.clear();
                pairs.push(("cleared", Json::Num(n as f64)));
            }
            pairs.push(("size", Json::Num(cache.entries.len() as f64)));
            pairs.push(("capacity", Json::Num(CACHE_CAP as f64)));
            let _ = write_line(out, &op_frame(&pairs));
            true
        }
        "partial_fit" => {
            match partial.partial_fit(&Json::Obj(map.clone())) {
                Ok(reply) => write_partial_reply("partial_fit", map, reply, fault, fault_fired, out),
                Err(e) => {
                    let _ = write_line(out, &error_reply(lineno, &e.to_string()));
                    true
                }
            }
        }
        "centroid_sync" => {
            match partial.centroid_sync(&Json::Obj(map.clone())) {
                Ok(reply) => {
                    write_partial_reply("centroid_sync", map, reply, fault, fault_fired, out)
                }
                Err(e) => {
                    let _ = write_line(out, &error_reply(lineno, &e.to_string()));
                    true
                }
            }
        }
        "bye" => false, // replies are already written (synchronous): close
        "shutdown" => {
            let _ = write_line(out, &op_frame(&[("op", Json::Str("shutdown-ack".into()))]));
            shared.stop.store(true, Ordering::SeqCst);
            false
        }
        other => {
            let _ = write_line(out, &error_reply(lineno, &format!("unknown op '{other}'")));
            true
        }
    }
}

/// Answer one job, applying the connection's fault at its trigger point;
/// returns `false` when the fault severed the connection.
fn answer_job(
    req: &FitRequest,
    fault: Fault,
    answered_here: &mut usize,
    out: &Mutex<TcpStream>,
    shared: &SharedState,
) -> bool {
    let t0 = std::time::Instant::now();
    // Real series for every answered job: the unlabeled latency histogram
    // plus, for tenanted jobs, the same series labeled by tenant — so a
    // scrape of this double exercises the documented §11 label surface.
    let record = |shared: &SharedState| {
        let el = t0.elapsed().as_secs_f64() * 1e3;
        shared.registry.histogram(names::SERVE_LATENCY_MS).record_ms(el);
        if !req.tenant.is_empty() {
            shared
                .registry
                .histogram_with(names::SERVE_LATENCY_MS, &[("tenant", &req.tenant)])
                .record_ms(el);
        }
    };
    match fault {
        Fault::DropMidReply { after } if *answered_here == after => {
            let line = job_reply_json(req).to_string();
            let torn = &line.as_bytes()[..line.len() / 2];
            {
                let mut w = out.lock().expect("fake writer poisoned");
                let _ = w.write_all(torn); // no newline — a torn frame
                let _ = w.flush();
                let _ = w.shutdown(std::net::Shutdown::Both);
            }
            false
        }
        Fault::GarbleReply { after } if *answered_here == after => {
            // Framing poison instead of the reply: a conformant client
            // must treat the link as lost (there is no way to resync a
            // stream whose peer emits non-protocol bytes).
            let _ = write_line(out, "!! this is not a protocol frame !!");
            *answered_here += 1;
            true
        }
        Fault::Stall { after, dead_air } if *answered_here == after => {
            // Dead air with the socket open: the failure mode EOF
            // detection cannot see. Whoever is watching has to decide the
            // peer is wedged on their own clock; by the time the nap ends
            // the socket is usually gone and the write below fails, which
            // ends the connection quietly.
            std::thread::sleep(dead_air);
            let ok = write_line(out, &job_reply_json(req).to_string()).is_ok();
            if ok {
                *answered_here += 1;
                shared.answered.fetch_add(1, Ordering::SeqCst);
                record(shared);
            }
            ok
        }
        Fault::StaleWireId { after } if *answered_here == after => {
            // A stray reply under an id nobody asked for, then the real
            // one: the front must ignore the stray and deliver exactly
            // one reply for the ticket.
            let mut stray = job_reply_json(req);
            if let Json::Obj(m) = &mut stray {
                m.insert("id".to_string(), Json::Num((req.id + 1_000_000) as f64));
            }
            let _ = write_line(out, &stray.to_string());
            let ok = write_line(out, &job_reply_json(req).to_string()).is_ok();
            if ok {
                *answered_here += 1;
                shared.answered.fetch_add(1, Ordering::SeqCst);
                record(shared);
            }
            ok
        }
        _ => {
            let ok = write_line(out, &cached_reply_json(req, shared).to_string()).is_ok();
            if ok {
                *answered_here += 1;
                shared.answered.fetch_add(1, Ordering::SeqCst);
                record(shared);
            }
            ok
        }
    }
}

/// Answer through the fake's fingerprint cache (PROTOCOL.md §8): a hit
/// replays the stored reply under the caller's identity with
/// `cached:true`; a miss runs the real fit and stores successful
/// replies, FIFO-bounded at [`CACHE_CAP`]. Faulted replies bypass this
/// path — a scripted tear or garble must apply to a freshly built line.
fn cached_reply_json(req: &FitRequest, shared: &SharedState) -> Json {
    let Some(fp) = fingerprint_of(req) else {
        return job_reply_json(req); // file datasets are never cached
    };
    {
        let mut cache = shared.cache.lock().expect("fake cache poisoned");
        if let Some(stored) = cache.entries.get(&fp) {
            shared.registry.counter(names::SERVE_CACHE_HITS).inc();
            let mut reply = stored.clone();
            if let Json::Obj(m) = &mut reply {
                m.insert("id".to_string(), Json::Num(req.id as f64));
                if req.trace_id.is_empty() {
                    m.remove("trace_id");
                } else {
                    m.insert("trace_id".to_string(), Json::Str(req.trace_id.clone()));
                }
                m.insert("cached".to_string(), Json::Bool(true));
            }
            // Reorder so the replayed entry is the most recently used.
            cache.order.retain(|k| *k != fp);
            cache.order.push_back(fp);
            return reply;
        }
        shared.registry.counter(names::SERVE_CACHE_MISSES).inc();
    }
    let reply = job_reply_json(req);
    if reply.get("status").ok().and_then(|v| v.as_str().ok()) == Some("ok") {
        let mut cache = shared.cache.lock().expect("fake cache poisoned");
        if !cache.entries.contains_key(&fp) {
            while cache.entries.len() >= CACHE_CAP {
                let Some(lru) = cache.order.pop_front() else { break };
                cache.entries.remove(&lru);
                shared.registry.counter(names::SERVE_CACHE_EVICTIONS).inc();
            }
            cache.entries.insert(fp, reply.clone());
            cache.order.push_back(fp);
        }
    }
    reply
}

/// Write one §10 map-reduce reply, applying the connection's scripted
/// fault at its trigger point; returns `false` when the fault severed the
/// connection. Triggers are epoch-addressed so each fault lands at a
/// deterministic point in the reduction, not at a reply count that would
/// shift with the front's retry behaviour.
fn write_partial_reply(
    op: &str,
    request: &BTreeMap<String, Json>,
    reply: Json,
    fault: Fault,
    fired: &mut bool,
    out: &Mutex<TcpStream>,
) -> bool {
    let reply_epoch = reply.get("epoch").ok().and_then(|v| v.as_usize().ok());
    let request_epoch = request.get("epoch").and_then(|v| v.as_usize().ok());
    match fault {
        Fault::StallPartial { at_epoch, dead_air }
            if !*fired && reply_epoch == Some(at_epoch) =>
        {
            // Dead air before the epoch's partial: the reducer looks
            // stalled; only the front's straggler watchdog can tell.
            *fired = true;
            std::thread::sleep(dead_air);
            write_line(out, &reply.to_string()).is_ok()
        }
        Fault::DieAtEpoch { at_epoch } if !*fired && reply_epoch == Some(at_epoch) => {
            // Shard death mid-iteration: the partial state advanced but
            // its reply never leaves. The replacement connection starts a
            // fresh PartialSession, so recovery must replay history.
            *fired = true;
            let w = out.lock().expect("fake writer poisoned");
            let _ = w.shutdown(std::net::Shutdown::Both);
            false
        }
        Fault::TearSync { at_epoch }
            if !*fired && op == "centroid_sync" && request_epoch == Some(at_epoch) =>
        {
            *fired = true;
            let line = reply.to_string();
            let torn = &line.as_bytes()[..line.len() / 2];
            let mut w = out.lock().expect("fake writer poisoned");
            let _ = w.write_all(torn); // no newline — a torn frame
            let _ = w.flush();
            let _ = w.shutdown(std::net::Shutdown::Both);
            false
        }
        _ => write_line(out, &reply.to_string()).is_ok(),
    }
}
