//! Protocol conformance: table-driven request/response vectors extracted
//! from PROTOCOL.md §4–§6 and the §10 map-reduce op pair
//! (`partial_fit` / `centroid_sync`), run against **both** the production
//! daemon (`serve::net::Daemon`) and the test double
//! (`support/fake_shard.rs`).
//!
//! This is the three-way contract that keeps the server, the client and
//! the document from silently diverging: the vectors are written from
//! the spec's text (each names the section it encodes), the daemon must
//! pass them because it *is* the spec's implementation, and the fake
//! must pass them because every remote-shards chaos test
//! (`rust/tests/cluster_remote.rs`) is only as honest as the double it
//! runs against. A behavior change that touches the wire shows up here
//! as a failing vector on one server but not the other — which is
//! exactly the drift the suite exists to catch.
//!
//! The client side under test is deliberately *raw*: a plain socket plus
//! the shared `serve::codec` line framing, no `ClientConn` — so the
//! vectors check the bytes the document promises, not what a convenient
//! client happens to tolerate.

#[allow(dead_code)]
#[path = "support/fake_shard.rs"]
mod fake_shard;

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use fake_shard::FakeShard;
use kpynq::serve::codec::{LineEvent, LineReader, MAX_LINE_BYTES};
use kpynq::serve::net::{Daemon, NetConfig, PROTO_VERSION};
use kpynq::serve::ServeConfig;
use kpynq::util::json::Json;

/// Fail-don't-hang budget for every read in the suite.
const TEST_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// A raw protocol connection: socket + shared line framing, nothing else.
struct Wire {
    reader: LineReader<TcpStream>,
    writer: TcpStream,
}

impl Wire {
    /// Connect, read the §2 greeting, return both.
    fn connect(addr: &str) -> (Json, Wire) {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(TEST_READ_TIMEOUT)).expect("read timeout");
        let writer = stream.try_clone().expect("clone stream");
        let mut reader = LineReader::new(stream);
        let greeting = match reader.next_event() {
            LineEvent::Line(bytes) => {
                Json::parse(std::str::from_utf8(&bytes).expect("greeting utf-8").trim())
                    .expect("greeting parses")
            }
            other => panic!("no greeting line, got {}", describe(&other)),
        };
        (greeting, Wire { reader, writer })
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send newline");
        self.writer.flush().expect("flush");
    }

    /// Next reply line as JSON; `None` once the server closed.
    fn recv(&mut self) -> Option<Json> {
        loop {
            match self.reader.next_event() {
                LineEvent::Line(bytes) => {
                    let text = std::str::from_utf8(&bytes).expect("reply utf-8");
                    return Some(Json::parse(text.trim()).expect("reply parses"));
                }
                LineEvent::Tick => panic!("read timeout waiting for a reply"),
                LineEvent::Oversized => panic!("server sent an oversized line"),
                // EOF and a post-close reset both mean "closed".
                LineEvent::Eof | LineEvent::Error(_) => return None,
            }
        }
    }
}

fn is_lower_hex(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase())
}

fn describe(ev: &LineEvent) -> &'static str {
    match ev {
        LineEvent::Line(_) => "line",
        LineEvent::Oversized => "oversized",
        LineEvent::Tick => "tick",
        LineEvent::Eof => "eof",
        LineEvent::Error(_) => "error",
    }
}

/// What one reply must look like.
enum Expect {
    /// `{"op":"pong","proto":1}` (§6).
    Pong,
    /// A `{"op":"stats"}` reply carrying every documented counter key (§6).
    StatsKeys(&'static [&'static str]),
    /// A §5 error reply whose `error` text contains the needle.
    ErrorContains(&'static str),
    /// A §5 error reply with 1-based line attribution (§5).
    ErrorAtLine(u64, &'static str),
    /// `{"op":"cancelled","id":N,"cancelled":B}` (§6).
    Cancelled { id: u64, value: bool },
    /// A `{"op":"trace"}` drain reply: `events` array + `dropped` count (§11).
    TraceDrain,
    /// A `{"op":"metrics"}` snapshot carrying all three sections (§6).
    MetricsSnapshot,
    /// A `{"op":"metrics","format":"prometheus"}` reply: the format
    /// echoed and a text-0.0.4 `body` containing the needle (§11).
    PrometheusBody(&'static str),
    /// An `ok` reply echoing the client's `trace_id` byte-identically (§4).
    OkJobWithTraceId { id: u64, trace_id: &'static str },
    /// A full §4 `ok` response: every always-present scalar, the
    /// `ok`-only fit fields, and a 16-lowercase-hex-digit §8 fingerprint.
    OkJob(u64),
    /// A §4 `ok` response replayed from the result cache: the full ok
    /// surface plus `cached:true` and zeroed timing (§4, §8).
    CachedOkJob(u64),
    /// A `{"op":"cache"}` reply: `size` + `capacity`, with `cleared`
    /// present exactly when the frame asked for a clear (§6).
    CacheStats { cleared: bool },
    /// A §4 `failed` response with a non-empty `detail`.
    FailedJob(u64),
    /// A §10 `partial` frame: id/epoch/shard_index echoed, `counts` one
    /// entry per cluster, `sums` at 160 hex chars per value, `init`
    /// present exactly on replies to `partial_fit`.
    Partial { id: u64, epoch: u64, shard_index: u64, init: bool },
    /// A §10 `partial_done` frame: the sealed slice `[lo, hi)`, its
    /// assignment vector at 8 hex chars per point, and a 160-hex-char
    /// exact inertia.
    PartialDone { id: u64, shard_index: u64 },
    /// The server closes the connection.
    Closed,
}

struct Vector {
    name: &'static str,
    send: Vec<String>,
    expect: Vec<Expect>,
}

fn ok_job_line(id: u64) -> String {
    format!("{{\"id\":{id},\"dataset\":\"blobs\",\"data_seed\":7,\"max_points\":300,\"k\":3,\"seed\":9}}")
}

/// A job body used *only* by the cache vector (distinct `data_seed`), so
/// its first send is a guaranteed cache miss no matter which vectors ran
/// before it on the shared server.
fn dup_job_line(id: u64) -> String {
    format!("{{\"id\":{id},\"dataset\":\"blobs\",\"data_seed\":13,\"max_points\":300,\"k\":3,\"seed\":9}}")
}

/// A §10 `partial_fit` frame: the §3 job body of [`ok_job_line`] plus the
/// op-specific keys (shard 0 of 2, the lloyd path — slicing must be
/// algorithm-agnostic, the battery covers the rest).
fn partial_fit_line(id: u64) -> String {
    format!(
        "{{\"id\":{id},\"dataset\":\"blobs\",\"data_seed\":7,\"max_points\":300,\"k\":3,\
         \"seed\":9,\"op\":\"partial_fit\",\"algorithm\":\"lloyd\",\
         \"shard_index\":0,\"shard_count\":2}}"
    )
}

/// A §10 `centroid_sync` frame for the job above. `blobs` is d=16 and the
/// job is k=3, so one centroid set is 3·16·8 = 384 hex chars; all-zero
/// bits decode to the origin, which the shard applies without judgement —
/// the *reduction's* correctness is the front's concern, the shard's
/// contract is only to apply what it is told (PROTOCOL.md §10).
fn sync_line(id: u64, epoch: u64, done: bool) -> String {
    format!(
        "{{\"op\":\"centroid_sync\",\"id\":{id},\"epoch\":{epoch},\
         \"centroids\":\"{}\",\"done\":{done}}}",
        "0".repeat(384)
    )
}

fn vectors() -> Vec<Vector> {
    let oversized = "a".repeat(MAX_LINE_BYTES + 16);
    vec![
        Vector {
            name: "ping answers pong with the protocol revision (§6)",
            send: vec![r#"{"op":"ping"}"#.into()],
            expect: vec![Expect::Pong],
        },
        Vector {
            name: "stats carries every documented counter key (§6)",
            send: vec![r#"{"op":"stats"}"#.into()],
            expect: vec![Expect::StatsKeys(&[
                "submitted",
                "queue_depth",
                "shed_full",
                "shed_deadline",
                "peak_queue_depth",
                "connections",
                "active_conns",
                "pending_here",
                "uptime_ms",
                "queue_lanes",
                "tenants",
            ])],
        },
        Vector {
            name: "trace drains the span ring as events + dropped (§11)",
            send: vec![r#"{"op":"trace"}"#.into()],
            expect: vec![Expect::TraceDrain],
        },
        Vector {
            name: "trace peek:true answers the same shape without draining (§11)",
            send: vec![r#"{"op":"trace","peek":true}"#.into(), r#"{"op":"trace","peek":true}"#.into()],
            expect: vec![Expect::TraceDrain, Expect::TraceDrain],
        },
        Vector {
            name: "metrics snapshots counters/gauges/histograms (§6)",
            send: vec![r#"{"op":"metrics"}"#.into()],
            expect: vec![Expect::MetricsSnapshot],
        },
        Vector {
            name: "metrics format=prometheus answers a text-0.0.4 body (§11)",
            send: vec![ok_job_line(41), r#"{"op":"metrics","format":"prometheus"}"#.into()],
            expect: vec![Expect::OkJob(41), Expect::PrometheusBody("serve_jobs_submitted")],
        },
        Vector {
            name: "an unknown metrics format draws a §5 error (§11)",
            send: vec![r#"{"op":"metrics","format":"xml"}"#.into()],
            expect: vec![Expect::ErrorContains("unknown metrics format")],
        },
        Vector {
            name: "a non-string metrics format draws a §5 error (§11)",
            send: vec![r#"{"op":"metrics","format":7}"#.into()],
            expect: vec![Expect::ErrorContains("must be a string")],
        },
        Vector {
            name: "a client trace_id is echoed on the reply byte-identically (§3, §4)",
            send: vec![format!(
                "{{\"id\":31,\"dataset\":\"blobs\",\"data_seed\":7,\"max_points\":300,\
                 \"k\":3,\"seed\":9,\"trace_id\":\"feedfacecafebeef\"}}"
            )],
            expect: vec![Expect::OkJobWithTraceId { id: 31, trace_id: "feedfacecafebeef" }],
        },
        Vector {
            name: "a handshake at the server's revision is accepted silently (§2)",
            send: vec![r#"{"proto":1}"#.into(), r#"{"op":"ping"}"#.into()],
            expect: vec![Expect::Pong],
        },
        Vector {
            name: "a handshake at a foreign revision is refused and closes (§2, §5)",
            send: vec![r#"{"proto":99}"#.into()],
            expect: vec![Expect::ErrorContains("protocol revision"), Expect::Closed],
        },
        Vector {
            name: "malformed JSON draws a §5 error with line attribution",
            send: vec!["{nope".into()],
            expect: vec![Expect::ErrorAtLine(1, "malformed JSON")],
        },
        Vector {
            name: "an unknown job key is rejected at admission (§3 strictness, §5)",
            send: vec![r#"{"id":1,"kay":8}"#.into()],
            expect: vec![Expect::ErrorContains("unknown job key")],
        },
        Vector {
            name: "a non-object frame is a §5 error, not a job",
            send: vec!["[1,2]".into()],
            expect: vec![Expect::ErrorContains("must be a JSON object")],
        },
        Vector {
            name: "an unknown control op draws a §5 error (§6)",
            send: vec![r#"{"op":"dance"}"#.into()],
            expect: vec![Expect::ErrorContains("unknown op")],
        },
        Vector {
            name: "cancel with a malformed id is a §5 error (§6)",
            send: vec![r#"{"op":"cancel","id":"x"}"#.into()],
            expect: vec![Expect::ErrorContains("cancel needs")],
        },
        Vector {
            name: "cancel of an unknown id acks cancelled:false (§6)",
            send: vec![r#"{"op":"cancel","id":7}"#.into()],
            expect: vec![Expect::Cancelled { id: 7, value: false }],
        },
        Vector {
            name: "blank lines and # comments are ignored (§2)",
            send: vec!["".into(), "# a comment".into(), r#"{"op":"ping"}"#.into()],
            expect: vec![Expect::Pong],
        },
        Vector {
            name: "an oversized line is rejected and framing resumes (§2, §5)",
            send: vec![oversized, r#"{"op":"ping"}"#.into()],
            expect: vec![Expect::ErrorContains("exceeds"), Expect::Pong],
        },
        Vector {
            name: "an ok response carries the full §4 scalar surface + §8 fingerprint",
            send: vec![ok_job_line(5)],
            expect: vec![Expect::OkJob(5)],
        },
        Vector {
            name: "an admitted-but-failing job answers failed with detail (§4)",
            send: vec![r#"{"id":6,"dataset":"no-such-file.csv"}"#.into()],
            expect: vec![Expect::FailedJob(6)],
        },
        Vector {
            name: "bye delivers every owed reply, then closes (§6, §2)",
            send: vec![ok_job_line(9), r#"{"op":"bye"}"#.into()],
            expect: vec![Expect::OkJob(9), Expect::Closed],
        },
        Vector {
            name: "a tenant label outside the §3 charset is rejected at admission (§3, §5)",
            send: vec![r#"{"id":61,"k":3,"tenant":"no spaces"}"#.into()],
            expect: vec![Expect::ErrorContains("tenant label")],
        },
        Vector {
            name: "a duplicate fit replays from the result cache with cached:true (§4, §8)",
            // data_seed 13 appears nowhere else in the suite, so the
            // first send is a deterministic miss and the second a hit —
            // the ids differ on purpose: identity keys are stripped from
            // the §8 fingerprint.
            send: vec![dup_job_line(62), dup_job_line(63)],
            expect: vec![Expect::OkJob(62), Expect::CachedOkJob(63)],
        },
        Vector {
            name: "the cache op reports size and capacity (§6)",
            send: vec![r#"{"op":"cache"}"#.into()],
            expect: vec![Expect::CacheStats { cleared: false }],
        },
        Vector {
            name: "cache clear:true drops every entry and reports cleared (§6)",
            send: vec![r#"{"op":"cache","clear":true}"#.into()],
            expect: vec![Expect::CacheStats { cleared: true }],
        },
        Vector {
            name: "a non-boolean cache clear is a §5 error (§6)",
            send: vec![r#"{"op":"cache","clear":"yes"}"#.into()],
            expect: vec![Expect::ErrorContains("must be a boolean")],
        },
        // --- §10 map-reduce ops ------------------------------------------
        Vector {
            name: "partial_fit answers the epoch-1 partial with init (§10)",
            send: vec![partial_fit_line(21)],
            expect: vec![Expect::Partial { id: 21, epoch: 1, shard_index: 0, init: true }],
        },
        Vector {
            name: "a duplicate partial_fit id is rejected, the first fit survives (§10, §5)",
            send: vec![partial_fit_line(22), partial_fit_line(22), sync_line(22, 1, true)],
            expect: vec![
                Expect::Partial { id: 22, epoch: 1, shard_index: 0, init: true },
                Expect::ErrorContains("already live"),
                Expect::PartialDone { id: 22, shard_index: 0 },
            ],
        },
        Vector {
            name: "partial_fit without shard_count is a §5 error (§10)",
            send: vec![
                r#"{"id":23,"dataset":"blobs","data_seed":7,"max_points":300,"k":3,"seed":9,"op":"partial_fit","shard_index":0}"#.into(),
            ],
            expect: vec![Expect::ErrorContains("shard_count")],
        },
        Vector {
            name: "partial_fit with an unknown algorithm is a §5 error (§10)",
            send: vec![
                r#"{"id":24,"dataset":"blobs","data_seed":7,"max_points":300,"k":3,"seed":9,"op":"partial_fit","algorithm":"dance","shard_index":0,"shard_count":2}"#.into(),
            ],
            expect: vec![Expect::ErrorContains("unknown algorithm")],
        },
        Vector {
            name: "partial_fit with shard_index out of range is a §5 error (§10)",
            send: vec![
                r#"{"id":25,"dataset":"blobs","data_seed":7,"max_points":300,"k":3,"seed":9,"op":"partial_fit","shard_index":5,"shard_count":2}"#.into(),
            ],
            expect: vec![Expect::ErrorContains("out of range")],
        },
        Vector {
            name: "partial_fit with a torn history is a §5 error (§10)",
            send: vec![
                r#"{"id":26,"dataset":"blobs","data_seed":7,"max_points":300,"k":3,"seed":9,"op":"partial_fit","shard_index":0,"shard_count":2,"history":"abcd"}"#.into(),
            ],
            expect: vec![Expect::ErrorContains("history length")],
        },
        Vector {
            name: "centroid_sync for an unknown id is a §5 error (§10)",
            send: vec![sync_line(77, 1, false)],
            expect: vec![Expect::ErrorContains("unknown partial fit id")],
        },
        Vector {
            name: "a continue sync advances the fit exactly one epoch, no init (§10)",
            send: vec![partial_fit_line(27), sync_line(27, 1, false)],
            expect: vec![
                Expect::Partial { id: 27, epoch: 1, shard_index: 0, init: true },
                Expect::Partial { id: 27, epoch: 2, shard_index: 0, init: false },
            ],
        },
        Vector {
            name: "an epoch-mismatched sync is rejected and leaves the fit replayable (§10, §5)",
            send: vec![partial_fit_line(28), sync_line(28, 5, false), sync_line(28, 1, true)],
            expect: vec![
                Expect::Partial { id: 28, epoch: 1, shard_index: 0, init: true },
                Expect::ErrorContains("shard is at epoch"),
                Expect::PartialDone { id: 28, shard_index: 0 },
            ],
        },
        Vector {
            name: "a done sync seals the slice and forgets the fit (§10)",
            send: vec![partial_fit_line(29), sync_line(29, 1, true), sync_line(29, 1, true)],
            expect: vec![
                Expect::Partial { id: 29, epoch: 1, shard_index: 0, init: true },
                Expect::PartialDone { id: 29, shard_index: 0 },
                Expect::ErrorContains("unknown partial fit id"),
            ],
        },
    ]
}

fn check_greeting(greeting: &Json, server: &str) {
    assert_eq!(
        greeting.get("kpynq").unwrap().as_str().unwrap(),
        "serve",
        "{server}: greeting names the protocol family (§2)"
    );
    assert_eq!(
        greeting.get("proto").unwrap().as_usize().unwrap() as u64,
        PROTO_VERSION,
        "{server}: greeting announces the revision (§2)"
    );
    assert_eq!(
        greeting.get("max_line_bytes").unwrap().as_usize().unwrap(),
        MAX_LINE_BYTES,
        "{server}: greeting echoes the line cap (§2)"
    );
    for key in ["version", "workers", "max_batch", "backends"] {
        assert!(greeting.get(key).is_ok(), "{server}: greeting key '{key}' missing (§2)");
    }
}

fn check(expect: &Expect, reply: Option<Json>, server: &str, vector: &str) {
    let ctx = format!("[{server}] {vector}");
    match expect {
        Expect::Closed => {
            assert!(reply.is_none(), "{ctx}: expected the connection to close, got {reply:?}");
            return;
        }
        _ => {}
    }
    let j = reply.unwrap_or_else(|| panic!("{ctx}: server closed instead of replying"));
    match expect {
        Expect::Pong => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "pong", "{ctx}");
            assert_eq!(j.get("proto").unwrap().as_usize().unwrap() as u64, PROTO_VERSION, "{ctx}");
        }
        Expect::StatsKeys(keys) => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "stats", "{ctx}");
            for key in *keys {
                assert!(j.get(key).is_ok(), "{ctx}: stats key '{key}' missing");
            }
        }
        Expect::ErrorContains(needle) => {
            assert_eq!(j.get("status").unwrap().as_str().unwrap(), "error", "{ctx}: {j:?}");
            let text = j.get("error").unwrap().as_str().unwrap().to_string();
            assert!(text.contains(needle), "{ctx}: error '{text}' lacks '{needle}'");
            assert!(j.get("id").is_err(), "{ctx}: §5 error replies carry no id");
        }
        Expect::ErrorAtLine(line, needle) => {
            assert_eq!(j.get("status").unwrap().as_str().unwrap(), "error", "{ctx}: {j:?}");
            let text = j.get("error").unwrap().as_str().unwrap().to_string();
            assert!(text.contains(needle), "{ctx}: error '{text}' lacks '{needle}'");
            assert_eq!(j.get("line").unwrap().as_usize().unwrap() as u64, *line, "{ctx}");
        }
        Expect::Cancelled { id, value } => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "cancelled", "{ctx}");
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(
                matches!(j.get("cancelled"), Ok(Json::Bool(true))),
                *value,
                "{ctx}: cancelled flag"
            );
        }
        Expect::TraceDrain => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "trace", "{ctx}: {j:?}");
            assert!(j.get("events").unwrap().as_arr().is_ok(), "{ctx}: events array");
            assert!(j.get("dropped").unwrap().as_usize().is_ok(), "{ctx}: dropped count");
        }
        Expect::MetricsSnapshot => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "metrics", "{ctx}: {j:?}");
            for key in ["counters", "gauges", "histograms"] {
                assert!(j.get(key).is_ok(), "{ctx}: metrics section '{key}' missing");
            }
        }
        Expect::PrometheusBody(needle) => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "metrics", "{ctx}: {j:?}");
            assert_eq!(
                j.get("format").unwrap().as_str().unwrap(),
                "prometheus",
                "{ctx}: the reply echoes the requested format (§11)"
            );
            let body = j.get("body").unwrap().as_str().unwrap().to_string();
            assert!(body.contains(needle), "{ctx}: body lacks '{needle}':\n{body}");
            assert!(
                body.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).count() > 0,
                "{ctx}: body carries at least one sample line"
            );
        }
        Expect::OkJobWithTraceId { id, trace_id } => {
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok", "{ctx}: {j:?}");
            assert_eq!(
                j.get("trace_id").unwrap().as_str().unwrap(),
                *trace_id,
                "{ctx}: trace_id must survive byte-identically"
            );
        }
        Expect::OkJob(id) => {
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok", "{ctx}: {j:?}");
            // Always-present scalars (§4).
            for key in ["worker", "batch_size", "queue_ms", "service_ms"] {
                assert!(
                    j.get(key).and_then(|v| v.as_f64()).is_ok(),
                    "{ctx}: §4 key '{key}' missing or non-numeric"
                );
            }
            // ok-only fit fields (§4).
            assert!(j.get("inertia").and_then(|v| v.as_f64()).is_ok(), "{ctx}: inertia");
            assert!(j.get("iterations").and_then(|v| v.as_usize()).is_ok(), "{ctx}: iterations");
            assert!(
                matches!(j.get("converged"), Ok(Json::Bool(_))),
                "{ctx}: converged must be a bool"
            );
            // §8: exactly 16 lowercase hex digits.
            let fnv = j.get("assignments_fnv").unwrap().as_str().unwrap().to_string();
            assert_eq!(fnv.len(), 16, "{ctx}: fingerprint '{fnv}' is not 16 digits");
            assert!(
                fnv.chars().all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase()),
                "{ctx}: fingerprint '{fnv}' is not lowercase hex"
            );
        }
        Expect::CachedOkJob(id) => {
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok", "{ctx}: {j:?}");
            assert!(
                matches!(j.get("cached"), Ok(Json::Bool(true))),
                "{ctx}: a replayed reply must carry cached:true (§4), got {j:?}"
            );
            // Replays waited on no queue and ran no engine (§8).
            assert_eq!(j.get("queue_ms").unwrap().as_f64().unwrap(), 0.0, "{ctx}: queue_ms");
            assert_eq!(j.get("service_ms").unwrap().as_f64().unwrap(), 0.0, "{ctx}: service_ms");
            // The result surface is still the full §4 ok shape.
            assert!(j.get("inertia").and_then(|v| v.as_f64()).is_ok(), "{ctx}: inertia");
            let fnv = j.get("assignments_fnv").unwrap().as_str().unwrap().to_string();
            assert_eq!(fnv.len(), 16, "{ctx}: fingerprint '{fnv}' is not 16 digits");
        }
        Expect::CacheStats { cleared } => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "cache", "{ctx}: {j:?}");
            assert!(j.get("size").and_then(|v| v.as_usize()).is_ok(), "{ctx}: size");
            let cap = j.get("capacity").unwrap().as_usize().unwrap();
            assert!(cap > 0, "{ctx}: a default-config server caches");
            if *cleared {
                assert!(
                    j.get("cleared").and_then(|v| v.as_usize()).is_ok(),
                    "{ctx}: clear:true reports how many entries dropped (§6)"
                );
                assert_eq!(
                    j.get("size").unwrap().as_usize().unwrap(),
                    0,
                    "{ctx}: size is the post-clear count"
                );
            } else {
                assert!(j.get("cleared").is_err(), "{ctx}: cleared only after a clear (§6)");
            }
        }
        Expect::FailedJob(id) => {
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(j.get("status").unwrap().as_str().unwrap(), "failed", "{ctx}: {j:?}");
            assert!(
                !j.get("detail").unwrap().as_str().unwrap().is_empty(),
                "{ctx}: failed replies carry the error text (§4)"
            );
        }
        Expect::Partial { id, epoch, shard_index, init } => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "partial", "{ctx}: {j:?}");
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(j.get("epoch").unwrap().as_usize().unwrap() as u64, *epoch, "{ctx}: epoch");
            assert_eq!(
                j.get("shard_index").unwrap().as_usize().unwrap() as u64,
                *shard_index,
                "{ctx}: shard_index"
            );
            let d = j.get("d").unwrap().as_usize().unwrap();
            let k = j.get("counts").unwrap().as_arr().unwrap().len();
            assert!(k > 0, "{ctx}: counts must carry one entry per cluster");
            for c in j.get("counts").unwrap().as_arr().unwrap() {
                assert!(c.as_usize().is_ok(), "{ctx}: counts must be non-negative integers");
            }
            // §10 framing: 160 hex chars per exact sum, k·d sums.
            let sums = j.get("sums").unwrap().as_str().unwrap().to_string();
            assert_eq!(sums.len(), k * d * 160, "{ctx}: sums length");
            assert!(is_lower_hex(&sums), "{ctx}: sums must be lowercase hex");
            match j.get("init") {
                Ok(v) if *init => {
                    let hex = v.as_str().unwrap();
                    assert_eq!(hex.len(), k * d * 8, "{ctx}: init length");
                    assert!(is_lower_hex(hex), "{ctx}: init must be lowercase hex");
                }
                Err(_) if !*init => {}
                other => panic!(
                    "{ctx}: init present only on replies to partial_fit (§10), got {other:?}"
                ),
            }
        }
        Expect::PartialDone { id, shard_index } => {
            assert_eq!(j.get("op").unwrap().as_str().unwrap(), "partial_done", "{ctx}: {j:?}");
            assert_eq!(j.get("id").unwrap().as_usize().unwrap() as u64, *id, "{ctx}");
            assert_eq!(
                j.get("shard_index").unwrap().as_usize().unwrap() as u64,
                *shard_index,
                "{ctx}: shard_index"
            );
            let lo = j.get("lo").unwrap().as_usize().unwrap();
            let hi = j.get("hi").unwrap().as_usize().unwrap();
            assert!(lo <= hi, "{ctx}: slice bounds inverted");
            // §10 framing: 8 hex chars per point assignment.
            let assignments = j.get("assignments").unwrap().as_str().unwrap().to_string();
            assert_eq!(assignments.len(), (hi - lo) * 8, "{ctx}: assignments length");
            assert!(is_lower_hex(&assignments), "{ctx}: assignments must be lowercase hex");
            // §10 framing: one 160-hex-char exact inertia.
            let inertia = j.get("inertia").unwrap().as_str().unwrap().to_string();
            assert_eq!(inertia.len(), 160, "{ctx}: inertia length");
            assert!(is_lower_hex(&inertia), "{ctx}: inertia must be lowercase hex");
        }
        Expect::Closed => unreachable!("handled above"),
    }
}

/// Run every vector against one server, each on a fresh connection so
/// line numbering and teardown expectations stay independent.
fn run_vectors(addr: &str, server: &str) {
    for v in vectors() {
        let (greeting, mut wire) = Wire::connect(addr);
        check_greeting(&greeting, server);
        for line in &v.send {
            wire.send(line);
        }
        for expect in &v.expect {
            check(expect, wire.recv(), server, v.name);
        }
    }
}

#[test]
fn the_daemon_conforms_to_the_documented_vectors() {
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        NetConfig::default(),
        ServeConfig { workers: 1, ..Default::default() },
    )
    .expect("daemon bind");
    let addr = daemon.local_addr();
    let handle = daemon.handle();
    let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
    run_vectors(&addr, "daemon");
    handle.shutdown();
    thread.join().unwrap();
}

#[test]
fn the_fake_shard_conforms_to_the_same_vectors() {
    let fake = FakeShard::start(vec![]);
    run_vectors(&fake.addr(), "fake_shard");
}

#[test]
fn ok_fingerprints_agree_across_daemon_fake_and_direct_runs() {
    // The §4 serving guarantee, cross-server: the same request answered
    // by the daemon, by the double, and by a direct coordinator run must
    // carry one identical §8 fingerprint — the property every
    // bit-identity assertion in the chaos suite stands on.
    let req = kpynq::serve::FitRequest {
        id: 5,
        dataset: "blobs".into(),
        data_seed: 7,
        max_points: 300,
        kmeans: kpynq::kmeans::KMeansConfig { k: 3, seed: 9, ..Default::default() },
        ..Default::default()
    };
    let rc = req.to_run_config().unwrap();
    let ds = rc.load_dataset().unwrap();
    let want = kpynq::coordinator::KpynqSystem::new(kpynq::coordinator::SystemConfig {
        backend: rc.backend(),
        verify: false,
    })
    .unwrap()
    .cluster(&ds, &req.kmeans)
    .unwrap();
    let want_fnv = format!(
        "{:016x}",
        kpynq::serve::job::assignments_checksum(&want.fit.assignments)
    );

    let mut got = Vec::new();
    // Daemon.
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        NetConfig::default(),
        ServeConfig { workers: 1, ..Default::default() },
    )
    .expect("daemon bind");
    let addr = daemon.local_addr();
    let handle = daemon.handle();
    let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
    {
        let (_, mut wire) = Wire::connect(&addr);
        wire.send(&ok_job_line(5));
        let j = wire.recv().expect("daemon reply");
        got.push(("daemon", j.get("assignments_fnv").unwrap().as_str().unwrap().to_string()));
    }
    handle.shutdown();
    thread.join().unwrap();
    // Fake.
    let fake = FakeShard::start(vec![]);
    {
        let (_, mut wire) = Wire::connect(&fake.addr());
        wire.send(&ok_job_line(5));
        let j = wire.recv().expect("fake reply");
        got.push(("fake", j.get("assignments_fnv").unwrap().as_str().unwrap().to_string()));
    }
    for (server, fnv) in got {
        assert_eq!(fnv, want_fnv, "{server} fingerprint diverges from the direct fit");
    }
}

#[test]
fn shutdown_acks_and_drains_on_both_servers() {
    // §6 `shutdown` last and on dedicated instances: it takes the whole
    // server down, which is the point.
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        NetConfig::default(),
        ServeConfig { workers: 1, ..Default::default() },
    )
    .expect("daemon bind");
    let addr = daemon.local_addr();
    let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
    {
        let (_, mut wire) = Wire::connect(&addr);
        wire.send(r#"{"op":"shutdown"}"#);
        let j = wire.recv().expect("shutdown-ack");
        assert_eq!(j.get("op").unwrap().as_str().unwrap(), "shutdown-ack");
        assert!(wire.recv().is_none(), "daemon closes after the ack");
    }
    thread.join().unwrap(); // the daemon actually exited

    let fake = FakeShard::start(vec![]);
    let fake_addr = fake.addr();
    {
        let (_, mut wire) = Wire::connect(&fake_addr);
        wire.send(r#"{"op":"shutdown"}"#);
        let j = wire.recv().expect("shutdown-ack");
        assert_eq!(j.get("op").unwrap().as_str().unwrap(), "shutdown-ack");
        assert!(wire.recv().is_none(), "fake closes after the ack");
    }
    drop(fake); // joins its (now stopped) accept loop
}
