//! Loopback tests for the `kpynq serve --listen` daemon front-end.
//!
//! The acceptance claims (ISSUE 3 / PROTOCOL.md):
//!
//! * a daemon-served fit is **bit-identical** to a direct `Engine` run of
//!   the same request — proven via the wire-level FNV assignment
//!   fingerprint plus inertia/iteration equality;
//! * ≥ 2 concurrent clients share one worker pool, and responses route to
//!   the connection that submitted them even when client-chosen job ids
//!   collide across connections;
//! * protocol edges — malformed NDJSON, unknown fields, oversized lines,
//!   bad handshakes, mid-stream disconnects — produce structured error
//!   replies or clean session teardown, never a dead daemon.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use kpynq::coordinator::{KpynqSystem, SystemConfig, SystemOutput};
use kpynq::serve::job::assignments_checksum;
use kpynq::serve::net::{Daemon, DaemonHandle, NetConfig, MAX_LINE_BYTES, PROTO_VERSION};
use kpynq::serve::{FitRequest, ServeConfig, ServeReport};
use kpynq::util::json::Json;

/// Bind a daemon on an ephemeral loopback port and run it on its own
/// thread; the returned join handle yields the session report.
fn start_daemon(
    serve: ServeConfig,
    net: NetConfig,
) -> (String, DaemonHandle, std::thread::JoinHandle<ServeReport>) {
    let daemon = Daemon::bind("127.0.0.1:0", net, serve).expect("bind loopback");
    let addr = daemon.local_addr();
    let handle = daemon.handle();
    let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
    (addr, handle, thread)
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Client { reader, writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send nl");
    }

    /// Read one protocol line; panics on EOF (use `read_raw` for that).
    fn read_json(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read line");
        assert!(n > 0, "unexpected EOF from daemon");
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"))
    }

    /// Read a line, returning `None` on EOF.
    fn read_opt(&mut self) -> Option<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line).expect("read line") == 0 {
            return None;
        }
        Some(Json::parse(line.trim()).expect("parseable line"))
    }

    /// Consume and sanity-check the server greeting (PROTOCOL.md §2).
    fn expect_greeting(&mut self) -> Json {
        let g = self.read_json();
        assert_eq!(g.get("kpynq").unwrap().as_str().unwrap(), "serve");
        assert_eq!(g.get("proto").unwrap().as_usize().unwrap() as u64, PROTO_VERSION);
        assert!(g.get("max_line_bytes").unwrap().as_usize().unwrap() >= 1024);
        g
    }
}

fn job_line(id: u64, data_seed: u64, k: usize, seed: u64) -> String {
    format!(
        r#"{{"id": {id}, "dataset": "blobs", "data_seed": {data_seed}, "max_points": 800, "k": {k}, "seed": {seed}}}"#
    )
}

/// The reference: the same request through the coordinator, no serving or
/// socket layer involved.
fn direct(line: &str) -> SystemOutput {
    let req = FitRequest::from_json_line(line).expect("valid job line");
    let rc = req.to_run_config().unwrap();
    let ds = rc.load_dataset().unwrap();
    KpynqSystem::new(SystemConfig { backend: rc.backend(), verify: false })
        .unwrap()
        .cluster(&ds, &req.kmeans)
        .unwrap()
}

/// Assert one wire response matches the direct run bit-for-bit, via the
/// FNV fingerprint (PROTOCOL.md §8) + inertia + iteration count.
fn assert_matches_direct(resp: &Json, line: &str) {
    let want = direct(line);
    assert_eq!(resp.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(
        resp.get("assignments_fnv").unwrap().as_str().unwrap(),
        format!("{:016x}", assignments_checksum(&want.fit.assignments)),
    );
    assert_eq!(resp.get("inertia").unwrap().as_f64().unwrap(), want.fit.inertia);
    assert_eq!(
        resp.get("iterations").unwrap().as_usize().unwrap(),
        want.fit.iterations
    );
}

#[test]
fn daemon_served_jobs_are_bit_identical_to_direct_runs() {
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 2, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    c.send(&format!(r#"{{"proto": {PROTO_VERSION}}}"#)); // explicit handshake

    let lines: Vec<String> = (0..3)
        .map(|i| job_line(i + 1, 100 + i, 3 + i as usize, 40 + i))
        .collect();
    for line in &lines {
        c.send(line);
    }
    // Responses may arrive in any completion order; collect by id.
    let mut by_id = std::collections::BTreeMap::new();
    for _ in 0..lines.len() {
        let r = c.read_json();
        by_id.insert(r.get("id").unwrap().as_usize().unwrap() as u64, r);
    }
    for (i, line) in lines.iter().enumerate() {
        assert_matches_direct(&by_id[&(i as u64 + 1)], line);
    }

    c.send(r#"{"op":"shutdown"}"#);
    assert_eq!(c.read_json().get("op").unwrap().as_str().unwrap(), "shutdown-ack");
    let report = thread.join().unwrap();
    assert_eq!(report.connections, 1);
    assert_eq!(report.completed, 3);
    assert_eq!(report.protocol_errors, 0);
}

#[test]
fn concurrent_clients_with_colliding_ids_share_one_pool() {
    let (addr, handle, thread) = start_daemon(
        ServeConfig { workers: 2, ..Default::default() },
        NetConfig::default(),
    );
    // Two clients connect before either submits, so the daemon observably
    // holds both at once; each uses the SAME job ids 1..=3 with different
    // tenant parameters — responses must route home, not leak across.
    let barrier = std::sync::Barrier::new(2);
    std::thread::scope(|scope| {
        for tenant in 0u64..2 {
            let addr = &addr;
            let barrier = &barrier;
            scope.spawn(move || {
                let mut c = Client::connect(addr);
                c.expect_greeting();
                barrier.wait();
                let lines: Vec<String> = (1..=3u64)
                    .map(|id| job_line(id, 500 + 10 * tenant + id, 4, 7 + 100 * tenant + id))
                    .collect();
                for line in &lines {
                    c.send(line);
                }
                let mut by_id = std::collections::BTreeMap::new();
                for _ in 0..lines.len() {
                    let r = c.read_json();
                    by_id.insert(r.get("id").unwrap().as_usize().unwrap() as u64, r);
                }
                // Fairness: this client got exactly its three ids back...
                assert_eq!(by_id.len(), 3, "tenant {tenant} got all its responses");
                // ...and each response is ITS clustering (bit-identity
                // against the direct run of its own parameters — a swap
                // with the other tenant's same-id job would fail here).
                for (id, line) in (1..=3u64).zip(&lines) {
                    assert_matches_direct(&by_id[&id], line);
                }
                c.send(r#"{"op":"bye"}"#);
                assert!(c.read_opt().is_none(), "bye drains then closes");
            });
        }
    });
    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.connections, 2);
    assert_eq!(report.peak_connections, 2, "both clients were live at once");
    assert_eq!(report.completed, 6, "one shared session served both tenants");
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn protocol_edges_answer_structured_errors_without_killing_the_session() {
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();

    // Table of bad frames → a fragment the error reply must mention.
    let oversized = format!(r#"{{"id": 1, "dataset": "{}"}}"#, "x".repeat(MAX_LINE_BYTES + 10));
    let cases: Vec<(&str, &str)> = vec![
        ("this is not json", "malformed JSON"),
        (r#"{"id": 1, "kay": 8}"#, "unknown job key"),
        (r#"{"id": "seven"}"#, "expected number"),
        (r#"{"id": 1, "backend": "gpu"}"#, "unknown backend"),
        (r#"{"id": 1, "priority": "urgent"}"#, "unknown priority"),
        (r#"[1, 2, 3]"#, "must be a JSON object"),
        (r#"{"op": "reboot"}"#, "unknown op"),
        (oversized.as_str(), "exceeds"),
    ];
    for (frame, expect) in &cases {
        c.send(frame);
        let r = c.read_json();
        assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error", "frame {frame:.60}");
        let msg = r.get("error").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains(expect), "frame {frame:.60}: got {msg:?}");
    }

    // The connection survived all of it: a valid job still serves, and
    // control frames still answer.
    c.send(r#"{"op":"ping"}"#);
    assert_eq!(c.read_json().get("op").unwrap().as_str().unwrap(), "pong");
    let good = job_line(9, 1, 3, 2);
    c.send(&good);
    let r = c.read_json();
    assert_eq!(r.get("id").unwrap().as_usize().unwrap(), 9);
    assert_matches_direct(&r, &good);
    c.send(r#"{"op":"stats"}"#);
    let stats = c.read_json();
    assert_eq!(stats.get("submitted").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("active_conns").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        stats.get("queue_depth").unwrap().as_usize().unwrap(),
        0,
        "queue_depth is part of the stats reply (PROTOCOL.md §6)"
    );

    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.protocol_errors as usize, cases.len());
    assert_eq!(report.completed, 1);
}

#[test]
fn mid_stream_disconnect_tears_down_cleanly() {
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig::default(),
    );
    {
        // Submit a job, then vanish without reading the response.
        let mut c = Client::connect(&addr);
        c.expect_greeting();
        c.send(&job_line(1, 9, 3, 9));
        // Dropping both halves closes the socket mid-stream.
    }
    // The daemon must still be fully serviceable afterwards.
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    let good = job_line(2, 10, 3, 10);
    c.send(&good);
    assert_matches_direct(&c.read_json(), &good);
    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.connections, 2);
    assert_eq!(report.completed, 2, "the abandoned job still executed");
}

#[test]
fn bad_handshake_is_refused() {
    let (addr, handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    c.send(r#"{"proto": 99}"#);
    let r = c.read_json();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error");
    assert!(r.get("error").unwrap().as_str().unwrap().contains("protocol revision"));
    assert!(c.read_opt().is_none(), "connection closes after handshake refusal");
    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.protocol_errors, 1);
}

#[test]
fn idle_connections_time_out() {
    let (addr, handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig { idle_timeout_ms: 250, ..Default::default() },
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    // Send nothing: the daemon must notice and close the connection.
    let notice = c.read_json();
    assert_eq!(notice.get("op").unwrap().as_str().unwrap(), "idle-timeout");
    assert!(c.read_opt().is_none(), "socket closed after the notice");
    handle.shutdown();
    thread.join().unwrap();
}

#[test]
fn connections_beyond_max_conns_are_refused() {
    let (addr, handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig { max_conns: 1, ..Default::default() },
    );
    let mut first = Client::connect(&addr);
    first.expect_greeting(); // greeting read ⇒ the slot is held
    let mut second = Client::connect(&addr);
    let refusal = second.read_json();
    assert_eq!(refusal.get("status").unwrap().as_str().unwrap(), "error");
    assert!(refusal.get("error").unwrap().as_str().unwrap().contains("max connections"));
    assert!(second.read_opt().is_none(), "refused connection is closed");
    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.connections, 1);
    assert_eq!(report.refused_connections, 1);
}

#[cfg(unix)]
#[test]
fn unix_domain_listener_serves_the_same_protocol() {
    use std::os::unix::net::UnixStream;

    let path = std::env::temp_dir().join(format!("kpynq-serve-test-{}.sock", std::process::id()));
    let addr = format!("unix:{}", path.display());
    let daemon = Daemon::bind(&addr, NetConfig::default(), ServeConfig::default()).unwrap();
    assert_eq!(daemon.local_addr(), addr);
    let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));

    let stream = UnixStream::connect(&path).expect("connect unix socket");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let greeting = Json::parse(line.trim()).unwrap();
    assert_eq!(greeting.get("kpynq").unwrap().as_str().unwrap(), "serve");

    let good = job_line(1, 77, 3, 77);
    writer.write_all(format!("{good}\n").as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_matches_direct(&Json::parse(line.trim()).unwrap(), &good);

    writer.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 1);
    assert!(!path.exists(), "socket file removed on drain");
}

#[test]
fn cancel_op_sheds_a_queued_job_and_acks_misses_honestly() {
    // One worker, no coalescing: a heavy head job keeps the second one
    // queued long enough to cancel it deterministically.
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 1, max_batch: 1, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    c.send(r#"{"id": 1, "max_points": 4000, "k": 8, "seed": 5}"#);
    c.send(&job_line(2, 7, 3, 7));
    c.send(r#"{"op":"cancel","id":2}"#);
    let ack = c.read_json();
    assert_eq!(ack.get("op").unwrap().as_str().unwrap(), "cancelled");
    assert_eq!(ack.get("id").unwrap().as_usize().unwrap(), 2);
    assert_eq!(
        ack.get("cancelled").unwrap(),
        &kpynq::util::json::Json::Bool(true),
        "job 2 had not started executing"
    );
    // Both jobs still answer exactly once: 1 ok, 2 shed-as-cancelled.
    let mut by_id = std::collections::BTreeMap::new();
    for _ in 0..2 {
        let r = c.read_json();
        by_id.insert(r.get("id").unwrap().as_usize().unwrap() as u64, r);
    }
    assert_eq!(by_id[&1].get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(by_id[&2].get("status").unwrap().as_str().unwrap(), "shed");
    assert!(by_id[&2].get("detail").unwrap().as_str().unwrap().contains("cancelled"));
    // Cancelling something finished (or never submitted) is a clean false.
    c.send(r#"{"op":"cancel","id":1}"#);
    let ack = c.read_json();
    assert_eq!(ack.get("cancelled").unwrap(), &kpynq::util::json::Json::Bool(false));
    c.send(r#"{"op":"cancel","id":777}"#);
    let ack = c.read_json();
    assert_eq!(ack.get("cancelled").unwrap(), &kpynq::util::json::Json::Bool(false));
    // A malformed cancel is a protocol error, not a dead connection.
    c.send(r#"{"op":"cancel","id":"two"}"#);
    let err = c.read_json();
    assert_eq!(err.get("status").unwrap().as_str().unwrap(), "error");

    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 1);
    assert_eq!(report.shed, 1);
    assert_eq!(report.protocol_errors, 1);
}

#[test]
fn trace_and_metrics_surface_over_the_wire() {
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();

    // A client-supplied trace_id (PROTOCOL.md §3) comes back on the
    // response byte-identically.
    c.send(
        r#"{"id": 1, "dataset": "blobs", "data_seed": 3, "max_points": 400, "k": 3, "seed": 5, "trace_id": "cafef00ddeadbeef"}"#,
    );
    let r = c.read_json();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(r.get("trace_id").unwrap().as_str().unwrap(), "cafef00ddeadbeef");
    // Work-efficiency counters ride along on ok replies (§4).
    assert!(r.get("dist_comps").unwrap().as_usize().unwrap() > 0);

    // stats gained uptime_ms and per-priority queue depths (§6 additive).
    c.send(r#"{"op":"stats"}"#);
    let stats = c.read_json();
    assert!(stats.get("uptime_ms").unwrap().as_usize().is_ok());
    assert_eq!(
        stats.get("queue_lanes").unwrap().as_arr().unwrap().len(),
        kpynq::serve::Priority::LEVELS,
    );

    // {"op":"trace"} drains the span chain, exactly once (§11).
    c.send(r#"{"op":"trace"}"#);
    let t = c.read_json();
    assert_eq!(t.get("op").unwrap().as_str().unwrap(), "trace");
    assert_eq!(t.get("dropped").unwrap().as_usize().unwrap(), 0);
    let chain: Vec<String> = t
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("trace_id").unwrap().as_str().unwrap() == "cafef00ddeadbeef")
        .map(|e| e.get("event").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(chain, ["admit", "queue-wait", "dispatch", "reply"]);
    c.send(r#"{"op":"trace"}"#);
    let again = c.read_json();
    assert!(again.get("events").unwrap().as_arr().unwrap().is_empty(), "drain is destructive");

    // {"op":"metrics"} snapshots the registry (§6).
    c.send(r#"{"op":"metrics"}"#);
    let m = c.read_json();
    assert_eq!(m.get("op").unwrap().as_str().unwrap(), "metrics");
    let counters = m.get("counters").unwrap();
    assert_eq!(counters.get("serve.jobs.submitted").unwrap().as_usize().unwrap(), 1);
    let lat = m.get("histograms").unwrap().get("serve.latency_ms").unwrap();
    assert!(lat.get("count").unwrap().as_usize().unwrap() >= 1);
    assert!(!lat.get("buckets").unwrap().as_arr().unwrap().is_empty());

    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 1);
    assert_eq!(report.protocol_errors, 0, "trace/metrics are known ops");
}

#[test]
fn cache_hits_replay_byte_identical_results_over_the_wire() {
    // The §8 result cache on the daemon: a fingerprint-identical resend
    // (identity keys differ — they are stripped) replays the stored
    // reply with `cached:true`, byte-identical on every result key.
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    let line = job_line(1, 900, 4, 31);
    c.send(&line);
    let first = c.read_json();
    assert_eq!(first.get("status").unwrap().as_str().unwrap(), "ok");
    assert!(first.get("cached").is_err(), "a cold fit is computed, not replayed");
    assert_matches_direct(&first, &line);

    c.send(&job_line(2, 900, 4, 31)); // same fit, new id
    let second = c.read_json();
    assert_eq!(second.get("id").unwrap().as_usize().unwrap(), 2);
    assert_eq!(
        second.get("cached").unwrap(),
        &Json::Bool(true),
        "a duplicate fit replays from the cache: {second:?}"
    );
    assert_eq!(second.get("queue_ms").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(second.get("service_ms").unwrap().as_f64().unwrap(), 0.0);
    // Byte-identity of the result surface: strip the identity, timing
    // and marker keys; every remaining key must serialize identically.
    let strip = |j: &Json| -> std::collections::BTreeMap<String, String> {
        match j {
            Json::Obj(m) => m
                .iter()
                .filter(|(k, _)| {
                    !matches!(k.as_str(), "id" | "trace_id" | "queue_ms" | "service_ms" | "cached")
                })
                .map(|(k, v)| (k.clone(), v.to_string()))
                .collect(),
            _ => panic!("replies are objects"),
        }
    };
    assert_eq!(strip(&first), strip(&second), "replayed result bytes must be identical");

    // §6 cache frame + §11 counters, then clear and recompute.
    c.send(r#"{"op":"cache"}"#);
    let info = c.read_json();
    assert_eq!(info.get("op").unwrap().as_str().unwrap(), "cache");
    assert_eq!(info.get("size").unwrap().as_usize().unwrap(), 1);
    assert!(info.get("capacity").unwrap().as_usize().unwrap() >= 1);
    c.send(r#"{"op":"metrics"}"#);
    let counters = c.read_json().get("counters").unwrap().clone();
    assert_eq!(counters.get("serve.cache.hits").unwrap().as_usize().unwrap(), 1);
    assert_eq!(counters.get("serve.cache.misses").unwrap().as_usize().unwrap(), 1);
    c.send(r#"{"op":"cache","clear":true}"#);
    let cleared = c.read_json();
    assert_eq!(cleared.get("cleared").unwrap().as_usize().unwrap(), 1);
    assert_eq!(cleared.get("size").unwrap().as_usize().unwrap(), 0);
    c.send(&job_line(3, 900, 4, 31));
    let third = c.read_json();
    assert_eq!(third.get("status").unwrap().as_str().unwrap(), "ok");
    assert!(third.get("cached").is_err(), "a cleared cache computes again");

    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 3, "cached replays count as completions");
}

#[test]
fn served_deadline_and_shed_semantics_hold_over_the_wire() {
    // A deadline_ms of 0 always sheds (PROTOCOL.md §7's escape hatch) —
    // the wire reply must say so rather than fabricate a clustering.
    let (addr, _handle, thread) = start_daemon(
        ServeConfig { workers: 1, ..Default::default() },
        NetConfig::default(),
    );
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    c.send(r#"{"id": 1, "max_points": 400, "deadline_ms": 0}"#);
    let r = c.read_json();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "shed");
    assert!(r.get("detail").unwrap().as_str().unwrap().contains("deadline"));
    assert!(r.get("assignments_fnv").is_err(), "shed replies carry no fingerprint");
    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.shed, 1);
}

#[test]
fn http_metrics_sidecar_serves_a_prometheus_scrape() {
    // `--metrics-listen` (PROTOCOL.md §11): a plain-HTTP GET /metrics on
    // a separate listener answers text format 0.0.4 rendered from the
    // live registry — including tenant-labeled series.
    use std::io::Read;
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        NetConfig { metrics_listen: Some("127.0.0.1:0".into()), ..Default::default() },
        ServeConfig { workers: 1, ..Default::default() },
    )
    .expect("bind loopback");
    let addr = daemon.local_addr();
    let maddr = daemon.metrics_addr().expect("metrics listener binds eagerly");
    let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));

    // One tenanted job, so the scrape carries real labeled series. The
    // registry records land before the reply is routed back, so reading
    // the reply orders the scrape after them.
    let mut c = Client::connect(&addr);
    c.expect_greeting();
    c.send(
        r#"{"id": 1, "dataset": "blobs", "data_seed": 3, "max_points": 400, "k": 3, "seed": 5, "tenant": "acme"}"#,
    );
    let r = c.read_json();
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(r.get("tenant").unwrap().as_str().unwrap(), "acme");

    let scrape = |method: &str, path: &str| -> String {
        let mut s = TcpStream::connect(&maddr).expect("connect scrape");
        s.write_all(format!("{method} {path} HTTP/1.1\r\nHost: test\r\n\r\n").as_bytes())
            .expect("write scrape");
        let mut buf = String::new();
        s.read_to_string(&mut buf).expect("read scrape");
        buf
    };
    let ok = scrape("GET", "/metrics");
    assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "scrape status:\n{ok}");
    assert!(
        ok.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n"),
        "scrape content type:\n{ok}"
    );
    let body = ok.split("\r\n\r\n").nth(1).expect("scrape body");
    for name in ["serve_jobs_submitted 1", "serve_queue_depth", "serve_latency_ms_count"] {
        assert!(body.contains(name), "scrape lacks '{name}':\n{body}");
    }
    assert!(
        body.contains("serve_latency_ms_count{tenant=\"acme\"} 1"),
        "tenant-labeled series missing:\n{body}"
    );

    // The endpoint serves exactly one read-only path.
    assert!(scrape("GET", "/other").starts_with("HTTP/1.1 404 "), "404 on unknown paths");
    assert!(scrape("POST", "/metrics").starts_with("HTTP/1.1 405 "), "405 on non-GET");

    c.send(r#"{"op":"shutdown"}"#);
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 1);
}
