//! XLA-engine parity: the AOT-compiled Pallas kernel (through PJRT) must
//! agree with the native Rust engine on random tiles, and a whole
//! coordinator run on the XLA backend must agree with Lloyd.
//!
//! Requires the `xla` cargo feature: without it the whole file compiles to
//! nothing, because the default offline build has no PJRT client to test
//! against. With the feature on, run `make artifacts` first and then
//! `cargo test --features xla` — a missing manifest is an error here, not
//! a skip, so a broken artifact pipeline cannot silently pass.

#![cfg(feature = "xla")]

use std::path::PathBuf;

use kpynq::coordinator::driver::run_with_engine;
use kpynq::data::synth;
use kpynq::kmeans::{self, Algorithm, KMeansConfig};
use kpynq::runtime::native::NativeEngine;
use kpynq::runtime::xla::XlaEngine;
use kpynq::runtime::Engine;
use kpynq::util::matrix::Matrix;
use kpynq::util::rng::Rng;

fn artifact_dir() -> PathBuf {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    root.join("artifacts")
}

fn require_engine() -> XlaEngine {
    XlaEngine::new(&artifact_dir()).expect(
        "artifacts/manifest.json missing or invalid — run `make artifacts` before `cargo test`",
    )
}

fn random_tile(rng: &mut Rng, n: usize, d: usize, k: usize) -> (Matrix, Matrix) {
    let pts: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let cents: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    (
        Matrix::from_vec(pts, n, d).unwrap(),
        Matrix::from_vec(cents, k, d).unwrap(),
    )
}

#[test]
fn xla_matches_native_on_random_tiles() {
    let mut xla = require_engine();
    let mut native = NativeEngine;
    let mut rng = Rng::new(0x7E57);
    // Sweep geometries that exercise every exported variant + padding.
    for &(n, d, k) in &[
        (256usize, 4usize, 16usize), // exact variant fit
        (256, 32, 16),
        (256, 64, 16),
        (256, 128, 16),
        (256, 64, 64),
        (100, 3, 5),   // padded rows, dims and centroids
        (300, 20, 16), // split across two tiles
        (512, 33, 17), // padded into the 64/64 variant
        (64, 1, 1),    // degenerate k=1
    ] {
        let (pts, cents) = random_tile(&mut rng, n, d, k);
        let a = native.assign_tile(&pts, &cents).unwrap();
        let b = xla.assign_tile(&pts, &cents).unwrap();
        assert_eq!(a.idx.len(), b.idx.len(), "({n},{d},{k}) length");
        for i in 0..n {
            assert_eq!(
                a.idx[i], b.idx[i],
                "({n},{d},{k}) point {i}: native {} vs xla {}",
                a.idx[i], b.idx[i]
            );
            let rel = |x: f32, y: f32| (x - y).abs() <= 1e-4 * x.abs().max(y.abs()).max(1e-3);
            assert!(rel(a.best[i], b.best[i]), "({n},{d},{k}) best[{i}]");
            if a.second[i].is_finite() || b.second[i].is_finite() {
                assert!(
                    rel(a.second[i], b.second[i]),
                    "({n},{d},{k}) second[{i}]: {} vs {}",
                    a.second[i],
                    b.second[i]
                );
            }
        }
    }
}

#[test]
fn xla_backend_coordinator_matches_lloyd() {
    let ds = synth::blobs(2000, 16, 6, 21);
    let kcfg = KMeansConfig { k: 6, seed: 9, ..Default::default() };
    let direct = kmeans::fit(Algorithm::Lloyd, &ds, &kcfg).unwrap();
    let mut eng = require_engine();
    let out = run_with_engine(&mut eng, &ds, &kcfg).unwrap();
    assert_eq!(direct.assignments, out.fit.assignments);
    assert_eq!(direct.iterations, out.fit.iterations);
    assert!(out.report.tiles_dispatched > 0);
    assert!(eng.tiles_executed > 0);
}

#[test]
fn xla_engine_reports_unsupported_geometry() {
    let mut xla = require_engine();
    let mut rng = Rng::new(3);
    // d=200 exceeds every exported variant.
    let (pts, cents) = random_tile(&mut rng, 256, 200, 8);
    let err = xla.assign_tile(&pts, &cents).unwrap_err();
    assert!(err.to_string().contains("no assign variant"), "{err}");
}
