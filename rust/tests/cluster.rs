//! End-to-end tests for `kpynq::cluster` — real child processes, real
//! sockets.
//!
//! The acceptance claims (ISSUE 4 / DESIGN.md §2):
//!
//! * a 2-shard cluster returns **bit-identical** `FitResponse`s —
//!   including the PROTOCOL.md §8 FNV fingerprint — to a single daemon,
//!   which in turn matches direct engine runs;
//! * killing a shard mid-stream is survivable: the supervisor restarts
//!   it, its in-flight jobs are requeued, and the external client still
//!   receives every reply exactly once;
//! * the router policy (BatchKey affinity, least-loaded fallback,
//!   lowest-index tie-break) is pinned at the public API.
//!
//! Shard children are the real `kpynq` binary (`CARGO_BIN_EXE_kpynq`),
//! exec'd as `kpynq serve --listen unix:…` exactly as `kpynq cluster`
//! does in production.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use kpynq::cluster::{Cluster, ClusterConfig, ClusterHandle, ClientConn, Router};
use kpynq::coordinator::{KpynqSystem, SystemConfig, SystemOutput};
use kpynq::serve::job::assignments_checksum;
use kpynq::serve::net::{Daemon, NetConfig};
use kpynq::serve::{FitRequest, FitResponse, JobStatus, ServeConfig, ServeReport};

/// Generous safety net: nothing here should take anywhere near this
/// long, but a wedged cluster must fail the test, not hang CI.
const TEST_READ_TIMEOUT: Duration = Duration::from_secs(120);

fn unique_socket_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("kpynq-cluster-test-{tag}-{}", std::process::id()))
}

fn cluster_config(shards: usize, tag: &str, serve: ServeConfig) -> ClusterConfig {
    ClusterConfig {
        shards,
        serve,
        socket_dir: unique_socket_dir(tag),
        max_restarts: 3,
        program: PathBuf::from(env!("CARGO_BIN_EXE_kpynq")),
        ..Default::default()
    }
}

fn start_cluster(
    shards: usize,
    tag: &str,
    serve: ServeConfig,
) -> (String, ClusterHandle, std::thread::JoinHandle<ServeReport>) {
    let cluster = Cluster::start("127.0.0.1:0", NetConfig::default(), cluster_config(shards, tag, serve))
        .expect("cluster start");
    let addr = cluster.local_addr();
    let handle = cluster.handle();
    let thread = std::thread::spawn(move || cluster.run().expect("cluster run"));
    (addr, handle, thread)
}

fn connect(addr: &str) -> ClientConn {
    let c = ClientConn::connect(addr).expect("connect");
    c.set_read_timeout(Some(TEST_READ_TIMEOUT)).expect("set timeout");
    c
}

fn job(id: u64, dataset: &str, data_seed: u64, k: usize, seed: u64) -> FitRequest {
    FitRequest {
        id,
        dataset: dataset.into(),
        data_seed,
        max_points: 800,
        kmeans: kpynq::kmeans::KMeansConfig { k, seed, ..Default::default() },
        ..Default::default()
    }
}

/// The ground truth: the same request straight through the coordinator —
/// no serving, no socket, no cluster.
fn direct(req: &FitRequest) -> SystemOutput {
    let rc = req.to_run_config().unwrap();
    let ds = rc.load_dataset().unwrap();
    KpynqSystem::new(SystemConfig { backend: rc.backend(), verify: false })
        .unwrap()
        .cluster(&ds, &req.kmeans)
        .unwrap()
}

fn collect_by_id(c: &mut ClientConn, n: usize) -> BTreeMap<u64, FitResponse> {
    let mut by_id = BTreeMap::new();
    for _ in 0..n {
        let r = c.recv_response().expect("response");
        assert!(
            by_id.insert(r.id, r).is_none(),
            "duplicate reply for one id: exactly-once delivery is broken"
        );
    }
    by_id
}

#[test]
fn two_shard_cluster_matches_single_daemon_and_direct_runs() {
    // A job mix spanning two BatchKeys (blobs d=16, kegg d=20), so the
    // router actually spreads work across both shards.
    let jobs: Vec<FitRequest> = vec![
        job(1, "blobs", 100, 3, 41),
        job(2, "blobs", 101, 4, 42),
        job(3, "kegg", 102, 5, 43),
        job(4, "blobs", 103, 3, 44),
        job(5, "kegg", 104, 4, 45),
        job(6, "blobs", 105, 5, 46),
    ];

    // Reference 1: one plain daemon (in-process), same total worker count.
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        NetConfig::default(),
        ServeConfig { workers: 2, ..Default::default() },
    )
    .expect("daemon bind");
    let daemon_addr = daemon.local_addr();
    let daemon_handle = daemon.handle();
    let daemon_thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
    let mut dc = connect(&daemon_addr);
    for j in &jobs {
        dc.submit(j).unwrap();
    }
    let daemon_replies = collect_by_id(&mut dc, jobs.len());
    daemon_handle.shutdown();
    daemon_thread.join().unwrap();

    // The system under test: two whole shard processes behind one port.
    let (addr, handle, thread) = start_cluster(
        2,
        "identity",
        ServeConfig { workers: 1, ..Default::default() },
    );
    let mut cc = connect(&addr);
    let g = cc.greeting();
    assert_eq!(g.get("shards").unwrap().as_usize().unwrap(), 2);
    assert_eq!(g.get("workers").unwrap().as_usize().unwrap(), 2, "shards x workers");
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    let cluster_replies = collect_by_id(&mut cc, jobs.len());

    for j in &jobs {
        let want = direct(j);
        let want_fnv = assignments_checksum(&want.fit.assignments);
        for (surface, reply) in
            [("daemon", &daemon_replies[&j.id]), ("cluster", &cluster_replies[&j.id])]
        {
            assert_eq!(reply.status, JobStatus::Ok, "{surface} job {}: {}", j.id, reply.detail);
            let s = reply.summary.expect("ok replies carry a summary");
            assert_eq!(s.assignments_fnv, want_fnv, "{surface} job {} fingerprint", j.id);
            assert_eq!(s.inertia, want.fit.inertia, "{surface} job {} inertia", j.id);
            assert_eq!(s.iterations, want.fit.iterations, "{surface} job {} iterations", j.id);
        }
    }

    // stats over the cluster front: aggregate queue_depth + shard gauges.
    let stats = cc.stats().unwrap();
    assert_eq!(stats.submitted, jobs.len() as u64);
    assert_eq!(stats.queue_depth, 0, "everything answered");

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.submitted, jobs.len() as u64);
    assert_eq!(report.completed, jobs.len() as u64);
    assert_eq!(report.shard_restarts, 0);
    assert_eq!(report.dropped_replies, 0);
    assert_eq!(report.workers, 2);
}

#[test]
fn shard_kill_mid_stream_loses_and_duplicates_nothing() {
    let (addr, handle, thread) = start_cluster(
        2,
        "chaos",
        ServeConfig { workers: 1, ..Default::default() },
    );
    let mut cc = connect(&addr);

    // Same BatchKey throughout ⇒ affinity piles the stream onto one
    // shard (the lowest-index tie-break says shard 0) — killing it hits
    // the busiest possible target.
    let jobs: Vec<FitRequest> =
        (1..=12).map(|i| job(i, "blobs", 200 + i, 3 + (i as usize % 3), 50 + i)).collect();
    for j in &jobs {
        cc.submit(j).unwrap();
    }
    // Kill while the stream is (very likely) in flight. Even if the pool
    // won the race and finished everything, the assertions below still
    // must hold: the kill always lands, the supervisor always restarts,
    // and no reply may be lost or duplicated either way.
    handle.kill_shard(0);
    let replies = collect_by_id(&mut cc, jobs.len());

    for j in &jobs {
        let r = &replies[&j.id];
        assert_eq!(r.status, JobStatus::Ok, "job {} after shard kill: {}", j.id, r.detail);
        let want = direct(j);
        assert_eq!(
            r.summary.unwrap().assignments_fnv,
            assignments_checksum(&want.fit.assignments),
            "job {} must be bit-identical even if it was requeued and re-run",
            j.id
        );
    }

    // The cluster is fully serviceable after recovery.
    assert_eq!(cc.ping().unwrap(), kpynq::serve::net::PROTO_VERSION);
    let post = job(99, "blobs", 999, 4, 99);
    cc.submit(&post).unwrap();
    let r = cc.recv_response().unwrap();
    assert_eq!(r.id, 99);
    assert_eq!(r.status, JobStatus::Ok, "{}", r.detail);

    handle.shutdown();
    let report = thread.join().unwrap();
    assert!(report.shard_restarts >= 1, "the killed shard was restarted");
    assert_eq!(report.submitted, jobs.len() as u64 + 1);
    assert_eq!(report.completed, jobs.len() as u64 + 1, "every job answered exactly once");
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn cancel_over_the_cluster_front_keeps_the_exactly_once_contract() {
    // One worker per shard, no coalescing: a heavy head job keeps shard
    // queues occupiable, so the cancel target is usually still queued.
    let (addr, handle, thread) = start_cluster(
        2,
        "cancel",
        ServeConfig { workers: 1, max_batch: 1, ..Default::default() },
    );
    let mut cc = connect(&addr);
    let mut heavy = job(1, "blobs", 300, 8, 61);
    heavy.max_points = 4_000;
    cc.submit(&heavy).unwrap();
    let target = job(2, "blobs", 301, 3, 62);
    cc.submit(&target).unwrap();
    // The ack is advisory (the cancel races execution); the invariant
    // under test is that BOTH jobs still get exactly one reply, with the
    // cancelled one shed iff the ack said so.
    let cancelled = cc.cancel(2).unwrap();
    let replies = collect_by_id(&mut cc, 2);
    assert_eq!(replies[&1].status, JobStatus::Ok, "{}", replies[&1].detail);
    if cancelled {
        assert_eq!(replies[&2].status, JobStatus::Shed);
        assert!(replies[&2].detail.contains("cancelled"), "{}", replies[&2].detail);
    } else {
        assert_eq!(replies[&2].status, JobStatus::Ok, "{}", replies[&2].detail);
    }
    // Cancelling something already answered is a clean false.
    assert!(!cc.cancel(1).unwrap());

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.submitted, 2);
    assert_eq!(report.completed + report.shed, 2);
}

#[test]
fn cluster_fit_yields_metrics_trace_and_work_counters() {
    // The ISSUE 7 acceptance triple, over the wire of a real 2-shard
    // cluster: (1) a metrics snapshot with queue/latency histograms,
    // (2) a drained trace with one admit→dispatch→reply chain under the
    // client's trace_id, (3) pruned-point counters nonzero for yinyang
    // and zero for lloyd.
    let (addr, handle, thread) =
        start_cluster(2, "obs", ServeConfig { workers: 1, ..Default::default() });
    let mut cc = connect(&addr);

    let mut yy = job(1, "blobs", 400, 4, 71);
    yy.algorithm = "yinyang".into();
    yy.trace_id = "0123456789abcdef".into();
    let mut ll = job(2, "blobs", 400, 4, 71);
    ll.algorithm = "lloyd".into();
    cc.submit(&yy).unwrap();
    cc.submit(&ll).unwrap();
    let replies = collect_by_id(&mut cc, 2);

    // (3) work-efficiency counters: the triangle-inequality kernel
    // prunes; the exhaustive one by definition cannot.
    let yy_reply = &replies[&1];
    assert_eq!(yy_reply.status, JobStatus::Ok, "{}", yy_reply.detail);
    assert_eq!(yy_reply.trace_id, "0123456789abcdef", "trace_id survives front→shard→front");
    let yw = yy_reply.summary.expect("ok replies carry a summary").work;
    assert!(yw.points_pruned > 0, "yinyang prunes points: {yw:?}");
    assert!(yw.dist_comps_avoided > 0, "yinyang avoids distance work: {yw:?}");
    let lw = replies[&2].summary.expect("ok replies carry a summary").work;
    assert_eq!(lw.points_pruned, 0, "lloyd scans every point");
    assert_eq!(lw.dist_comps_avoided, 0, "lloyd computes every distance");
    assert!(lw.dist_comps > 0);

    // (2) the front's span ring holds the chain for the traced job only.
    let t = cc.drain_trace().unwrap();
    let chain: Vec<String> = t
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("trace_id").unwrap().as_str().unwrap() == "0123456789abcdef")
        .map(|e| e.get("event").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(chain, ["admit", "dispatch", "reply"]);

    // (1) the metrics snapshot carries the front's histograms.
    let m = cc.metrics().unwrap();
    assert_eq!(
        m.get("counters").unwrap().get("cluster.jobs.submitted").unwrap().as_usize().unwrap(),
        2
    );
    let h = m.get("histograms").unwrap();
    assert!(h.get("serve.latency_ms").unwrap().get("count").unwrap().as_usize().unwrap() >= 2);
    assert!(h.get("serve.queue_wait_ms").unwrap().get("count").unwrap().as_usize().unwrap() >= 1);

    // §6 additive stats: front uptime plus per-lane depths summed over
    // the shards (all drained by now).
    let stats = cc.stats().unwrap();
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.queue_lanes.iter().sum::<usize>(), 0, "nothing left queued");

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.completed, 2);
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn duplicate_fits_replay_from_the_front_cache_bit_identically() {
    // The §8 result cache on the cluster front: the second submission of
    // a fingerprint-identical fit (different id / trace_id — identity
    // keys are stripped) replays the stored reply without consuming a
    // shard, bit-identical to both the computed reply and a direct run.
    let (addr, handle, thread) =
        start_cluster(2, "cache", ServeConfig { workers: 1, ..Default::default() });
    let mut cc = connect(&addr);
    let a = job(11, "blobs", 700, 4, 81);
    let mut b = job(12, "blobs", 700, 4, 81);
    b.trace_id = "beefbeefbeefbeef".into();

    cc.submit(&a).unwrap();
    // Wait for the computed reply so it is cached before the duplicate.
    let first = cc.recv_response().unwrap();
    assert_eq!(first.id, 11);
    assert_eq!(first.status, JobStatus::Ok, "{}", first.detail);
    assert!(!first.cached, "a cold fit is computed, not replayed");

    cc.submit(&b).unwrap();
    let second = cc.recv_response().unwrap();
    assert_eq!(second.id, 12, "the replay answers under the caller's id");
    assert_eq!(second.status, JobStatus::Ok, "{}", second.detail);
    assert!(second.cached, "a duplicate fit replays from the front cache");
    assert_eq!(second.trace_id, "beefbeefbeefbeef", "identity keys are the caller's");
    assert_eq!(second.queue_seconds, 0.0, "a replay waits on no queue");
    assert_eq!(second.service_seconds, 0.0, "a replay runs no engine");

    let want = direct(&a);
    for (tag, r) in [("computed", &first), ("cached", &second)] {
        let s = r.summary.expect("ok replies carry a summary");
        assert_eq!(
            s.assignments_fnv,
            assignments_checksum(&want.fit.assignments),
            "{tag} fingerprint"
        );
        assert_eq!(s.inertia, want.fit.inertia, "{tag} inertia");
        assert_eq!(s.iterations, want.fit.iterations, "{tag} iterations");
    }

    // The front's registry counted the hit, and the §6 cache frame
    // reports + clears the front-side entries over the wire.
    let m = cc.metrics().unwrap();
    assert_eq!(
        m.get("counters").unwrap().get("serve.cache.hits").unwrap().as_usize().unwrap(),
        1
    );
    let mut frame = BTreeMap::new();
    frame.insert("op".to_string(), kpynq::util::json::Json::Str("cache".into()));
    frame.insert("clear".to_string(), kpynq::util::json::Json::Bool(true));
    cc.send_frame(&kpynq::util::json::Json::Obj(frame)).unwrap();
    loop {
        match cc.next_event().unwrap() {
            kpynq::cluster::ClientEvent::Notice(j) => {
                assert_eq!(j.get("op").unwrap().as_str().unwrap(), "cache");
                assert!(j.get("cleared").unwrap().as_usize().unwrap() >= 1, "{j:?}");
                assert_eq!(j.get("size").unwrap().as_usize().unwrap(), 0);
                break;
            }
            other => panic!("expected the cache reply, got {other:?}"),
        }
    }

    handle.shutdown();
    let report = thread.join().unwrap();
    assert_eq!(report.submitted, 2);
    assert_eq!(report.completed, 2, "cached replays count as completions");
    assert_eq!(report.dropped_replies, 0);
}

#[test]
fn router_pins_batch_keys_and_breaks_ties_low() {
    // The policy pinned at the public API (unit-level detail lives in
    // cluster::router's own tests): affinity beats load, new keys go
    // least-loaded, ties break to the lowest index, dead shards re-home.
    let mut r = Router::new();
    let blobs = FitRequest::default(); // native + blobs: batchable
    let first = r.route(&blobs, &[0, 0]).unwrap();
    assert_eq!(first, 0, "tie-break: lowest index");
    assert_eq!(r.route(&blobs, &[7, 0]).unwrap(), 0, "affinity beats least-loaded");
    let mut kegg = FitRequest::default();
    kegg.dataset = "kegg".into();
    assert_eq!(r.route(&kegg, &[7, 0]).unwrap(), 1, "new key goes least-loaded");
    r.forget_shard(0);
    assert_eq!(r.route(&blobs, &[0, 9]).unwrap(), 0, "forgotten pins re-home by load");
    let mut solo = FitRequest::default();
    solo.backend_name = "fpga-sim".into(); // no BatchKey: never pinned
    assert_eq!(r.route(&solo, &[5, 2]).unwrap(), 1);
    assert_eq!(r.route(&solo, &[1, 2]).unwrap(), 0);
}
