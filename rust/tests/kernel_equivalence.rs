//! The distance micro-kernel equivalence battery (DESIGN.md §5).
//!
//! The tiled kernel (`kmeans::kernel`) replaced four hand-rolled distance
//! loops; this suite is the proof the refactor changed *nothing* the
//! paper's work-efficiency story depends on. Three layers:
//!
//! (a) **kernel == naive, bit for bit** — every batch API against the
//!     per-pair `util::matrix::sq_dist` loop it replaced, across a grid of
//!     tile-boundary shapes (n, k, d each in {1, tile−1, tile, tile+1,
//!     odd primes, 67}) and random-shape/random-tile property cases.
//! (b) **fits bit-identical across algorithms and backends** — a frozen
//!     naive-Lloyd oracle (the pre-kernel implementation, re-inlined here)
//!     against `kmeans::fit_from` for all four algorithms, the simulated
//!     accelerator and the native-engine coordinator, on golden fixtures:
//!     assignments, centroids, inertia and the PROTOCOL.md §8 FNV
//!     fingerprint all equal.
//! (c) **`WorkEfficiency` invariants pinned** — Lloyd reports exactly
//!     `n·k` dist comps per iteration through the batch seam; yinyang's
//!     filter counters (`points_pruned` included) are deterministic and
//!     identical between software and the accelerator model.

use kpynq::data::{synth, Dataset};
use kpynq::hw::{AccelConfig, Accelerator};
use kpynq::kmeans::kernel::{self, TILE_CENTROIDS, TILE_POINTS};
use kpynq::kmeans::reduce::{ExactSum, PartialAccumulator};
use kpynq::kmeans::{self, init, Algorithm, FitResult, InitMethod, KMeansConfig};
use kpynq::serve::job::assignments_checksum;
use kpynq::util::matrix::{sq_dist, Matrix};
use kpynq::util::proptest::{run_cases, run_cases_n};
use kpynq::util::rng::Rng;

// ---------------------------------------------------------------------
// (a) kernel == naive sq_dist loops, bit for bit
// ---------------------------------------------------------------------

/// Tile-boundary values for one axis: 1, around the tile size, small odd
/// primes, and 67 (> 2 tiles for both default tile sizes).
fn axis_values(tile: usize) -> Vec<usize> {
    let mut v = vec![1, tile - 1, tile, tile + 1, 3, 7, 13, 67];
    v.sort_unstable();
    v.dedup();
    v.retain(|&x| x > 0);
    v
}

fn random_instance(n: usize, d: usize, k: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let cts: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    (Matrix::from_vec(pts, n, d).unwrap(), Matrix::from_vec(cts, k, d).unwrap())
}

/// The naive reference the kernel replaced: per point, scan centroids in
/// ascending order with strict-`<` best/second updates over `sq_dist`.
fn naive_nearest(points: &Matrix, centroids: &Matrix) -> (Vec<u32>, Vec<f32>, Vec<f32>) {
    let mut idx = Vec::with_capacity(points.rows());
    let mut best = Vec::with_capacity(points.rows());
    let mut second = Vec::with_capacity(points.rows());
    for row in points.rows_iter() {
        let mut b = f32::INFINITY;
        let mut s = f32::INFINITY;
        let mut a = 0usize;
        for c in 0..centroids.rows() {
            let d2 = sq_dist(row, centroids.row(c));
            if d2 < b {
                s = b;
                b = d2;
                a = c;
            } else if d2 < s {
                s = d2;
            }
        }
        idx.push(a as u32);
        best.push(b);
        second.push(s);
    }
    (idx, best, second)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Check every kernel API against the naive loops on one instance, with
/// the given tile sizes. Returns an error description on any bit mismatch.
fn check_kernel_vs_naive(
    pts: &Matrix,
    cts: &Matrix,
    tp: usize,
    tc: usize,
) -> Result<(), String> {
    let n = pts.rows();
    let k = cts.rows();
    let tag = format!("n={n} k={k} d={} tp={tp} tc={tc}", pts.cols());

    // nearest_into_tiled == naive scan.
    let (ridx, rbest, rsecond) = naive_nearest(pts, cts);
    let mut idx = vec![0u32; n];
    let mut best = vec![0.0f32; n];
    let mut second = vec![0.0f32; n];
    let comps = kernel::nearest_into_tiled(pts, 0, n, cts, tp, tc, &mut idx, &mut best, &mut second);
    if comps != (n as u64) * (k as u64) {
        return Err(format!("{tag}: nearest count {comps} != n*k"));
    }
    if idx != ridx {
        return Err(format!("{tag}: argmin mismatch"));
    }
    if bits(&best) != bits(&rbest) || bits(&second) != bits(&rsecond) {
        return Err(format!("{tag}: best/second bits mismatch"));
    }

    // sq_dist_block_tiled == per-pair sq_dist.
    let mut block = vec![0.0f32; n * k];
    let comps = kernel::sq_dist_block_tiled(pts, 0, n, cts, tp, tc, &mut block);
    if comps != (n as u64) * (k as u64) {
        return Err(format!("{tag}: block count {comps} != n*k"));
    }
    for i in 0..n {
        for c in 0..k {
            let want = sq_dist(pts.row(i), cts.row(c));
            if block[i * k + c].to_bits() != want.to_bits() {
                return Err(format!("{tag}: block[{i},{c}] bits mismatch"));
            }
        }
    }

    // sq_dists_to == naive column (against each centroid as target).
    let mut col = vec![0.0f32; n];
    for c in 0..k {
        let comps = kernel::sq_dists_to(pts, cts.row(c), &mut col);
        if comps != n as u64 {
            return Err(format!("{tag}: column count {comps} != n"));
        }
        for i in 0..n {
            let want = sq_dist(pts.row(i), cts.row(c));
            if col[i].to_bits() != want.to_bits() {
                return Err(format!("{tag}: col[{i}] vs centroid {c} bits mismatch"));
            }
        }
    }

    // Singles are literally the same reduction.
    for i in 0..n.min(4) {
        for c in 0..k.min(4) {
            let want = sq_dist(pts.row(i), cts.row(c));
            if kernel::sq_dist_pair(pts.row(i), cts.row(c)).to_bits() != want.to_bits() {
                return Err(format!("{tag}: sq_dist_pair mismatch"));
            }
            if kernel::dist_pair(pts.row(i), cts.row(c)).to_bits() != want.sqrt().to_bits() {
                return Err(format!("{tag}: dist_pair mismatch"));
            }
        }
    }
    Ok(())
}

/// (a) The full tile-boundary grid with the production tile sizes. Every
/// (n, k, d) combination where each axis takes a boundary value.
#[test]
fn kernel_matches_naive_on_every_tile_boundary_shape() {
    let mut case = 0u64;
    for &n in &axis_values(TILE_POINTS) {
        for &k in &axis_values(TILE_CENTROIDS) {
            for &d in &axis_values(8) {
                case += 1;
                let (pts, cts) = random_instance(n, d, k, 0x5EED ^ case);
                check_kernel_vs_naive(&pts, &cts, TILE_POINTS, TILE_CENTROIDS).unwrap();
            }
        }
    }
    assert!(case > 300, "grid unexpectedly small: {case} cases");
}

/// (a) Random shapes AND random tile sizes: the result must be invariant
/// to tiling, not just correct for the production tiles.
#[test]
fn kernel_is_tile_size_invariant_on_random_shapes() {
    run_cases("kernel tiling invariant", 0x7117E, |rng| {
        let n = 1 + rng.next_below(80);
        let d = 1 + rng.next_below(20);
        let k = 1 + rng.next_below(20);
        let (pts, cts) = random_instance(n, d, k, rng.next_u64());
        let tp = 1 + rng.next_below(n + 4);
        let tc = 1 + rng.next_below(k + 4);
        check_kernel_vs_naive(&pts, &cts, tp, tc)?;
        // Sub-range form: a middle slice must index its buffers from lo.
        if n >= 3 {
            let lo = 1 + rng.next_below(n - 2);
            let hi = lo + 1 + rng.next_below(n - lo);
            let nn = hi - lo;
            let mut idx = vec![0u32; nn];
            let mut best = vec![0.0f32; nn];
            let mut second = vec![0.0f32; nn];
            kernel::nearest_into_tiled(&pts, lo, hi, &cts, tp, tc, &mut idx, &mut best, &mut second);
            let (ridx, rbest, _) = naive_nearest(&pts, &cts);
            for j in 0..nn {
                if idx[j] != ridx[lo + j] || best[j].to_bits() != rbest[lo + j].to_bits() {
                    return Err(format!("sub-range [{lo},{hi}) row {j} mismatch"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// (b) all four algorithms bit-identical on golden fixtures + backends
// ---------------------------------------------------------------------

/// Golden fixtures: shapes chosen to straddle tile boundaries (odd n,
/// n == 67, k around TILE_CENTROIDS) on both blob and uniform geometry.
fn fixtures() -> Vec<(Dataset, KMeansConfig)> {
    let cfg = |k: usize, groups: usize, seed: u64| KMeansConfig {
        k,
        groups,
        seed,
        max_iters: 40,
        init: InitMethod::KMeansPlusPlus,
        ..Default::default()
    };
    vec![
        (synth::blobs(400, 8, 4, 17), cfg(6, 2, 5)),
        (synth::blobs(257, 3, 5, 23), cfg(5, 0, 9)),
        (synth::blobs(67, 13, 3, 41), cfg(3, 1, 1)),
        (synth::uniform(123, 2, 31), cfg(7, 3, 3)),
        (synth::uniform(96, 9, 47), cfg(9, 0, 11)),
    ]
}

/// The pre-kernel Lloyd implementation, frozen here as the oracle: scalar
/// scan per point (ascending centroids, strict `<`), shared exact centroid
/// update, drift-based convergence, order-independent inertia.
fn naive_lloyd_oracle(ds: &Dataset, cfg: &KMeansConfig, init_c: Matrix) -> FitResult {
    let n = ds.n();
    let mut centroids = init_c;
    let mut assignments = vec![0u32; n];
    let mut stats = kpynq::kmeans::RunStats::default();
    let mut converged = false;
    let mut iterations = 0usize;
    for _ in 0..cfg.max_iters {
        iterations += 1;
        let mut it = kpynq::kmeans::IterStats::default();
        let mut reassigned = 0u64;
        for (i, row) in ds.points.rows_iter().enumerate() {
            let mut best = f32::INFINITY;
            let mut arg = 0usize;
            for c in 0..centroids.rows() {
                let d2 = sq_dist(row, centroids.row(c));
                if d2 < best {
                    best = d2;
                    arg = c;
                }
            }
            if assignments[i] != arg as u32 {
                reassigned += 1;
                assignments[i] = arg as u32;
            }
        }
        it.dist_comps = (n as u64) * (cfg.k as u64);
        it.reassigned = reassigned;
        it.survivors = n as u64;
        // Exact update: same order-independent accumulator the library uses.
        let mut acc = PartialAccumulator::new(cfg.k, ds.d());
        for (i, row) in ds.points.rows_iter().enumerate() {
            acc.add_point(row, assignments[i] as usize);
        }
        let (new_c, _counts) = acc.finalize(&centroids);
        let mut max_drift = 0.0f32;
        for c in 0..cfg.k {
            let d = sq_dist(centroids.row(c), new_c.row(c)).sqrt();
            max_drift = max_drift.max(d);
        }
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);
        if (max_drift as f64) <= cfg.tol {
            converged = true;
            break;
        }
    }
    let mut sum = ExactSum::new();
    for (i, &a) in assignments.iter().enumerate() {
        sum.add(sq_dist(ds.points.row(i), centroids.row(a as usize)));
    }
    FitResult { centroids, assignments, inertia: sum.value(), iterations, converged, stats }
}

fn assert_bit_identical(name: &str, a: &FitResult, b: &FitResult) {
    assert_eq!(a.iterations, b.iterations, "{name}: iterations");
    assert_eq!(a.converged, b.converged, "{name}: converged");
    assert_eq!(a.assignments, b.assignments, "{name}: assignments");
    assert_eq!(a.centroids, b.centroids, "{name}: centroids");
    assert_eq!(
        a.inertia.to_bits(),
        b.inertia.to_bits(),
        "{name}: inertia {} vs {}",
        a.inertia,
        b.inertia
    );
    assert_eq!(
        assignments_checksum(&a.assignments),
        assignments_checksum(&b.assignments),
        "{name}: PROTOCOL.md §8 fingerprint"
    );
}

/// (b) The kernel-backed Lloyd reproduces the frozen pre-kernel oracle bit
/// for bit on every golden fixture — including per-iteration dist-comp
/// accounting through the batch seam.
#[test]
fn lloyd_matches_frozen_prerewire_oracle() {
    for (fi, (ds, cfg)) in fixtures().into_iter().enumerate() {
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let oracle = naive_lloyd_oracle(&ds, &cfg, c0.clone());
        let lloyd = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0).unwrap();
        assert_bit_identical(&format!("fixture {fi}: lloyd vs oracle"), &oracle, &lloyd);
        assert_eq!(oracle.stats.iters.len(), lloyd.stats.iters.len(), "fixture {fi}");
        for (t, (a, b)) in oracle.stats.iters.iter().zip(&lloyd.stats.iters).enumerate() {
            assert_eq!(a.dist_comps, b.dist_comps, "fixture {fi} iter {t}: dist_comps");
            assert_eq!(a.reassigned, b.reassigned, "fixture {fi} iter {t}: reassigned");
            assert_eq!(
                a.max_drift.to_bits(),
                b.max_drift.to_bits(),
                "fixture {fi} iter {t}: max_drift"
            );
        }
    }
}

/// (b) All four algorithms produce bit-identical fits on the fixtures, and
/// the accelerator + native-engine coordinator backends agree too.
#[test]
fn four_algorithms_and_backends_bit_identical_on_fixtures() {
    use kpynq::coordinator::driver::run_with_engine;
    use kpynq::runtime::native::NativeEngine;
    for (fi, (ds, cfg)) in fixtures().into_iter().enumerate() {
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let lloyd = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        for algo in [Algorithm::Hamerly, Algorithm::Elkan, Algorithm::Yinyang] {
            let f = kmeans::fit_from(algo, &ds, &cfg, c0.clone()).unwrap();
            assert_bit_identical(&format!("fixture {fi}: {} vs lloyd", algo.name()), &lloyd, &f);
        }
        let hw = Accelerator::new(AccelConfig::default()).run_fit(&ds, &cfg, c0.clone()).unwrap();
        assert_bit_identical(&format!("fixture {fi}: accelerator vs lloyd"), &lloyd, &hw.fit);
        let out = run_with_engine(&mut NativeEngine, &ds, &cfg).unwrap();
        assert_bit_identical(&format!("fixture {fi}: native coordinator vs lloyd"), &lloyd, &out.fit);
    }
}

/// (b) The same holds on random instances (fewer cases than the dedicated
/// equivalence suite — this is the kernel battery's smoke layer, extended
/// to inertia bits + fingerprint which `equivalence.rs` doesn't compare).
#[test]
fn algorithms_bit_identical_on_random_instances() {
    run_cases_n("kernel battery random fits", 0xFAB, 25, |rng| {
        let (pts, n, d, k) = kpynq::util::proptest::small_instance(rng);
        let ds = Dataset::new("kb", Matrix::from_vec(pts, n, d).unwrap());
        let cfg = KMeansConfig {
            k,
            groups: 1 + rng.next_below(k),
            max_iters: 20,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let oracle = naive_lloyd_oracle(&ds, &cfg, c0.clone());
        for algo in Algorithm::ALL {
            let f = kmeans::fit_from(algo, &ds, &cfg, c0.clone()).unwrap();
            if f.assignments != oracle.assignments {
                return Err(format!("{}: assignments diverge from oracle", algo.name()));
            }
            if f.centroids != oracle.centroids || f.iterations != oracle.iterations {
                return Err(format!("{}: trajectory diverges from oracle", algo.name()));
            }
            if f.inertia.to_bits() != oracle.inertia.to_bits() {
                return Err(format!("{}: inertia bits diverge", algo.name()));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// (c) WorkEfficiency invariants pinned
// ---------------------------------------------------------------------

/// (c) Lloyd through the batch seam still reports exactly n·k distance
/// computations per iteration: work_ratio 1, nothing pruned.
#[test]
fn lloyd_work_accounting_exact_through_batch_seam() {
    for (fi, (ds, cfg)) in fixtures().into_iter().enumerate() {
        let r = kmeans::fit(Algorithm::Lloyd, &ds, &cfg).unwrap();
        let nk = (ds.n() as u64) * (cfg.k as u64);
        for (t, it) in r.stats.iters.iter().enumerate() {
            assert_eq!(it.dist_comps, nk, "fixture {fi} iter {t}");
            assert_eq!(it.filtered_global, 0, "fixture {fi} iter {t}");
            assert_eq!(it.survivors, ds.n() as u64, "fixture {fi} iter {t}");
        }
        assert!((r.stats.work_ratio(ds.n(), cfg.k) - 1.0).abs() < 1e-12, "fixture {fi}");
        let eff = r.stats.work_efficiency(ds.n(), cfg.k);
        assert_eq!(eff.points_pruned, 0, "fixture {fi}");
        assert_eq!(eff.dist_comps_avoided, 0, "fixture {fi}");
    }
}

/// (c) Yinyang's filter counters are deterministic across re-runs and
/// identical between the software fit and the accelerator model — pinning
/// `points_pruned` (and every other counter) on the fixture set, so a
/// kernel change that silently altered filter decisions would fail here.
#[test]
fn yinyang_filter_counters_unchanged_and_match_accelerator() {
    let mut pruned_anywhere = false;
    for (fi, (ds, cfg)) in fixtures().into_iter().enumerate() {
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let y1 = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0.clone()).unwrap();
        let y2 = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0.clone()).unwrap();
        let hw = Accelerator::new(AccelConfig::default()).run_fit(&ds, &cfg, c0).unwrap();
        for (name, other) in [("rerun", &y2), ("accelerator", &hw.fit)] {
            assert_eq!(
                y1.stats.iters.len(),
                other.stats.iters.len(),
                "fixture {fi} vs {name}: iteration count"
            );
            for (t, (a, b)) in y1.stats.iters.iter().zip(&other.stats.iters).enumerate() {
                assert_eq!(a.dist_comps, b.dist_comps, "fixture {fi} {name} iter {t}");
                assert_eq!(a.filtered_global, b.filtered_global, "fixture {fi} {name} iter {t}");
                assert_eq!(a.filtered_group, b.filtered_group, "fixture {fi} {name} iter {t}");
                assert_eq!(a.filtered_point, b.filtered_point, "fixture {fi} {name} iter {t}");
                assert_eq!(a.survivors, b.survivors, "fixture {fi} {name} iter {t}");
                assert_eq!(a.reassigned, b.reassigned, "fixture {fi} {name} iter {t}");
            }
            assert_eq!(
                y1.stats.points_pruned(),
                other.stats.points_pruned(),
                "fixture {fi} vs {name}: points_pruned"
            );
        }
        // Counter conservation each filtered iteration.
        for (t, it) in y1.stats.iters.iter().enumerate().skip(1) {
            assert_eq!(
                it.filtered_global + it.survivors,
                ds.n() as u64,
                "fixture {fi} iter {t}: every point filtered or scanned"
            );
        }
        pruned_anywhere |= y1.stats.points_pruned() > 0;
    }
    // The fixture set must actually exercise the filter (blobs converge
    // with most points globally filtered after a couple of iterations).
    assert!(pruned_anywhere, "no fixture pruned any point — fixtures too hard?");
}
