//! End-to-end system tests: the full KPynq stack on realistic (small)
//! workloads, config-file driving, fixed-point fidelity and cross-backend
//! agreement.

use kpynq::config::RunConfig;
use kpynq::coordinator::{Backend, KpynqSystem, SystemConfig};
use kpynq::data::{normalize, synth};
use kpynq::hw::fixed_point::QFormat;
use kpynq::hw::{AccelConfig, Accelerator};
use kpynq::kmeans::{self, init, Algorithm, KMeansConfig};

#[test]
fn all_backends_agree_on_a_uci_equivalent() {
    // kegg, subsampled for test speed; min-max normalised like the
    // fixed-point datapath expects.
    let mut ds = synth::uci("kegg", 1).unwrap().subsample(3000, 1);
    normalize::min_max(&mut ds);
    let kcfg = KMeansConfig { k: 8, seed: 5, ..Default::default() };

    let fpga = KpynqSystem::new(SystemConfig::default())
        .unwrap()
        .cluster(&ds, &kcfg)
        .unwrap();
    let native = KpynqSystem::new(SystemConfig { backend: Backend::Native, verify: false })
        .unwrap()
        .cluster(&ds, &kcfg)
        .unwrap();
    let direct = kmeans::fit(Algorithm::Lloyd, &ds, &kcfg).unwrap();

    assert_eq!(fpga.fit.assignments, direct.assignments, "fpga-sim vs lloyd");
    assert_eq!(native.fit.assignments, direct.assignments, "native vs lloyd");
    assert!(fpga.report.total_cycles > 0);
    assert!(native.report.wall_seconds > 0.0);
}

#[test]
fn simulated_speedup_shape_holds_on_suite() {
    // The headline shape at test scale: the multi-level filter wins
    // simulated cycles on every dataset where distance compute matters
    // (d >= 8). On d=3 roadnetwork the AXIS stream dominates and the extra
    // bounds traffic can cancel the savings — the filter must then cost at
    // most a bounded overhead (the full-size F2 table shows 0.99x there,
    // while the system still beats the CPU 2.3x via the pipeline).
    let suite = kpynq::harness::bench_suite(7, 1500);
    let kcfg = KMeansConfig { k: 16, seed: 3, max_iters: 40, ..Default::default() };
    for ds in &suite {
        let init_c = init::initialize(ds, &kcfg).unwrap();
        let on = Accelerator::new(AccelConfig::default())
            .run_fit(ds, &kcfg, init_c.clone())
            .unwrap();
        let off = Accelerator::new(AccelConfig { enable_filters: false, ..Default::default() })
            .run_fit(ds, &kcfg, init_c)
            .unwrap();
        if ds.d() >= 8 {
            assert!(
                on.total_cycles < off.total_cycles,
                "{}: filters must win ({} vs {})",
                ds.name,
                on.total_cycles,
                off.total_cycles
            );
        } else {
            assert!(
                (on.total_cycles as f64) < 1.10 * off.total_cycles as f64,
                "{}: filter overhead must stay bounded on low-d ({} vs {})",
                ds.name,
                on.total_cycles,
                off.total_cycles
            );
        }
    }
}

#[test]
fn config_file_drives_the_system() {
    let dir = std::env::temp_dir().join(format!("kpynq-cfg-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        r#"
dataset = "blobs"
max_points = 800
normalize = "minmax"

[kmeans]
k = 5
seed = 77
algorithm = "yinyang"

[accelerator]
lanes = 2
mac_width = 4
"#,
    )
    .unwrap();
    let cfg = RunConfig::from_file(&path).unwrap();
    assert_eq!(cfg.kmeans.k, 5);
    assert_eq!(cfg.lanes, 2);
    let ds = cfg.load_dataset().unwrap();
    assert_eq!(ds.n(), 800);
    let sys = KpynqSystem::new(SystemConfig { backend: cfg.backend(), verify: true }).unwrap();
    let out = sys.cluster(&ds, &cfg.kmeans).unwrap();
    assert!(out.fit.iterations >= 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fixed_point_fidelity_on_normalized_data() {
    // Quantise a normalised dataset + centroids to Q1.15 and verify the
    // resulting assignments agree with f32 for (nearly) all points —
    // the justification for simulating the datapath in f32 (DESIGN.md §1).
    let mut ds = synth::uci("mnist", 3).unwrap().subsample(2000, 3);
    normalize::min_max(&mut ds);
    let kcfg = KMeansConfig { k: 10, seed: 11, ..Default::default() };
    let fit = kmeans::fit(Algorithm::Lloyd, &ds, &kcfg).unwrap();

    let q = QFormat::Q1_15;
    let qpoints = q.quantize_slice(ds.points.as_slice());
    let qcents = q.quantize_slice(fit.centroids.as_slice());
    let qp = kpynq::util::matrix::Matrix::from_vec(qpoints, ds.n(), ds.d()).unwrap();
    let qc = kpynq::util::matrix::Matrix::from_vec(qcents, kcfg.k, ds.d()).unwrap();

    let mut mismatches = 0usize;
    for i in 0..ds.n() {
        let (qa, _, _) = kpynq::kmeans::lloyd::scan_all(qp.row(i), &qc);
        if qa as u32 != fit.assignments[i] {
            mismatches += 1;
        }
    }
    let rate = mismatches as f64 / ds.n() as f64;
    assert!(rate < 1e-3, "fixed-point flipped {:.4}% of assignments", rate * 100.0);
}

#[test]
fn resource_gate_blocks_impossible_runs_end_to_end() {
    let ds = synth::blobs(500, 700, 4, 9); // d=700 blows the BRAM budget
    let kcfg = KMeansConfig { k: 4, seed: 1, ..Default::default() };
    let sys = KpynqSystem::new(SystemConfig::default()).unwrap();
    let err = sys.cluster(&ds, &kcfg).unwrap_err();
    assert!(matches!(err, kpynq::Error::Resource { .. }), "got {err}");
}

#[test]
fn streaming_double_buffer_composes_with_engine() {
    // The buffer::pipelined overlap helper must deliver identical results
    // to the serial path when used for tile prep + assign.
    use kpynq::coordinator::buffer::pipelined;
    use kpynq::coordinator::scheduler;
    use kpynq::runtime::{native::NativeEngine, Engine};

    let mut ds = synth::uci("gassensor", 5).unwrap().subsample(1024, 5);
    normalize::min_max(&mut ds);
    let cents = ds.points.gather_rows(&(0..8).collect::<Vec<_>>());

    let tiles = scheduler::partition(ds.n(), 256);
    let serial: Vec<u32> = tiles
        .iter()
        .flat_map(|t| {
            NativeEngine
                .assign_tile(&ds.points.gather_rows(&t.indices), &cents)
                .unwrap()
                .idx
        })
        .collect();

    let points = &ds.points;
    let cents_ref = &cents;
    let (chunks, _timing) = pipelined(
        tiles,
        move |t| points.gather_rows(&t.indices),
        |tile_pts| NativeEngine.assign_tile(&tile_pts, cents_ref).unwrap().idx,
    );
    let overlapped: Vec<u32> = chunks.into_iter().flatten().collect();
    assert_eq!(serial, overlapped);
}
