//! Partition-equivalence battery for map-reduce fits (PROTOCOL.md §10,
//! DESIGN.md §2): slicing one fit's points across shards and reducing
//! per-cluster partial sums each iteration must be **bit-identical** to
//! the solo in-process fit — same assignments, same centroid bits, same
//! inertia bits, same iteration count and convergence flag, same FNV §8
//! fingerprint — for every algorithm variant and every shard count,
//! including degenerate slicings (more shards than points ⇒ empty
//! slices).
//!
//! The keystone is the exact reduction (`kmeans::reduce`): merges of
//! `ExactSum` superaccumulators are exactly associative, so any
//! partitioning produces the same canonical sums and hence the same
//! `f64` centroids as the solo accumulation. These properties would fail
//! instantly under naive `f32`/`f64` partial sums.

use kpynq::cluster::fit_sliced;
use kpynq::data::Dataset;
use kpynq::kmeans::{self, Algorithm, FitResult, KMeansConfig};
use kpynq::serve::job::assignments_checksum;
use kpynq::util::matrix::Matrix;
use kpynq::util::proptest::{run_cases_n, small_instance};
use kpynq::util::rng::Rng;

/// Bit-level equality check between a solo fit and a sliced fit.
fn check_identical(
    algo: Algorithm,
    shards: usize,
    solo: &FitResult,
    sliced: &FitResult,
) -> Result<(), String> {
    let tag = format!("{} x {shards} shards", algo.name());
    if sliced.assignments != solo.assignments {
        return Err(format!("{tag}: assignments diverged"));
    }
    let solo_bits: Vec<u32> = solo.centroids.as_slice().iter().map(|v| v.to_bits()).collect();
    let sliced_bits: Vec<u32> =
        sliced.centroids.as_slice().iter().map(|v| v.to_bits()).collect();
    if solo_bits != sliced_bits {
        return Err(format!("{tag}: centroid bits diverged"));
    }
    if sliced.inertia.to_bits() != solo.inertia.to_bits() {
        return Err(format!(
            "{tag}: inertia diverged ({} vs {})",
            sliced.inertia, solo.inertia
        ));
    }
    if sliced.iterations != solo.iterations {
        return Err(format!(
            "{tag}: iterations {} vs {}",
            sliced.iterations, solo.iterations
        ));
    }
    if sliced.converged != solo.converged {
        return Err(format!("{tag}: converged flag diverged"));
    }
    if assignments_checksum(&sliced.assignments) != assignments_checksum(&solo.assignments) {
        return Err(format!("{tag}: FNV fingerprint diverged"));
    }
    Ok(())
}

fn random_dataset(rng: &mut Rng) -> (Dataset, usize) {
    let (pts, n, d, k) = small_instance(rng);
    let ds = Dataset {
        name: "mapreduce-prop".into(),
        points: Matrix::from_vec(pts, n, d).unwrap(),
        labels: None,
    };
    (ds, k)
}

#[test]
fn map_reduce_equals_solo_for_every_algorithm_and_shard_count() {
    run_cases_n("map-reduce == solo fit", 0xA11, 30, |rng| {
        let (ds, k) = random_dataset(rng);
        let cfg = KMeansConfig {
            k,
            max_iters: 1 + rng.next_below(25),
            seed: rng.next_u64(),
            // Exercise non-default grouping geometry on the yinyang path.
            groups: rng.next_below(4),
            ..Default::default()
        };
        for algo in Algorithm::ALL {
            let solo = kmeans::fit(algo, &ds, &cfg).map_err(|e| e.to_string())?;
            for shards in 1..=5 {
                let sliced =
                    fit_sliced(algo, &ds, &cfg, shards).map_err(|e| e.to_string())?;
                check_identical(algo, shards, &solo, &sliced)?;
            }
        }
        Ok(())
    });
}

#[test]
fn map_reduce_equals_solo_with_empty_slices() {
    // More shards than points: some slices are empty and contribute an
    // all-zero accumulator; the reduction must still match the solo fit
    // bit for bit (and never produce NaN centroids — the empty-cluster
    // guard keeps the previous row).
    run_cases_n("empty slices are harmless", 0xE2, 20, |rng| {
        let n = 1 + rng.next_below(6);
        let d = 1 + rng.next_below(4);
        let k = 1 + rng.next_below(n);
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let ds = Dataset {
            name: "tiny".into(),
            points: Matrix::from_vec(pts, n, d).unwrap(),
            labels: None,
        };
        let cfg = KMeansConfig { k, max_iters: 8, seed: rng.next_u64(), ..Default::default() };
        for algo in Algorithm::ALL {
            let solo = kmeans::fit(algo, &ds, &cfg).map_err(|e| e.to_string())?;
            let shards = n + 2; // guaranteed empty slices
            let sliced = fit_sliced(algo, &ds, &cfg, shards).map_err(|e| e.to_string())?;
            check_identical(algo, shards, &solo, &sliced)?;
            if !sliced.centroids.as_slice().iter().all(|v| v.is_finite()) {
                return Err(format!("{}: non-finite centroid", algo.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn map_reduce_stats_track_the_solo_drift_trace() {
    // Work counters are shard-local and deliberately not reproduced, but
    // the per-iteration max_drift is partition-invariant — it is computed
    // from the reduced centroids, which are bit-identical.
    run_cases_n("max_drift trace is partition-invariant", 0xD1, 15, |rng| {
        let (ds, k) = random_dataset(rng);
        let cfg = KMeansConfig { k, max_iters: 12, seed: rng.next_u64(), ..Default::default() };
        let solo = kmeans::fit(Algorithm::Yinyang, &ds, &cfg).map_err(|e| e.to_string())?;
        let sliced =
            fit_sliced(Algorithm::Yinyang, &ds, &cfg, 3).map_err(|e| e.to_string())?;
        if solo.stats.iters.len() != sliced.stats.iters.len() {
            return Err(format!(
                "iter-stats length {} vs {}",
                sliced.stats.iters.len(),
                solo.stats.iters.len()
            ));
        }
        for (i, (s, m)) in solo.stats.iters.iter().zip(&sliced.stats.iters).enumerate() {
            if s.max_drift.to_bits() != m.max_drift.to_bits() {
                return Err(format!(
                    "iteration {i}: max_drift {} vs {}",
                    m.max_drift, s.max_drift
                ));
            }
        }
        Ok(())
    });
}
