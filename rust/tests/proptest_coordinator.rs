//! Property tests on coordinator and hardware-model invariants
//! (DESIGN.md §6): tile scheduling conserves points, the DMA model
//! conserves bytes against physical link limits, resource estimates are
//! monotone, bound arithmetic stays conservative under drift.

use kpynq::coordinator::scheduler;
use kpynq::hw::dma::{Dir, DmaModel, Transfer};
use kpynq::hw::filter_unit::FilterUnitConfig;
use kpynq::hw::pipeline::PipelineConfig;
use kpynq::hw::resource::{estimate, ProblemShape};
use kpynq::hw::ZynqPart;
use kpynq::kmeans::bounds::{deflate_lb, filter_safe, group_max_drifts, inflate_ub};
use kpynq::util::proptest::run_cases;

#[test]
fn partition_is_exact_cover() {
    run_cases("partition covers 0..n once", 1, |rng| {
        let n = rng.next_below(5000);
        let tile = 1 + rng.next_below(512);
        let tiles = scheduler::partition(n, tile);
        let mut seen = vec![false; n];
        for t in &tiles {
            if t.indices.len() > tile {
                return Err(format!("tile of {} > {}", t.indices.len(), tile));
            }
            for &i in &t.indices {
                if i >= n || seen[i] {
                    return Err(format!("index {i} duplicated or out of range"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("not all points covered".into());
        }
        Ok(())
    });
}

#[test]
fn compact_preserves_survivor_set() {
    run_cases("compact = sorted survivor multiset", 2, |rng| {
        let n = 1 + rng.next_below(3000);
        let tile = 1 + rng.next_below(300);
        // Random subset, shuffled order.
        let mut survivors: Vec<usize> = (0..n).filter(|_| rng.next_below(3) == 0).collect();
        rng.shuffle(&mut survivors);
        let expect: std::collections::BTreeSet<usize> = survivors.iter().copied().collect();
        let tiles = scheduler::compact(survivors, tile);
        let mut got = Vec::new();
        for t in &tiles {
            // Dense ascending within a tile.
            for w in t.indices.windows(2) {
                if w[0] >= w[1] {
                    return Err("tile not ascending".into());
                }
            }
            got.extend_from_slice(&t.indices);
        }
        let got_set: std::collections::BTreeSet<usize> = got.iter().copied().collect();
        if got.len() != got_set.len() || got_set != expect {
            return Err("survivor set changed".into());
        }
        Ok(())
    });
}

#[test]
fn dma_never_beats_physics() {
    run_cases("dma >= bytes/width and >= ddr floor", 3, |rng| {
        let part = ZynqPart::xc7z020();
        let m = DmaModel::for_part(&part);
        let bytes = 1 + rng.next_below(1 << 24) as u64;
        let c = m.transfer_cycles(Transfer { bytes, dir: Dir::ToPl });
        if c < bytes.div_ceil(m.port_bytes_per_cycle) {
            return Err(format!("{bytes} B in {c} cycles beats the port"));
        }
        // Concurrent makespan ≥ any member, ≥ DDR floor.
        let t1 = Transfer { bytes, dir: Dir::ToPl };
        let t2 = Transfer { bytes: 1 + rng.next_below(1 << 22) as u64, dir: Dir::FromPl };
        let mk = m.concurrent(&[t1, t2]);
        if mk < m.transfer_cycles(t1).max(0) || mk + m.setup_cycles < m.transfer_cycles(t2) {
            return Err("concurrent makespan below a member".into());
        }
        let ddr_per_cycle = m.ddr_bandwidth / m.pl_clock_hz;
        let floor = ((t1.bytes + t2.bytes) as f64 / ddr_per_cycle) as u64;
        if mk < floor {
            return Err(format!("makespan {mk} under DDR floor {floor}"));
        }
        Ok(())
    });
}

#[test]
fn pipeline_cycles_scale_and_never_undercount() {
    run_cases("pipeline work conservation", 4, |rng| {
        let lanes = 1 + rng.next_below(32) as u64;
        let w = 1 + rng.next_below(16) as u64;
        let p = PipelineConfig { lanes, mac_width: w };
        let d = 1 + rng.next_below(256);
        let n = rng.next_below(100_000) as u64;
        let c = p.cycles(n, d);
        // Work conservation: lanes × cycles ≥ total issue slots.
        let slots = n * (d as u64).div_ceil(w);
        if n > 0 && c * lanes < slots {
            return Err(format!("{c} cycles × {lanes} lanes < {slots} slots"));
        }
        if n == 0 && c != 0 {
            return Err("zero work must cost zero cycles".into());
        }
        Ok(())
    });
}

#[test]
fn resource_estimates_monotone_in_every_axis() {
    run_cases("resources monotone", 5, |rng| {
        let filt = FilterUnitConfig::default();
        let lanes = 1 + rng.next_below(16) as u64;
        let w = 1 + rng.next_below(8) as u64;
        let k = 2 + rng.next_below(63);
        let d = 1 + rng.next_below(256);
        let g = 1 + rng.next_below(16);
        let tile = 64 + rng.next_below(512);
        let base = estimate(&PipelineConfig { lanes, mac_width: w }, &filt,
                            &ProblemShape::new(k, d, g, tile));
        // Doubling lanes: DSP/LUT strictly grow.
        let more = estimate(&PipelineConfig { lanes: lanes * 2, mac_width: w }, &filt,
                            &ProblemShape::new(k, d, g, tile));
        if more.dsp <= base.dsp || more.luts <= base.luts {
            return Err("lanes x2 did not grow DSP/LUT".into());
        }
        // 4x dimensionality: BRAM never shrinks below base (bank floors).
        let wide = estimate(&PipelineConfig { lanes, mac_width: w }, &filt,
                            &ProblemShape::new(k, d * 4, g, tile));
        if wide.bram_18k < base.bram_18k {
            return Err("d x4 shrank BRAM".into());
        }
        Ok(())
    });
}

#[test]
fn bound_updates_remain_conservative() {
    // Simulate bound drift arithmetic against explicitly-moved points and
    // verify filter_safe never lies: if it says "skip", the true nearest
    // centroid must still be the assigned one.
    use kpynq::util::matrix::{dist, Matrix};
    run_cases("drifted bounds stay safe", 6, |rng| {
        let d = 1 + rng.next_below(8);
        let k = 2 + rng.next_below(6);
        // A point, k centroids, then all centroids move by random drifts.
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut cents = vec![0.0f32; k * d];
        for v in cents.iter_mut() {
            *v = rng.normal_f32(0.0, 2.0);
        }
        let c0 = Matrix::from_vec(cents.clone(), k, d).unwrap();
        // Exact bounds at time 0.
        let mut best = f32::INFINITY;
        let mut second = f32::INFINITY;
        let mut a = 0usize;
        for c in 0..k {
            let dd = dist(&x, c0.row(c));
            if dd < best {
                second = best;
                best = dd;
                a = c;
            } else if dd < second {
                second = dd;
            }
        }
        // Move centroids.
        let mut moved = cents;
        for v in moved.iter_mut() {
            *v += rng.normal_f32(0.0, 0.3);
        }
        let c1 = Matrix::from_vec(moved, k, d).unwrap();
        let drifts: Vec<f32> = (0..k).map(|c| dist(c0.row(c), c1.row(c))).collect();
        let max_drift = drifts.iter().cloned().fold(0.0, f32::max);
        let ub = inflate_ub(best, drifts[a]);
        let lb = deflate_lb(second, max_drift);
        if filter_safe(lb, ub) {
            // The filter claims assignment cannot change: verify exactly.
            let mut true_best = f32::INFINITY;
            let mut true_a = 0usize;
            for c in 0..k {
                let dd = dist(&x, c1.row(c));
                if dd < true_best {
                    true_best = dd;
                    true_a = c;
                }
            }
            if true_a != a {
                return Err(format!(
                    "filter lied: said keep {a}, truth is {true_a} (ub {ub}, lb {lb})"
                ));
            }
        }
        // Group drift helper must dominate each member's drift.
        let groups: Vec<usize> = (0..k).map(|_| rng.next_below(3)).collect();
        let gd = group_max_drifts(&drifts, &groups, 3);
        for c in 0..k {
            if gd[groups[c]] < drifts[c] {
                return Err("group drift below member drift".into());
            }
        }
        Ok(())
    });
}
