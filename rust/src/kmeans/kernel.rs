//! The tiled points×centroids distance micro-kernel — the one home of all
//! point↔centroid distance arithmetic (DESIGN.md §5, "distance kernel
//! contract").
//!
//! The paper's whole premise (PAPER.md) is that the distance stage is the
//! part worth accelerating: KPynq streams point tiles through a P-lane ×
//! 8-wide MAC-tree pipeline while the multi-level filter decides which
//! distances are worth computing at all. This module is the software
//! mirror of that pipeline: every algorithm variant (`lloyd`, `hamerly`,
//! `elkan`, `yinyang`), the k-means++ seeding scan, the coordinator's
//! shard slices and the engine/accelerator backends call these batch APIs
//! instead of hand-rolling their own loops over `util::matrix::sq_dist`.
//!
//! # Contract (normative — DESIGN.md §5)
//!
//! * **Bit-exactness.** Every API except [`sq_dist_block_norms`] produces
//!   bit-identical results to the naive "for each point, for each centroid
//!   in ascending order, `sq_dist`" loop, for *any* tile size. Tiling
//!   iterates both axes in ascending order and each (point, centroid) pair
//!   is computed by the same scalar `sq_dist` reduction, so the per-point
//!   visit order — and therefore every strict-`<` argmin/second-best
//!   update — is unchanged. `rust/tests/kernel_equivalence.rs` pins this
//!   across every tile-boundary shape.
//! * **Accounting.** Batch APIs return the exact number of distance
//!   computations performed as a `u64`; a tile that computes `t` distances
//!   reports exactly `t`. Callers feed these counts into
//!   `metrics::IterStats` unmodified — the work-efficiency story survives
//!   the batch seam byte for byte.
//! * **The algebraic form is opt-in.** `‖x‖² + ‖c‖² − 2x·c` (via
//!   [`row_sq_norms`]) trades the subtract-then-square reduction for a dot
//!   product and changes bits (catastrophic cancellation near 0). It is
//!   allowed only where the caller tolerates approximate distances (bench
//!   baselines, approximate scoring) and never in a fit path; the exact
//!   `sq_dist` tiling is the normative fallback.
//!
//! Tile sizes: [`TILE_POINTS`] keeps a point tile's rows plus a centroid
//! tile resident in L1/L2 across the centroid sweep; [`TILE_CENTROIDS`]
//! matches the 8-wide lane shape of `util::matrix::sq_dist` (and the
//! FPGA MAC tree) so a `std::simd`/intrinsics drop-in later can hold eight
//! running distances in one vector register.

use crate::util::matrix::{sq_dist, Matrix};

/// Points per tile: 32 rows of typical `d` keep the tile plus a centroid
/// block L1-resident while the centroid axis is swept.
pub const TILE_POINTS: usize = 32;

/// Centroids per tile: matches the 8-lane accumulation shape of
/// `util::matrix::sq_dist` (one future `f32x8` register of running bests).
pub const TILE_CENTROIDS: usize = 8;

/// Scan all centroids for one point; returns (argmin, best d², second d²).
/// Ties break to the lowest index (strict `<`), matching the Pallas kernel
/// and the oracle. The batch APIs below produce bit-identical results to
/// repeating this scan per point; it remains public as the scalar
/// reference scan for external engines and the fixed-point fidelity test.
#[inline]
pub fn scan_all(point: &[f32], centroids: &Matrix) -> (usize, f32, f32) {
    let mut best = f32::INFINITY;
    let mut second = f32::INFINITY;
    let mut arg = 0usize;
    for c in 0..centroids.rows() {
        let d2 = sq_dist(point, centroids.row(c));
        if d2 < best {
            second = best;
            best = d2;
            arg = c;
        } else if d2 < second {
            second = d2;
        }
    }
    (arg, best, second)
}

/// Exact squared distance between one point and one centroid — the same
/// scalar reduction the tiled paths use. Single-pair escape hatch for the
/// filtered algorithms' tighten steps (one distance, data-dependent),
/// where batching has nothing to amortise.
#[inline]
pub fn sq_dist_pair(point: &[f32], centroid: &[f32]) -> f32 {
    sq_dist(point, centroid)
}

/// Exact Euclidean distance for one (point, centroid) pair:
/// `sq_dist_pair(..).sqrt()`.
#[inline]
pub fn dist_pair(point: &[f32], centroid: &[f32]) -> f32 {
    sq_dist(point, centroid).sqrt()
}

/// Result of a batched nearest/second-nearest scan over a point range.
#[derive(Clone, Debug)]
pub struct NearestScan {
    /// Argmin centroid per point (ties to the lowest index).
    pub idx: Vec<u32>,
    /// Best squared distance per point.
    pub best: Vec<f32>,
    /// Second-best squared distance per point (`+inf` when `k == 1`).
    pub second: Vec<f32>,
    /// Exact number of distance computations performed (`n·k`).
    pub dist_comps: u64,
}

/// Batched [`scan_all`] over every row of `points` with the default tile
/// sizes. Bit-identical to the per-row scalar scan; `dist_comps` is
/// exactly `points.rows() · centroids.rows()`.
pub fn nearest_full_scan(points: &Matrix, centroids: &Matrix) -> NearestScan {
    let n = points.rows();
    let mut idx = vec![0u32; n];
    let mut best = vec![0.0f32; n];
    let mut second = vec![0.0f32; n];
    let dist_comps = nearest_into(points, 0, n, centroids, &mut idx, &mut best, &mut second);
    NearestScan { idx, best, second, dist_comps }
}

/// Tiled nearest/second-nearest scan over `points[lo..hi]`, writing into
/// caller-owned buffers (index 0 of each buffer corresponds to point `lo`)
/// so iterative fits can reuse their allocations. Returns the exact
/// distance-computation count, `(hi-lo) · k`.
pub fn nearest_into(
    points: &Matrix,
    lo: usize,
    hi: usize,
    centroids: &Matrix,
    idx: &mut [u32],
    best: &mut [f32],
    second: &mut [f32],
) -> u64 {
    nearest_into_tiled(points, lo, hi, centroids, TILE_POINTS, TILE_CENTROIDS, idx, best, second)
}

/// [`nearest_into`] with explicit tile sizes — the property tests sweep
/// these to prove the results are tile-size independent; production call
/// sites use the defaults via `nearest_into`.
#[allow(clippy::too_many_arguments)]
pub fn nearest_into_tiled(
    points: &Matrix,
    lo: usize,
    hi: usize,
    centroids: &Matrix,
    tile_points: usize,
    tile_centroids: usize,
    idx: &mut [u32],
    best: &mut [f32],
    second: &mut [f32],
) -> u64 {
    let nn = hi - lo;
    let k = centroids.rows();
    assert!(lo <= hi && hi <= points.rows(), "point range out of bounds");
    assert_eq!(points.cols(), centroids.cols(), "dimension mismatch");
    assert_eq!(idx.len(), nn);
    assert_eq!(best.len(), nn);
    assert_eq!(second.len(), nn);
    assert!(tile_points > 0 && tile_centroids > 0, "tile sizes must be positive");

    best[..nn].fill(f32::INFINITY);
    second[..nn].fill(f32::INFINITY);
    idx[..nn].fill(0);

    let mut comps = 0u64;
    let mut p0 = 0usize;
    while p0 < nn {
        let p1 = (p0 + tile_points).min(nn);
        // Sweep the centroid axis in ascending tiles: each point's running
        // (best, second, arg) sees centroids in the same order as a flat
        // scan, so strict-`<` updates are bit-identical for any tiling.
        let mut c0 = 0usize;
        while c0 < k {
            let c1 = (c0 + tile_centroids).min(k);
            for j in p0..p1 {
                let row = points.row(lo + j);
                let mut b = best[j];
                let mut s = second[j];
                let mut a = idx[j];
                for c in c0..c1 {
                    let d2 = sq_dist(row, centroids.row(c));
                    if d2 < b {
                        s = b;
                        b = d2;
                        a = c as u32;
                    } else if d2 < s {
                        s = d2;
                    }
                }
                best[j] = b;
                second[j] = s;
                idx[j] = a;
            }
            comps += ((p1 - p0) * (c1 - c0)) as u64;
            c0 = c1;
        }
        p0 = p1;
    }
    comps
}

/// Rectangular tile of exact squared distances: `out[(i-lo)*k + c] =
/// sq_dist(points[i], centroids[c])` for `i` in `lo..hi`. What Elkan's
/// bound initialisation and yinyang's group scans consume. Returns the
/// exact count, `(hi-lo) · k`.
pub fn sq_dist_block(
    points: &Matrix,
    lo: usize,
    hi: usize,
    centroids: &Matrix,
    out: &mut [f32],
) -> u64 {
    sq_dist_block_tiled(points, lo, hi, centroids, TILE_POINTS, TILE_CENTROIDS, out)
}

/// [`sq_dist_block`] with explicit tile sizes (swept by the equivalence
/// battery; every entry is an independent `sq_dist`, so tiling cannot
/// change bits regardless of order — asserted anyway).
pub fn sq_dist_block_tiled(
    points: &Matrix,
    lo: usize,
    hi: usize,
    centroids: &Matrix,
    tile_points: usize,
    tile_centroids: usize,
    out: &mut [f32],
) -> u64 {
    let nn = hi - lo;
    let k = centroids.rows();
    assert!(lo <= hi && hi <= points.rows(), "point range out of bounds");
    assert_eq!(points.cols(), centroids.cols(), "dimension mismatch");
    assert_eq!(out.len(), nn * k, "output tile shape mismatch");
    assert!(tile_points > 0 && tile_centroids > 0, "tile sizes must be positive");

    let mut comps = 0u64;
    let mut p0 = 0usize;
    while p0 < nn {
        let p1 = (p0 + tile_points).min(nn);
        let mut c0 = 0usize;
        while c0 < k {
            let c1 = (c0 + tile_centroids).min(k);
            for j in p0..p1 {
                let row = points.row(lo + j);
                let orow = &mut out[j * k..(j + 1) * k];
                for c in c0..c1 {
                    orow[c] = sq_dist(row, centroids.row(c));
                }
            }
            comps += ((p1 - p0) * (c1 - c0)) as u64;
            c0 = c1;
        }
        p0 = p1;
    }
    comps
}

/// One column of exact squared distances: `out[i] = sq_dist(points[i],
/// target)` for every row. The k-means++ D² update (and the centroid
/// grouping seed scan) is exactly this shape. Returns `points.rows()`.
pub fn sq_dists_to(points: &Matrix, target: &[f32], out: &mut [f32]) -> u64 {
    let n = points.rows();
    assert_eq!(points.cols(), target.len(), "dimension mismatch");
    assert_eq!(out.len(), n);
    for (o, row) in out.iter_mut().zip(points.rows_iter()) {
        *o = sq_dist(row, target);
    }
    n as u64
}

/// Per-row squared norms `‖r‖²`, accumulated with the same 8-lane
/// reduction shape as `sq_dist`. Precompute these for the centroid set to
/// feed [`sq_dist_block_norms`].
pub fn row_sq_norms(m: &Matrix) -> Vec<f32> {
    m.rows_iter().map(|r| sq_dist(r, &vec![0.0f32; r.len()])).collect()
}

/// Algebraic-form distance tile: `‖x‖² + ‖c‖² − 2x·c` with `c_norms`
/// precomputed by [`row_sq_norms`], clamped at zero.
///
/// **Not bit-exact** — the cancellation `‖x‖² + ‖c‖² − 2x·c` loses
/// low-order bits exactly where distances are small, which is where argmin
/// decisions happen. Per the kernel contract (DESIGN.md §5) this path is
/// opt-in for approximate consumers only (bench baselines, approximate
/// scoring); fit paths must use the exact [`sq_dist_block`] fallback.
/// Returns the exact count, `(hi-lo) · k` — accounting stays truthful even
/// on the approximate path.
pub fn sq_dist_block_norms(
    points: &Matrix,
    lo: usize,
    hi: usize,
    centroids: &Matrix,
    c_norms: &[f32],
    out: &mut [f32],
) -> u64 {
    let nn = hi - lo;
    let k = centroids.rows();
    let d = points.cols();
    assert!(lo <= hi && hi <= points.rows(), "point range out of bounds");
    assert_eq!(d, centroids.cols(), "dimension mismatch");
    assert_eq!(c_norms.len(), k, "one precomputed norm per centroid");
    assert_eq!(out.len(), nn * k, "output tile shape mismatch");

    for j in 0..nn {
        let row = points.row(lo + j);
        // ‖x‖² with the same lane shape as sq_dist.
        let mut lanes = [0.0f32; 8];
        let ca = row.chunks_exact(8);
        let rem = ca.remainder();
        for xa in ca {
            let xa: &[f32; 8] = xa.try_into().unwrap();
            for l in 0..8 {
                lanes[l] += xa[l] * xa[l];
            }
        }
        let mut tail = 0.0f32;
        for &x in rem {
            tail += x * x;
        }
        let x_norm = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
            + tail;
        let orow = &mut out[j * k..(j + 1) * k];
        for (c, o) in orow.iter_mut().enumerate() {
            let crow = centroids.row(c);
            let mut dot_lanes = [0.0f32; 8];
            let cx = row.chunks_exact(8);
            let cc = crow.chunks_exact(8);
            let (rx, rc) = (cx.remainder(), cc.remainder());
            for (xa, xb) in cx.zip(cc) {
                let xa: &[f32; 8] = xa.try_into().unwrap();
                let xb: &[f32; 8] = xb.try_into().unwrap();
                for l in 0..8 {
                    dot_lanes[l] += xa[l] * xb[l];
                }
            }
            let mut dot_tail = 0.0f32;
            for (x, y) in rx.iter().zip(rc) {
                dot_tail += x * y;
            }
            let dot = ((dot_lanes[0] + dot_lanes[1]) + (dot_lanes[2] + dot_lanes[3]))
                + ((dot_lanes[4] + dot_lanes[5]) + (dot_lanes[6] + dot_lanes[7]))
                + dot_tail;
            *o = (x_norm + c_norms[c] - 2.0 * dot).max(0.0);
        }
    }
    (nn as u64) * (k as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn random_instance(n: usize, d: usize, k: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cts: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        (Matrix::from_vec(pts, n, d).unwrap(), Matrix::from_vec(cts, k, d).unwrap())
    }

    #[test]
    fn scan_all_finds_best_and_second() {
        let c = Matrix::from_vec(vec![0.0, 0.0, 1.0, 0.0, 5.0, 0.0], 3, 2).unwrap();
        let (arg, best, second) = scan_all(&[0.9, 0.0], &c);
        assert_eq!(arg, 1);
        assert!((best - 0.01).abs() < 1e-6);
        assert!((second - 0.81).abs() < 1e-6);
    }

    #[test]
    fn scan_all_tie_breaks_low_index() {
        let c = Matrix::from_vec(vec![1.0, 0.0, -1.0, 0.0], 2, 2).unwrap();
        let (arg, _, _) = scan_all(&[0.0, 0.0], &c);
        assert_eq!(arg, 0);
    }

    #[test]
    fn batch_matches_scalar_scan_bit_for_bit() {
        for &(n, d, k) in &[(1, 1, 1), (33, 7, 9), (67, 8, 8), (31, 9, 7)] {
            let (pts, cts) = random_instance(n, d, k, 0xA11CE ^ (n * d * k) as u64);
            let scan = nearest_full_scan(&pts, &cts);
            assert_eq!(scan.dist_comps, (n as u64) * (k as u64));
            for i in 0..n {
                let (arg, best, second) = scan_all(pts.row(i), &cts);
                assert_eq!(scan.idx[i], arg as u32, "n={n} d={d} k={k} i={i}");
                assert_eq!(scan.best[i].to_bits(), best.to_bits());
                assert_eq!(scan.second[i].to_bits(), second.to_bits());
            }
        }
    }

    #[test]
    fn tiling_is_result_invariant() {
        let (pts, cts) = random_instance(67, 9, 13, 42);
        let reference = nearest_full_scan(&pts, &cts);
        for &(tp, tc) in &[(1, 1), (2, 3), (31, 7), (32, 8), (33, 9), (100, 100)] {
            let mut idx = vec![0u32; 67];
            let mut best = vec![0.0f32; 67];
            let mut second = vec![0.0f32; 67];
            let comps =
                nearest_into_tiled(&pts, 0, 67, &cts, tp, tc, &mut idx, &mut best, &mut second);
            assert_eq!(comps, reference.dist_comps, "tp={tp} tc={tc}");
            assert_eq!(idx, reference.idx, "tp={tp} tc={tc}");
            assert_eq!(
                best.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                reference.best.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                second.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                reference.second.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn block_matches_pairwise_sq_dist() {
        let (pts, cts) = random_instance(33, 5, 9, 7);
        let mut out = vec![0.0f32; 33 * 9];
        let comps = sq_dist_block(&pts, 0, 33, &cts, &mut out);
        assert_eq!(comps, 33 * 9);
        for i in 0..33 {
            for c in 0..9 {
                let want = sq_dist(pts.row(i), cts.row(c));
                assert_eq!(out[i * 9 + c].to_bits(), want.to_bits(), "i={i} c={c}");
            }
        }
    }

    #[test]
    fn sub_range_indexes_from_lo() {
        let (pts, cts) = random_instance(20, 4, 3, 11);
        let mut out = vec![0.0f32; 5 * 3];
        sq_dist_block(&pts, 7, 12, &cts, &mut out);
        for j in 0..5 {
            for c in 0..3 {
                let want = sq_dist(pts.row(7 + j), cts.row(c));
                assert_eq!(out[j * 3 + c].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn column_matches_pairwise_sq_dist() {
        let (pts, cts) = random_instance(29, 6, 4, 3);
        let mut col = vec![0.0f32; 29];
        let comps = sq_dists_to(&pts, cts.row(2), &mut col);
        assert_eq!(comps, 29);
        for i in 0..29 {
            let want = sq_dist(pts.row(i), cts.row(2));
            assert_eq!(col[i].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn k1_second_best_is_infinite() {
        let (pts, cts) = random_instance(10, 3, 1, 5);
        let scan = nearest_full_scan(&pts, &cts);
        assert!(scan.second.iter().all(|s| s.is_infinite()));
        assert!(scan.idx.iter().all(|&i| i == 0));
    }

    #[test]
    fn norms_path_is_close_but_only_advisory() {
        let ds = synth::blobs(120, 6, 3, 9);
        let cts = ds.points.gather_rows(&[0, 40, 80]);
        let norms = row_sq_norms(&cts);
        let mut approx = vec![0.0f32; 120 * 3];
        let comps = sq_dist_block_norms(&ds.points, 0, 120, &cts, &norms, &mut approx);
        assert_eq!(comps, 120 * 3, "accounting is exact even on the approximate path");
        let mut exact = vec![0.0f32; 120 * 3];
        sq_dist_block(&ds.points, 0, 120, &cts, &mut exact);
        for (i, (&a, &e)) in approx.iter().zip(&exact).enumerate() {
            assert!(a >= 0.0, "clamped at zero");
            assert!((a - e).abs() <= 1e-3 * e.max(1.0), "entry {i}: {a} vs {e}");
        }
        // On a well-separated fixture the approximate argmin still agrees.
        for i in 0..120 {
            let arow = &approx[i * 3..(i + 1) * 3];
            let erow = &exact[i * 3..(i + 1) * 3];
            let aa = arow.iter().enumerate().min_by(|x, y| x.1.partial_cmp(y.1).unwrap()).unwrap().0;
            let ea = erow.iter().enumerate().min_by(|x, y| x.1.partial_cmp(y.1).unwrap()).unwrap().0;
            assert_eq!(aa, ea, "point {i}");
        }
    }

    #[test]
    fn row_sq_norms_match_self_distance_to_origin() {
        let (pts, _) = random_instance(17, 11, 1, 13);
        let norms = row_sq_norms(&pts);
        for i in 0..17 {
            let origin = vec![0.0f32; 11];
            assert_eq!(norms[i].to_bits(), sq_dist(pts.row(i), &origin).to_bits());
        }
    }
}
