//! Centroid initialisation: random distinct points and k-means++.
//!
//! Both are deterministic in `cfg.seed`. Every algorithm (and the
//! accelerated coordinator path) initialises through this module, so any
//! two runs with the same config start from bit-identical centroids — the
//! foundation of the cross-algorithm equivalence tests.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::kernel;
use crate::kmeans::{InitMethod, KMeansConfig};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Initialise centroids per the config.
pub fn initialize(ds: &Dataset, cfg: &KMeansConfig) -> Result<Matrix> {
    cfg.validate(ds.n())?;
    let mut rng = Rng::new(cfg.seed);
    Ok(match cfg.init {
        InitMethod::RandomPoints => random_points(ds, cfg.k, &mut rng),
        InitMethod::KMeansPlusPlus => kmeans_pp(ds, cfg.k, &mut rng),
    })
}

/// k distinct points chosen uniformly.
pub fn random_points(ds: &Dataset, k: usize, rng: &mut Rng) -> Matrix {
    // Partial Fisher–Yates over the index range: O(n) memory, O(k) swaps.
    let n = ds.n();
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.next_below(n - i);
        idx.swap(i, j);
    }
    ds.points.gather_rows(&idx[..k])
}

/// k-means++: D² weighted seeding (Arthur & Vassilvitskii 2007). The D²
/// scan against each new centroid is one kernel column
/// (`kernel::sq_dists_to`) — element-wise the same `sq_dist` values the
/// old per-point loop produced, so seeding stays bit-identical.
pub fn kmeans_pp(ds: &Dataset, k: usize, rng: &mut Rng) -> Matrix {
    let n = ds.n();
    let d = ds.d();
    let mut centroids = Matrix::zeros(k, d);

    // First centroid: uniform.
    let first = rng.next_below(n);
    centroids.row_mut(0).copy_from_slice(ds.points.row(first));

    // Maintain the running min squared distance to the chosen set.
    let mut col = vec![0.0f32; n];
    kernel::sq_dists_to(&ds.points, centroids.row(0), &mut col);
    let mut min_d2: Vec<f64> = col.iter().map(|&v| v as f64).collect();

    for c in 1..k {
        let pick = rng.sample_weighted(&min_d2);
        centroids.row_mut(c).copy_from_slice(ds.points.row(pick));
        if c + 1 < k {
            kernel::sq_dists_to(&ds.points, centroids.row(c), &mut col);
            for (m, &v) in min_d2.iter_mut().zip(&col) {
                let d2 = v as f64;
                if d2 < *m {
                    *m = d2;
                }
            }
        }
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::InitMethod;

    fn cfg(k: usize, init: InitMethod, seed: u64) -> KMeansConfig {
        KMeansConfig { k, init, seed, ..Default::default() }
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = synth::blobs(500, 6, 4, 3);
        for init in [InitMethod::RandomPoints, InitMethod::KMeansPlusPlus] {
            let a = initialize(&ds, &cfg(5, init, 7)).unwrap();
            let b = initialize(&ds, &cfg(5, init, 7)).unwrap();
            assert_eq!(a, b);
            let c = initialize(&ds, &cfg(5, init, 8)).unwrap();
            assert_ne!(a, c);
        }
    }

    #[test]
    fn centroids_are_dataset_points() {
        let ds = synth::blobs(200, 5, 3, 1);
        for init in [InitMethod::RandomPoints, InitMethod::KMeansPlusPlus] {
            let c = initialize(&ds, &cfg(8, init, 5)).unwrap();
            for r in 0..8 {
                assert!(
                    (0..ds.n()).any(|i| ds.points.row(i) == c.row(r)),
                    "centroid {r} is not a dataset point"
                );
            }
        }
    }

    #[test]
    fn random_points_are_distinct_indices() {
        // With distinct data points, the k chosen rows must be distinct.
        let ds = synth::blobs(100, 4, 2, 9);
        let c = initialize(&ds, &cfg(10, InitMethod::RandomPoints, 3)).unwrap();
        for a in 0..10 {
            for b in (a + 1)..10 {
                assert_ne!(c.row(a), c.row(b), "duplicate centroid {a}/{b}");
            }
        }
    }

    #[test]
    fn kmeanspp_spreads_over_blobs() {
        // With 4 well-separated blobs and k=4, k-means++ should (almost
        // always) pick one seed per blob. Use the ground-truth labels.
        let ds = synth::blobs(400, 8, 4, 11);
        let c = initialize(&ds, &cfg(4, InitMethod::KMeansPlusPlus, 1)).unwrap();
        let labels = ds.labels.as_ref().unwrap();
        let mut hit = [false; 4];
        for r in 0..4 {
            let i = (0..ds.n()).find(|&i| ds.points.row(i) == c.row(r)).unwrap();
            hit[labels[i] as usize] = true;
        }
        assert!(hit.iter().all(|&h| h), "seeds missed a blob: {hit:?}");
    }

    #[test]
    fn k_equals_n_takes_every_point() {
        let ds = synth::blobs(6, 3, 2, 2);
        let c = initialize(&ds, &cfg(6, InitMethod::RandomPoints, 1)).unwrap();
        for i in 0..6 {
            assert!((0..6).any(|r| c.row(r) == ds.points.row(i)));
        }
    }
}
