//! Standard (Lloyd's) K-means — the paper's CPU baseline.
//!
//! "Optimized CPU-based standard K-means" in the paper's terms: the
//! assignment pass is one call into the tiled distance micro-kernel
//! (`kmeans::kernel`, DESIGN.md §5), which cache-blocks over points and
//! centroids and keeps the 8-lane accumulation LLVM auto-vectorises. It
//! performs exactly `n·k` distance computations per iteration — the
//! yardstick the filtered algorithms are measured against — and the
//! kernel's returned count is what lands in `IterStats`, not a recomputed
//! product.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::kernel;
use crate::kmeans::{
    centroid_drifts, compute_inertia, metrics::IterStats, recompute_centroids, FitResult,
    KMeansConfig, RunStats,
};
use crate::obs::profile::{Phase, PhaseTimer};
use crate::util::matrix::Matrix;

// The scalar reference scan lived here before the kernel module existed;
// re-exported so external callers (engines, benches, fidelity tests) keep
// their `lloyd::scan_all` path.
pub use crate::kmeans::kernel::scan_all;

/// Fit with Lloyd's algorithm from explicit initial centroids.
pub fn fit(ds: &Dataset, cfg: &KMeansConfig, init: Matrix) -> Result<FitResult> {
    let n = ds.n();
    let mut centroids = init;
    let mut assignments = vec![0u32; n];
    // Reused kernel output buffers (best/second are Lloyd's by-product).
    let mut idx = vec![0u32; n];
    let mut best = vec![0.0f32; n];
    let mut second = vec![0.0f32; n];
    let mut stats = RunStats::default();
    let mut converged = false;
    let mut iterations = 0;
    // Per-phase wall clock (obs::profile): a no-op unless profiling is
    // enabled; touches nothing the fit reads, so results are
    // bit-identical either way (DESIGN.md §2).
    let mut timer = PhaseTimer::new();

    for _ in 0..cfg.max_iters {
        iterations += 1;
        let mut it = IterStats::default();

        // Assignment step: full scan (n·k distances by definition).
        timer.enter(Phase::Assign);
        let comps =
            kernel::nearest_into(&ds.points, 0, n, &centroids, &mut idx, &mut best, &mut second);
        let mut reassigned = 0u64;
        for (i, &arg) in idx.iter().enumerate() {
            if assignments[i] != arg {
                reassigned += 1;
                assignments[i] = arg;
            }
        }
        debug_assert_eq!(comps, (n as u64) * (cfg.k as u64));
        it.dist_comps = comps;
        it.reassigned = reassigned;
        it.survivors = n as u64;

        // Update step.
        timer.enter(Phase::Update);
        let (new_centroids, _counts) = recompute_centroids(ds, &assignments, &centroids);
        let (_, max_drift) = centroid_drifts(&centroids, &new_centroids);
        centroids = new_centroids;
        it.max_drift = max_drift;
        stats.push(it);
        timer.exit();

        if (max_drift as f64) <= cfg.tol {
            converged = true;
            break;
        }
    }

    stats.phases = timer.totals();
    let inertia = compute_inertia(ds, &centroids, &assignments);
    Ok(FitResult { centroids, assignments, inertia, iterations, converged, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, InitMethod};

    fn cfg(k: usize) -> KMeansConfig {
        KMeansConfig { k, seed: 42, init: InitMethod::KMeansPlusPlus, ..Default::default() }
    }

    fn run(ds: &Dataset, cfg: &KMeansConfig) -> FitResult {
        let c0 = init::initialize(ds, cfg).unwrap();
        fit(ds, cfg, c0).unwrap()
    }

    // `scan_all`'s own unit tests moved to `kernel::tests` with the
    // implementation; `inertia_decreases_monotonically` below still calls
    // it through this module's re-export, keeping that path covered.

    #[test]
    fn recovers_separated_blobs() {
        let ds = synth::blobs(600, 6, 4, 5);
        let r = run(&ds, &cfg(4));
        assert!(r.converged, "should converge on easy blobs");
        // Clustering must match ground truth up to a relabelling.
        let labels = ds.labels.as_ref().unwrap();
        let mut map = [usize::MAX; 4];
        for i in 0..ds.n() {
            let a = r.assignments[i] as usize;
            let l = labels[i] as usize;
            if map[l] == usize::MAX {
                map[l] = a;
            }
            assert_eq!(map[l], a, "label {l} split across clusters");
        }
    }

    #[test]
    fn inertia_decreases_monotonically() {
        let ds = synth::blobs(400, 5, 3, 7);
        let c0 = init::initialize(&ds, &cfg(3)).unwrap();
        // Re-run manually tracking inertia per iteration.
        let mut centroids = c0;
        let mut last = f64::INFINITY;
        for _ in 0..8 {
            let mut assignments = vec![0u32; ds.n()];
            let mut inertia = 0.0f64;
            for (i, row) in ds.points.rows_iter().enumerate() {
                let (arg, best, _) = scan_all(row, &centroids);
                assignments[i] = arg as u32;
                inertia += best as f64;
            }
            assert!(inertia <= last * (1.0 + 1e-6), "{inertia} > {last}");
            last = inertia;
            let (nc, _) = recompute_centroids(&ds, &assignments, &centroids);
            centroids = nc;
        }
    }

    #[test]
    fn dist_comps_are_exactly_nk_per_iter() {
        let ds = synth::blobs(300, 4, 3, 9);
        let r = run(&ds, &cfg(3));
        for it in &r.stats.iters {
            assert_eq!(it.dist_comps, 300 * 3);
        }
        assert!((r.stats.work_ratio(300, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn k_equals_one_converges_to_mean() {
        let ds = synth::blobs(128, 3, 2, 4);
        let r = run(&ds, &cfg(1));
        assert!(r.converged);
        let mut mean = vec![0.0f64; 3];
        for row in ds.points.rows_iter() {
            for (m, &v) in mean.iter_mut().zip(row) {
                *m += v as f64;
            }
        }
        for (j, m) in mean.iter().enumerate() {
            let want = (m / ds.n() as f64) as f32;
            assert!((r.centroids.row(0)[j] - want).abs() < 1e-3);
        }
    }

    #[test]
    fn max_iters_respected_without_convergence() {
        // tol = 0 forces running until drift is exactly 0 or the cap hits.
        let ds = synth::uniform(500, 8, 3);
        let cfg = KMeansConfig { k: 7, max_iters: 3, tol: 0.0, seed: 1, ..Default::default() };
        let r = run(&ds, &cfg);
        assert!(r.iterations <= 3);
    }
}
