//! Triangle-inequality bound arithmetic shared by the filtered algorithms.
//!
//! All bounds live in *distance* (not squared-distance) space, because the
//! triangle inequality only composes there:
//!
//! * after centroid `c` moves by `δ_c`, any distance `d(x, c)` changes by at
//!   most `δ_c`, so an upper bound grows by `δ_{a(x)}` and a lower bound
//!   shrinks by the relevant max drift;
//! * a point can be skipped when `lb ≥ ub` — its assignment provably cannot
//!   change.
//!
//! Float safety: computed distances carry relative rounding error, so a raw
//! `lb >= ub` test could filter a point whose true lower bound is a hair
//! *below* its true upper bound — an incorrect result, not just wasted
//! work. [`filter_safe`] therefore demands a small relative margin; rounding
//! can only ever cause extra distance computations. The margin is sized (a
//! few ulps at f32) so the equivalence property (`filtered == lloyd`) holds
//! on everything the test suite throws at it.

/// Relative safety margin for bound comparisons.
pub const SAFETY_REL: f32 = 1e-5;
/// Absolute safety floor (guards the `ub == lb == 0` case).
pub const SAFETY_ABS: f32 = 1e-12;

/// True iff `lb >= ub` is certain even under f32 rounding — i.e. it is safe
/// to skip the candidate(s) guarded by `lb`.
#[inline]
pub fn filter_safe(lb: f32, ub: f32) -> bool {
    lb >= ub + SAFETY_REL * ub.abs() + SAFETY_ABS
}

/// Apply the post-update drift to an upper bound (assigned centroid moved).
#[inline]
pub fn inflate_ub(ub: f32, drift_of_assigned: f32) -> f32 {
    ub + drift_of_assigned
}

/// Apply the post-update drift to a lower bound (any guarded centroid may
/// have moved toward the point). Clamped at zero: distances are
/// non-negative, and negative lower bounds would poison later max() logic.
#[inline]
pub fn deflate_lb(lb: f32, max_drift: f32) -> f32 {
    (lb - max_drift).max(0.0)
}

/// Per-group maximum drift (the group filter's deflation amount).
pub fn group_max_drifts(drifts: &[f32], group_of: &[usize], n_groups: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_groups];
    for (c, &g) in group_of.iter().enumerate() {
        if drifts[c] > out[g] {
            out[g] = drifts[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_requires_margin() {
        assert!(filter_safe(1.1, 1.0));
        assert!(!filter_safe(1.0, 1.0), "exact equality must NOT filter");
        assert!(!filter_safe(0.0, 0.0));
        assert!(!filter_safe(1.0 + 1e-7, 1.0), "inside the margin must not filter");
        assert!(filter_safe(2.0, 0.0));
    }

    #[test]
    fn bound_updates_compose() {
        let ub = inflate_ub(1.0, 0.25);
        assert_eq!(ub, 1.25);
        let lb = deflate_lb(0.1, 0.5);
        assert_eq!(lb, 0.0, "lower bounds clamp at zero");
        assert_eq!(deflate_lb(2.0, 0.5), 1.5);
    }

    #[test]
    fn group_drifts_take_max_per_group() {
        let drifts = [0.1, 0.9, 0.3, 0.2];
        let groups = [0, 1, 0, 1];
        let gd = group_max_drifts(&drifts, &groups, 2);
        assert_eq!(gd, vec![0.3, 0.9]);
    }

    #[test]
    fn empty_group_has_zero_drift() {
        let gd = group_max_drifts(&[0.5], &[1], 3);
        assert_eq!(gd, vec![0.0, 0.5, 0.0]);
    }
}
