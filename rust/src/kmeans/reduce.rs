//! Exact, order-independent reduction primitives for distributed K-means.
//!
//! The map-reduce cluster mode (PROTOCOL.md §10) splits one fit's points
//! across shards and reduces per-cluster partial sums on the front. For
//! the distributed fit to be **bit-identical** to the solo fit — the
//! contract `rust/tests/mapreduce.rs` enforces — the reduction must not
//! depend on the order addends arrive in, which rules out floating-point
//! running sums (`(a + b) + c != a + (b + c)` in f64). [`ExactSum`] is a
//! fixed-point superaccumulator: a 320-bit signed integer in base 2^32
//! limbs spanning binary weights 2^-160 .. 2^160, wide enough to hold any
//! finite `f32` addend (subnormals included) *exactly*. Integer addition
//! is associative and commutative, and [`ExactSum::value`] reads the
//! canonical normalized form, so any partition of the addends over any
//! number of shards merges to the same bits as the sequential sum.
//!
//! [`PartialAccumulator`] packages the per-cluster `k*d` coordinate sums
//! plus member counts — the thing a shard computes over its slice and the
//! front merges — and owns the empty-cluster guard: a cluster (or a whole
//! shard slice) with zero members contributes zero sums/counts and the
//! finalize step keeps the previous centroid row instead of dividing by
//! zero into NaN.
//!
//! The solo path (`kmeans::recompute_centroids` / `compute_inertia`) is
//! built on these same primitives, so "solo" and "distributed over N
//! shards" are literally the same arithmetic.
//!
//! The hex codecs at the bottom are the wire forms PROTOCOL.md §10 uses:
//! JSON float printing does not round-trip f32 bits, so centroids,
//! partial sums and assignment vectors cross the wire as fixed-width
//! little-endian hex strings instead.

use crate::error::{Error, Result};
use crate::util::matrix::Matrix;

/// Limb count: 10 base-2^32 digits = 320 bits.
const LIMBS: usize = 10;
/// Binary weight of bit 0 of limb 0 is 2^-BIAS.
const BIAS: i32 = 160;
/// Normalize after this many raw adds so limb magnitudes stay far from
/// i64 overflow (each add deposits < 2^33 per limb; 2^24 * 2^33 << 2^63).
const NORMALIZE_EVERY: u32 = 1 << 24;

/// A 320-bit fixed-point superaccumulator for finite `f32` addends.
///
/// Limb `i` carries binary weights `2^(32*i - 160) ..= 2^(32*i - 129)`.
/// An f32's mantissa spans at most 24 bits at weights `2^-149 ..= 2^104`
/// (bit positions 11..=264 after the +160 bias), so every finite addend
/// lands entirely inside the accumulator. Limbs are signed during
/// accumulation; `normalize` canonicalizes digits 0..9 into `[0, 2^32)`
/// with the sign carried by the top limb, which makes the representation
/// a function of the accumulated *value* alone — independent of add
/// order, partitioning, or when intermediate normalizations happened.
#[derive(Clone, Debug)]
pub struct ExactSum {
    limbs: [i64; LIMBS],
    adds: u32,
}

impl Default for ExactSum {
    fn default() -> Self {
        Self::new()
    }
}

impl ExactSum {
    pub fn new() -> ExactSum {
        ExactSum { limbs: [0; LIMBS], adds: 0 }
    }

    /// Add one finite f32 exactly. Panics on NaN/infinity — an exact
    /// accumulator has no representation for them, and every K-means
    /// quantity fed here (coordinates, squared distances of finite rows)
    /// is finite by construction.
    pub fn add(&mut self, v: f32) {
        assert!(v.is_finite(), "ExactSum::add requires a finite addend, got {v}");
        let bits = v.to_bits();
        let exp = ((bits >> 23) & 0xff) as i32;
        let frac = bits & 0x7f_ffff;
        let (mant, pow) = if exp == 0 {
            if frac == 0 {
                return; // ±0 contributes nothing
            }
            (frac, -149) // subnormal: no implicit leading bit
        } else {
            (frac | 0x80_0000, exp - 150)
        };
        let bitpos = (pow + BIAS) as usize; // 11 ..= 264
        let (limb, shift) = (bitpos / 32, bitpos % 32);
        let wide = (mant as u64) << shift; // at most 55 significant bits
        let (lo, hi) = ((wide & 0xffff_ffff) as i64, (wide >> 32) as i64);
        if bits >> 31 == 1 {
            self.limbs[limb] -= lo;
            self.limbs[limb + 1] -= hi;
        } else {
            self.limbs[limb] += lo;
            self.limbs[limb + 1] += hi;
        }
        self.adds += 1;
        if self.adds >= NORMALIZE_EVERY {
            self.normalize();
        }
    }

    /// Fold another accumulator in: plain limb-wise integer addition, the
    /// front's reduction step. Exactly equivalent to having added the
    /// other side's addends here one by one.
    pub fn merge(&mut self, other: &ExactSum) {
        for (a, b) in self.limbs.iter_mut().zip(other.limbs.iter()) {
            *a += *b;
        }
        self.normalize();
    }

    /// Carry-propagate into canonical form: digits 0..9 in `[0, 2^32)`,
    /// sign (and overflow headroom) carried by the top limb.
    fn normalize(&mut self) {
        let mut carry = 0i64;
        for i in 0..LIMBS - 1 {
            let v = self.limbs[i] + carry;
            carry = v >> 32; // arithmetic shift = floor division by 2^32
            self.limbs[i] = v - (carry << 32);
        }
        self.limbs[LIMBS - 1] += carry;
        self.adds = 0;
    }

    /// The accumulated value, correctly rounded to the nearest f64
    /// (round-half-even, with a sticky bit for the truncated tail). A
    /// pure function of the accumulated value — same bits no matter how
    /// the adds were ordered or partitioned.
    pub fn value(&self) -> f64 {
        let mut s = self.clone();
        s.normalize();
        let negative = s.limbs[LIMBS - 1] < 0;
        // Magnitude as 11 base-2^32 digits (the top limb may hold 2).
        let mut digs = [0u32; LIMBS + 1];
        let top: u64;
        if negative {
            let mut borrow = 0i64;
            for i in 0..LIMBS - 1 {
                let v = -s.limbs[i] - borrow;
                if v < 0 {
                    digs[i] = (v + (1i64 << 32)) as u32;
                    borrow = 1;
                } else {
                    digs[i] = v as u32;
                    borrow = 0;
                }
            }
            top = (-s.limbs[LIMBS - 1] - borrow) as u64;
        } else {
            for i in 0..LIMBS - 1 {
                digs[i] = s.limbs[i] as u32;
            }
            top = s.limbs[LIMBS - 1] as u64;
        }
        digs[LIMBS - 1] = top as u32;
        digs[LIMBS] = (top >> 32) as u32;

        let top_dig = match (0..digs.len()).rev().find(|&i| digs[i] != 0) {
            Some(i) => i,
            None => return 0.0,
        };
        let top_bit = 32 * top_dig + (31 - digs[top_dig].leading_zeros() as usize);
        let shift = top_bit.saturating_sub(63);
        let (d, off) = (shift / 32, shift % 32);
        let chunk = |i: usize| digs.get(i).copied().unwrap_or(0) as u128;
        let wide = chunk(d) | (chunk(d + 1) << 32) | (chunk(d + 2) << 64);
        let mut window = ((wide >> off) & u64::MAX as u128) as u64;
        let sticky = digs[..d].iter().any(|&x| x != 0)
            || (off > 0 && digs[d] & ((1u32 << off) - 1) != 0);
        if sticky {
            window |= 1;
        }
        // Scale by 2^(shift - 160); the exponent field stays in range for
        // every reachable shift (0 ..= 288).
        let scale = f64::from_bits(((shift as i64 - BIAS as i64 + 1023) as u64) << 52);
        let mag = window as f64 * scale;
        if negative {
            -mag
        } else {
            mag
        }
    }

    /// Canonical wire form: 160 lowercase hex chars (10 limbs of 16,
    /// low limb first, each the limb's i64 bits as u64).
    pub fn to_hex(&self) -> String {
        let mut s = self.clone();
        s.normalize();
        let mut out = String::with_capacity(LIMBS * 16);
        for limb in s.limbs {
            out.push_str(&format!("{:016x}", limb as u64));
        }
        out
    }

    pub fn from_hex(hex: &str) -> Result<ExactSum> {
        if hex.len() != LIMBS * 16 {
            return Err(Error::Parse(format!(
                "ExactSum hex must be {} chars, got {}",
                LIMBS * 16,
                hex.len()
            )));
        }
        let mut limbs = [0i64; LIMBS];
        for (i, limb) in limbs.iter_mut().enumerate() {
            let chunk = &hex[i * 16..(i + 1) * 16];
            *limb = u64::from_str_radix(chunk, 16)
                .map_err(|_| Error::Parse(format!("bad ExactSum hex limb '{chunk}'")))?
                as i64;
        }
        Ok(ExactSum { limbs, adds: 0 })
    }
}

/// Per-cluster partial sums + counts over a slice of the dataset: what
/// one shard computes per iteration and the front merges into the next
/// centroid matrix (PROTOCOL.md §10). `sums` is row-major `k*d`.
#[derive(Clone, Debug)]
pub struct PartialAccumulator {
    k: usize,
    d: usize,
    sums: Vec<ExactSum>,
    counts: Vec<u64>,
}

impl PartialAccumulator {
    pub fn new(k: usize, d: usize) -> PartialAccumulator {
        PartialAccumulator {
            k,
            d,
            sums: (0..k * d).map(|_| ExactSum::new()).collect(),
            counts: vec![0; k],
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Fold one point into its assigned cluster's sums.
    pub fn add_point(&mut self, row: &[f32], cluster: usize) {
        debug_assert_eq!(row.len(), self.d);
        self.counts[cluster] += 1;
        let base = cluster * self.d;
        for (j, &x) in row.iter().enumerate() {
            self.sums[base + j].add(x);
        }
    }

    /// Merge another shard's partials in (the front's reduce step).
    pub fn merge(&mut self, other: &PartialAccumulator) -> Result<()> {
        if self.k != other.k || self.d != other.d {
            return Err(Error::Parse(format!(
                "partial shape mismatch: {}x{} vs {}x{}",
                self.k, self.d, other.k, other.d
            )));
        }
        for (a, b) in self.sums.iter_mut().zip(other.sums.iter()) {
            a.merge(b);
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        Ok(())
    }

    /// New centroids from the merged sums. A cluster that captured no
    /// points — including the degenerate "more shards than points" case
    /// where whole slices are empty — keeps its previous row instead of
    /// dividing 0/0 into NaN. Returns the per-cluster counts alongside.
    pub fn finalize(&self, prev: &Matrix) -> (Matrix, Vec<usize>) {
        debug_assert_eq!((prev.rows(), prev.cols()), (self.k, self.d));
        let mut out = Matrix::zeros(self.k, self.d);
        for c in 0..self.k {
            let row = out.row_mut(c);
            if self.counts[c] == 0 {
                row.copy_from_slice(prev.row(c));
                continue;
            }
            let inv = 1.0 / self.counts[c] as f64;
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = (self.sums[c * self.d + j].value() * inv) as f32;
            }
        }
        (out, self.counts.iter().map(|&c| c as usize).collect())
    }

    /// Wire form of the sums: `k*d` concatenated [`ExactSum::to_hex`]
    /// blocks, row-major.
    pub fn sums_hex(&self) -> String {
        let mut out = String::with_capacity(self.sums.len() * LIMBS * 16);
        for s in &self.sums {
            out.push_str(&s.to_hex());
        }
        out
    }

    /// Rebuild from the wire (`counts` array + sums hex). The shape must
    /// be known from the request context; the hex length is checked
    /// against it.
    pub fn from_wire(k: usize, d: usize, counts: &[u64], sums_hex: &str) -> Result<PartialAccumulator> {
        if counts.len() != k {
            return Err(Error::Parse(format!(
                "partial counts must have {k} entries, got {}",
                counts.len()
            )));
        }
        let block = LIMBS * 16;
        if sums_hex.len() != k * d * block {
            return Err(Error::Parse(format!(
                "partial sums hex must be {} chars for k={k} d={d}, got {}",
                k * d * block,
                sums_hex.len()
            )));
        }
        let mut sums = Vec::with_capacity(k * d);
        for i in 0..k * d {
            sums.push(ExactSum::from_hex(&sums_hex[i * block..(i + 1) * block])?);
        }
        Ok(PartialAccumulator { k, d, sums, counts: counts.to_vec() })
    }
}

// ---- wire hex codecs (PROTOCOL.md §10) ---------------------------------
//
// JSON number printing is not a bit-faithful f32 transport; these codecs
// are. Fixed width, little-endian bytes, lowercase hex.

/// f32 slice -> hex (8 chars per value, little-endian bytes).
pub fn f32s_to_hex(values: &[f32]) -> String {
    let mut out = String::with_capacity(values.len() * 8);
    for v in values {
        for b in v.to_le_bytes() {
            out.push_str(&format!("{b:02x}"));
        }
    }
    out
}

pub fn f32s_from_hex(hex: &str) -> Result<Vec<f32>> {
    let bytes = hex_bytes(hex)?;
    if bytes.len() % 4 != 0 {
        return Err(Error::Parse(format!("f32 hex length {} is not a multiple of 8", hex.len())));
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// u32 slice -> hex (8 chars per value, little-endian bytes) — the wire
/// form of assignment vectors.
pub fn u32s_to_hex(values: &[u32]) -> String {
    let mut out = String::with_capacity(values.len() * 8);
    for v in values {
        for b in v.to_le_bytes() {
            out.push_str(&format!("{b:02x}"));
        }
    }
    out
}

pub fn u32s_from_hex(hex: &str) -> Result<Vec<u32>> {
    let bytes = hex_bytes(hex)?;
    if bytes.len() % 4 != 0 {
        return Err(Error::Parse(format!("u32 hex length {} is not a multiple of 8", hex.len())));
    }
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// A `k x d` matrix -> hex of its row-major f32 data.
pub fn matrix_to_hex(m: &Matrix) -> String {
    f32s_to_hex(m.as_slice())
}

pub fn matrix_from_hex(hex: &str, k: usize, d: usize) -> Result<Matrix> {
    let values = f32s_from_hex(hex)?;
    if values.len() != k * d {
        return Err(Error::Parse(format!(
            "matrix hex holds {} values, expected {k}x{d}",
            values.len()
        )));
    }
    Matrix::from_vec(values, k, d)
}

fn hex_bytes(hex: &str) -> Result<Vec<u8>> {
    if hex.len() % 2 != 0 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(Error::Parse("malformed hex payload".into()));
    }
    Ok(hex
        .as_bytes()
        .chunks_exact(2)
        .map(|c| {
            let hi = (c[0] as char).to_digit(16).unwrap() as u8;
            let lo = (c[1] as char).to_digit(16).unwrap() as u8;
            (hi << 4) | lo
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift64* — no external RNG dependency in tests.
    struct TestRng(u64);

    impl TestRng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            self.0 = x;
            x.wrapping_mul(0x2545_F491_4F6C_DD1D)
        }

        /// A finite f32 with a wild exponent spread (subnormals included).
        fn f32(&mut self) -> f32 {
            loop {
                let v = f32::from_bits(self.next() as u32);
                if v.is_finite() {
                    return v;
                }
            }
        }
    }

    fn sum_of(values: &[f32]) -> ExactSum {
        let mut s = ExactSum::new();
        for &v in values {
            s.add(v);
        }
        s
    }

    #[test]
    fn exact_on_integers_and_singletons() {
        let mut s = ExactSum::new();
        for v in [1.0f32, 2.0, 3.0, -4.5, 0.25] {
            s.add(v);
        }
        assert_eq!(s.value(), 1.75);
        for v in [0.0f32, -0.0, 1.0, -1.0, 3.5e37, -1.1754944e-38, 1e-45, f32::MIN_POSITIVE] {
            assert_eq!(sum_of(&[v]).value(), v as f64, "singleton {v} must round-trip");
        }
        assert_eq!(ExactSum::new().value(), 0.0);
    }

    #[test]
    fn order_and_partition_invariant() {
        let mut rng = TestRng(0x9E37_79B9_7F4A_7C15);
        let values: Vec<f32> = (0..4000).map(|_| rng.f32()).collect();
        let sequential = sum_of(&values);
        // Reversed order.
        let reversed: Vec<f32> = values.iter().rev().copied().collect();
        assert_eq!(sum_of(&reversed).to_hex(), sequential.to_hex());
        assert_eq!(sum_of(&reversed).value().to_bits(), sequential.value().to_bits());
        // Every partition into 1..=5 contiguous shards, merged in order
        // and in reverse order, lands on the same canonical bits.
        for shards in 1..=5 {
            let n = values.len();
            let parts: Vec<ExactSum> = (0..shards)
                .map(|i| sum_of(&values[i * n / shards..(i + 1) * n / shards]))
                .collect();
            for ordering in [false, true] {
                let mut merged = ExactSum::new();
                let idx: Vec<usize> =
                    if ordering { (0..shards).rev().collect() } else { (0..shards).collect() };
                for i in idx {
                    merged.merge(&parts[i]);
                }
                assert_eq!(merged.to_hex(), sequential.to_hex(), "shards={shards}");
                assert_eq!(merged.value().to_bits(), sequential.value().to_bits());
            }
        }
    }

    #[test]
    fn cancellation_is_exact() {
        // Catastrophic cancellation that f64 running sums get wrong.
        let mut s = ExactSum::new();
        s.add(3.4e38);
        s.add(1.0);
        s.add(-3.4e38);
        assert_eq!(s.value(), 1.0);
        let mut t = ExactSum::new();
        t.add(1.0e-40); // subnormal survives alongside a huge addend
        t.add(2.0e38);
        t.add(-2.0e38);
        assert_eq!(t.value(), 1.0e-40f32 as f64);
    }

    #[test]
    fn hex_round_trips() {
        let mut rng = TestRng(42);
        let values: Vec<f32> = (0..257).map(|_| rng.f32()).collect();
        let s = sum_of(&values);
        let back = ExactSum::from_hex(&s.to_hex()).unwrap();
        assert_eq!(back.to_hex(), s.to_hex());
        assert_eq!(back.value().to_bits(), s.value().to_bits());
        assert!(ExactSum::from_hex("zz").is_err());
        assert!(ExactSum::from_hex(&"0".repeat(159)).is_err());

        assert_eq!(f32s_from_hex(&f32s_to_hex(&values)).unwrap(), values);
        let ids: Vec<u32> = (0..300).map(|_| rng.next() as u32).collect();
        assert_eq!(u32s_from_hex(&u32s_to_hex(&ids)).unwrap(), ids);
        assert!(f32s_from_hex("0q").is_err());
        assert!(u32s_from_hex("abcdef").is_err(), "length not a multiple of 8");
    }

    #[test]
    fn accumulator_matches_whole_when_split() {
        let mut rng = TestRng(7);
        let (k, d, n) = (4, 3, 200);
        let rows: Vec<Vec<f32>> =
            (0..n).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
        let assign: Vec<usize> = (0..n).map(|_| rng.next() as usize % k).collect();
        let mut whole = PartialAccumulator::new(k, d);
        for (row, &c) in rows.iter().zip(assign.iter()) {
            whole.add_point(row, c);
        }
        let mut merged = PartialAccumulator::new(k, d);
        for shard in 0..3 {
            let mut part = PartialAccumulator::new(k, d);
            for i in (0..n).filter(|i| i % 3 == shard) {
                part.add_point(&rows[i], assign[i]);
            }
            // Wire round-trip every partial before merging, as the front does.
            let wired =
                PartialAccumulator::from_wire(k, d, part.counts(), &part.sums_hex()).unwrap();
            merged.merge(&wired).unwrap();
        }
        assert_eq!(merged.counts(), whole.counts());
        assert_eq!(merged.sums_hex(), whole.sums_hex());
        let prev = Matrix::zeros(k, d);
        let (a, ca) = whole.finalize(&prev);
        let (b, cb) = merged.finalize(&prev);
        assert_eq!(a.as_slice(), b.as_slice());
        assert_eq!(ca, cb);
        assert!(PartialAccumulator::new(k, d).merge(&PartialAccumulator::new(k + 1, d)).is_err());
        assert!(PartialAccumulator::from_wire(k, d, &[0; 3], &"0".repeat(k * d * 160)).is_err());
        assert!(PartialAccumulator::from_wire(k, d, &[0; 4], "00").is_err());
    }

    #[test]
    fn empty_cluster_keeps_previous_centroid() {
        // The "more shards than points" edge: an accumulator that saw no
        // points at all must reproduce `prev` exactly, never NaN.
        let prev = Matrix::from_vec(vec![1.5, -2.5, 0.25, 9.0], 2, 2).unwrap();
        let acc = PartialAccumulator::new(2, 2);
        let (out, counts) = acc.finalize(&prev);
        assert_eq!(out.as_slice(), prev.as_slice());
        assert_eq!(counts, vec![0, 0]);
        assert!(out.as_slice().iter().all(|v| v.is_finite()));
        // And a half-empty accumulator guards per cluster.
        let mut half = PartialAccumulator::new(2, 2);
        half.add_point(&[4.0, 8.0], 1);
        let (out, counts) = half.finalize(&prev);
        assert_eq!(&out.as_slice()[..2], &prev.as_slice()[..2]);
        assert_eq!(&out.as_slice()[2..], &[4.0, 8.0]);
        assert_eq!(counts, vec![0, 1]);
    }

    #[test]
    fn matrix_hex_round_trips() {
        let m = Matrix::from_vec(vec![1.0, -0.5, 3.25e-12, 7.0, 0.0, -4.5e20], 2, 3).unwrap();
        let back = matrix_from_hex(&matrix_to_hex(&m), 2, 3).unwrap();
        assert_eq!(back.as_slice(), m.as_slice());
        assert!(matrix_from_hex(&matrix_to_hex(&m), 3, 3).is_err());
    }
}
