//! Hamerly's algorithm: one upper + one lower bound per point.
//!
//! The simplest triangle-inequality K-means (Hamerly 2010). KPynq's
//! point-level filter is exactly this test, so Hamerly serves both as a
//! baseline in the ablation (point-level filter only, no groups) and as the
//! stepping stone to the multi-level [`super::yinyang`] algorithm.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::bounds::{deflate_lb, filter_safe, inflate_ub};
use crate::kmeans::kernel::{self, scan_all};
use crate::kmeans::{
    centroid_drifts, compute_inertia, metrics::IterStats, recompute_centroids, FitResult,
    KMeansConfig, RunStats,
};
use crate::obs::profile::{Phase, PhaseTimer};
use crate::util::matrix::Matrix;

/// Half the distance from each centroid to its nearest other centroid.
/// A point with `ub <= s[a]` cannot change assignment (any other centroid
/// is at least `2·s[a]` away from `a`). Returns the pair-scan count.
pub(crate) fn half_nearest_other(centroids: &Matrix) -> (Vec<f32>, u64) {
    let k = centroids.rows();
    let mut s = vec![f32::INFINITY; k];
    for a in 0..k {
        for b in (a + 1)..k {
            let d = kernel::dist_pair(centroids.row(a), centroids.row(b));
            if d < s[a] {
                s[a] = d;
            }
            if d < s[b] {
                s[b] = d;
            }
        }
    }
    for v in s.iter_mut() {
        *v *= 0.5;
        if !v.is_finite() {
            *v = f32::INFINITY; // k == 1: no other centroid exists.
        }
    }
    (s, (k as u64 * k.saturating_sub(1) as u64) / 2)
}

pub fn fit(ds: &Dataset, cfg: &KMeansConfig, init: Matrix) -> Result<FitResult> {
    let n = ds.n();
    let k = cfg.k;
    let mut centroids = init;
    let mut assignments = vec![0u32; n];
    let mut ub = vec![0.0f32; n];
    let mut lb = vec![0.0f32; n];
    let mut stats = RunStats::default();
    let mut converged = false;
    let mut iterations = 0;
    // obs::profile phase clock — pure annotation, bit-identical on/off.
    let mut timer = PhaseTimer::new();

    // Iteration 1: full scan initialises bounds (counted like Lloyd's).
    {
        iterations += 1;
        timer.enter(Phase::Init);
        let mut it = IterStats::default();
        let scan = kernel::nearest_full_scan(&ds.points, &centroids);
        for i in 0..n {
            assignments[i] = scan.idx[i];
            ub[i] = scan.best[i].sqrt();
            lb[i] = scan.second[i].sqrt();
        }
        it.dist_comps = scan.dist_comps;
        it.survivors = n as u64;
        it.reassigned = n as u64;
        timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(ds, &assignments, &centroids);
        let (drifts, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);
        if (max_drift as f64) <= cfg.tol {
            converged = true;
        } else {
            // Apply drifts for the next iteration's bounds.
            timer.enter(Phase::Bounds);
            for i in 0..n {
                ub[i] = inflate_ub(ub[i], drifts[assignments[i] as usize]);
                lb[i] = deflate_lb(lb[i], max_drift);
            }
        }
        timer.exit();
    }

    while !converged && iterations < cfg.max_iters {
        iterations += 1;
        let mut it = IterStats::default();
        let mut dist_comps = 0u64;

        timer.enter(Phase::Assign);
        let (s_half, pair_comps) = half_nearest_other(&centroids);
        dist_comps += pair_comps;

        for (i, row) in ds.points.rows_iter().enumerate() {
            let a = assignments[i] as usize;
            let m = lb[i].max(s_half[a]);
            // Global filter on the stale upper bound.
            if filter_safe(m, ub[i]) {
                it.filtered_global += 1;
                continue;
            }
            // Tighten ub with one exact distance and retest.
            let exact = kernel::dist_pair(row, centroids.row(a));
            dist_comps += 1;
            ub[i] = exact;
            if filter_safe(m, ub[i]) {
                it.filtered_global += 1;
                continue;
            }
            // Survivor: full scan.
            let (arg, best, second) = scan_all(row, &centroids);
            dist_comps += k as u64;
            it.survivors += 1;
            if assignments[i] != arg as u32 {
                it.reassigned += 1;
                assignments[i] = arg as u32;
            }
            ub[i] = best.sqrt();
            lb[i] = second.sqrt();
        }

        it.dist_comps = dist_comps;
        timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(ds, &assignments, &centroids);
        let (drifts, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);

        if (max_drift as f64) <= cfg.tol {
            converged = true;
        } else {
            timer.enter(Phase::Bounds);
            for i in 0..n {
                ub[i] = inflate_ub(ub[i], drifts[assignments[i] as usize]);
                lb[i] = deflate_lb(lb[i], max_drift);
            }
        }
        timer.exit();
    }

    stats.phases = timer.totals();
    let inertia = compute_inertia(ds, &centroids, &assignments);
    Ok(FitResult { centroids, assignments, inertia, iterations, converged, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, init, Algorithm, InitMethod};

    fn cfg(k: usize, seed: u64) -> KMeansConfig {
        KMeansConfig { k, seed, init: InitMethod::KMeansPlusPlus, ..Default::default() }
    }

    #[test]
    fn half_nearest_other_is_correct() {
        let c = Matrix::from_vec(vec![0.0, 0.0, 2.0, 0.0, 10.0, 0.0], 3, 2).unwrap();
        let (s, comps) = half_nearest_other(&c);
        assert_eq!(comps, 3);
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!((s[1] - 1.0).abs() < 1e-6);
        assert!((s[2] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn k1_has_infinite_guard() {
        let c = Matrix::from_vec(vec![0.0, 0.0], 1, 2).unwrap();
        let (s, comps) = half_nearest_other(&c);
        assert_eq!(comps, 0);
        assert!(s[0].is_infinite());
    }

    #[test]
    fn matches_lloyd_on_blobs() {
        let ds = synth::blobs(500, 8, 4, 3);
        let cfg = cfg(4, 11);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let h = fit(&ds, &cfg, c0).unwrap();
        assert_eq!(l.assignments, h.assignments);
        assert_eq!(l.iterations, h.iterations);
        assert_eq!(l.centroids, h.centroids);
        assert!((l.inertia - h.inertia).abs() <= 1e-9 * l.inertia.max(1.0));
    }

    #[test]
    fn does_less_work_than_lloyd() {
        let ds = synth::blobs(2000, 16, 8, 5);
        let cfg = cfg(8, 3);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let h = fit(&ds, &cfg, c0).unwrap();
        // On easy blobs both converge in few iterations; the first full
        // scan is shared, so the bound is "meaningfully less", not half.
        assert!(
            (h.stats.total_dist_comps() as f64) < 0.75 * l.stats.total_dist_comps() as f64,
            "hamerly {} vs lloyd {}",
            h.stats.total_dist_comps(),
            l.stats.total_dist_comps()
        );
    }

    #[test]
    fn filter_counters_accounted() {
        let ds = synth::blobs(300, 6, 3, 7);
        let cfg = cfg(3, 9);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let h = fit(&ds, &cfg, c0).unwrap();
        for (t, it) in h.stats.iters.iter().enumerate().skip(1) {
            assert_eq!(
                it.filtered_global + it.survivors,
                300,
                "iter {t}: every point either filtered or scanned"
            );
        }
    }
}
