//! K-means algorithm family.
//!
//! Four exact algorithms over the same public interface:
//!
//! * [`lloyd`] — the standard algorithm; the paper's CPU baseline.
//! * [`hamerly`] — single upper + single lower bound per point.
//! * [`elkan`] — per-centroid lower bounds + inter-centroid pruning.
//! * [`yinyang`] — the paper's **multi-level filter**: a global filter, a
//!   group-level filter over centroid groups, and a point-level filter
//!   inside each surviving group. This is the algorithm KPynq maps to
//!   hardware; its filter phase is factored out ([`yinyang::FilterState`])
//!   so the accelerator model and the coordinator execute *the same
//!   decisions* the software algorithm makes.
//!
//! All four are exact: given the same initialisation they produce the same
//! assignments and centroids as Lloyd's algorithm at every iteration (bound
//! arithmetic carries a conservative epsilon so float rounding can only
//! cause extra distance computations, never wrong ones). The property tests
//! in `rust/tests/` assert this equivalence on random instances.
//!
//! Every point↔centroid distance any of them computes goes through the
//! shared tiled micro-kernel, [`kernel`] (DESIGN.md §5) — the four
//! algorithms differ only in *which* distances they decide to compute,
//! never in how a distance is computed. `tools/check-docs.sh` enforces
//! the seam: no file in this module except `kernel.rs` may call the raw
//! `util::matrix` distance helpers.

pub mod bounds;
pub mod elkan;
pub mod hamerly;
pub mod init;
pub mod kernel;
pub mod lloyd;
pub mod metrics;
pub mod reduce;
pub mod yinyang;

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::util::matrix::Matrix;

pub use metrics::{IterStats, RunStats};

/// Initialisation strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitMethod {
    /// k distinct points chosen uniformly at random.
    RandomPoints,
    /// k-means++ (D² sampling).
    KMeansPlusPlus,
}

/// Which algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Lloyd,
    Hamerly,
    Elkan,
    Yinyang,
}

impl Algorithm {
    pub const ALL: [Algorithm; 4] =
        [Algorithm::Lloyd, Algorithm::Hamerly, Algorithm::Elkan, Algorithm::Yinyang];

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Lloyd => "lloyd",
            Algorithm::Hamerly => "hamerly",
            Algorithm::Elkan => "elkan",
            Algorithm::Yinyang => "yinyang",
        }
    }

    pub fn from_name(name: &str) -> Result<Algorithm> {
        Self::ALL
            .iter()
            .copied()
            .find(|a| a.name() == name)
            .ok_or_else(|| Error::Config(format!("unknown algorithm '{name}'")))
    }
}

/// Shared configuration for every algorithm.
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Iteration cap.
    pub max_iters: usize,
    /// Convergence: stop when the max centroid movement (Euclidean) falls
    /// at or below this threshold.
    pub tol: f64,
    /// Seed for initialisation.
    pub seed: u64,
    pub init: InitMethod,
    /// Yinyang group count; 0 = auto (`ceil(k / 10)`, the Yinyang paper's
    /// recommendation, clamped to at least 1).
    pub groups: usize,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        Self {
            k: 8,
            max_iters: 100,
            tol: 1e-4,
            seed: 0xC0FFEE,
            init: InitMethod::KMeansPlusPlus,
            groups: 0,
        }
    }
}

impl KMeansConfig {
    pub fn validate(&self, n: usize) -> Result<()> {
        if self.k == 0 {
            return Err(Error::Config("k must be >= 1".into()));
        }
        if self.k > n {
            return Err(Error::Config(format!("k={} exceeds n={}", self.k, n)));
        }
        if self.max_iters == 0 {
            return Err(Error::Config("max_iters must be >= 1".into()));
        }
        if !(self.tol >= 0.0) {
            return Err(Error::Config(format!("tol must be >= 0, got {}", self.tol)));
        }
        if self.groups > self.k {
            return Err(Error::Config(format!(
                "groups={} exceeds k={}",
                self.groups, self.k
            )));
        }
        Ok(())
    }

    /// Effective Yinyang group count.
    pub fn effective_groups(&self) -> usize {
        if self.groups > 0 {
            self.groups
        } else {
            (self.k + 9) / 10
        }
    }
}

/// The result of a fit.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub centroids: Matrix,
    pub assignments: Vec<u32>,
    /// Sum of squared distances to assigned centroids at the final state.
    pub inertia: f64,
    pub iterations: usize,
    pub converged: bool,
    pub stats: RunStats,
}

/// Run `algo` on `ds`.
pub fn fit(algo: Algorithm, ds: &Dataset, cfg: &KMeansConfig) -> Result<FitResult> {
    cfg.validate(ds.n())?;
    ds.validate()?;
    let init_c = init::initialize(ds, cfg)?;
    fit_from(algo, ds, cfg, init_c)
}

/// Run `algo` from explicit initial centroids (shared by the equivalence
/// tests and the coordinator, which must agree on initialisation).
pub fn fit_from(
    algo: Algorithm,
    ds: &Dataset,
    cfg: &KMeansConfig,
    init_centroids: Matrix,
) -> Result<FitResult> {
    if init_centroids.rows() != cfg.k || init_centroids.cols() != ds.d() {
        return Err(Error::Config(format!(
            "initial centroids are {}x{}, expected {}x{}",
            init_centroids.rows(),
            init_centroids.cols(),
            cfg.k,
            ds.d()
        )));
    }
    match algo {
        Algorithm::Lloyd => lloyd::fit(ds, cfg, init_centroids),
        Algorithm::Hamerly => hamerly::fit(ds, cfg, init_centroids),
        Algorithm::Elkan => elkan::fit(ds, cfg, init_centroids),
        Algorithm::Yinyang => yinyang::fit(ds, cfg, init_centroids),
    }
}

/// Recompute centroids from assignments.
///
/// Every algorithm uses this same routine, and it runs on the
/// order-independent [`reduce::PartialAccumulator`] — so the result is
/// bit-identical whether the points are folded in sequentially (solo fit)
/// or as merged per-shard partials (`cluster` map-reduce mode,
/// PROTOCOL.md §10). Empty clusters keep their previous centroid
/// (matching `python/compile/model.py`); the same guard covers shard
/// slices that contributed no points at all.
pub(crate) fn recompute_centroids(
    ds: &Dataset,
    assignments: &[u32],
    prev: &Matrix,
) -> (Matrix, Vec<usize>) {
    let (k, d) = (prev.rows(), prev.cols());
    let mut acc = reduce::PartialAccumulator::new(k, d);
    for (i, row) in ds.points.rows_iter().enumerate() {
        acc.add_point(row, assignments[i] as usize);
    }
    acc.finalize(prev)
}

/// Per-centroid drift (Euclidean movement) between two centroid sets, plus
/// the maximum drift. Used by every bounded algorithm and by convergence.
pub(crate) fn centroid_drifts(old: &Matrix, new: &Matrix) -> (Vec<f32>, f32) {
    let mut drifts = Vec::with_capacity(old.rows());
    let mut max = 0.0f32;
    for c in 0..old.rows() {
        let d = kernel::dist_pair(old.row(c), new.row(c));
        max = max.max(d);
        drifts.push(d);
    }
    (drifts, max)
}

/// Final inertia for a fitted state. Accumulated on [`reduce::ExactSum`]
/// so the value is independent of summation order — per-shard slice
/// inertias merged by the map-reduce front (PROTOCOL.md §10) reproduce
/// the solo value bit for bit.
pub(crate) fn compute_inertia(ds: &Dataset, centroids: &Matrix, assignments: &[u32]) -> f64 {
    let mut sum = reduce::ExactSum::new();
    for (i, &a) in assignments.iter().enumerate() {
        sum.add(kernel::sq_dist_pair(ds.points.row(i), centroids.row(a as usize)));
    }
    sum.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn config_validation() {
        let ds_n = 100;
        let mut cfg = KMeansConfig::default();
        cfg.validate(ds_n).unwrap();
        cfg.k = 0;
        assert!(cfg.validate(ds_n).is_err());
        cfg.k = 101;
        assert!(cfg.validate(ds_n).is_err());
        cfg.k = 8;
        cfg.groups = 9;
        assert!(cfg.validate(ds_n).is_err());
        cfg.groups = 0;
        cfg.tol = f64::NAN;
        assert!(cfg.validate(ds_n).is_err());
    }

    #[test]
    fn effective_groups_follows_k_over_10() {
        let mut cfg = KMeansConfig { k: 25, ..Default::default() };
        assert_eq!(cfg.effective_groups(), 3);
        cfg.k = 10;
        assert_eq!(cfg.effective_groups(), 1);
        cfg.groups = 5;
        assert_eq!(cfg.effective_groups(), 5);
    }

    #[test]
    fn algorithm_names_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::from_name(a.name()).unwrap(), a);
        }
        assert!(Algorithm::from_name("bogus").is_err());
    }

    #[test]
    fn recompute_keeps_empty_clusters() {
        let ds = synth::blobs(20, 3, 2, 1);
        let prev = Matrix::from_vec(vec![9.0; 9], 3, 3).unwrap();
        // Nobody assigned to cluster 2.
        let assign: Vec<u32> = (0..20).map(|i| (i % 2) as u32).collect();
        let (new_c, counts) = recompute_centroids(&ds, &assign, &prev);
        assert_eq!(counts[2], 0);
        assert_eq!(new_c.row(2), prev.row(2));
        assert!(counts[0] > 0 && new_c.row(0) != prev.row(0));
    }

    #[test]
    fn drift_of_identical_sets_is_zero() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        let (drifts, max) = centroid_drifts(&m, &m);
        assert_eq!(drifts, vec![0.0, 0.0]);
        assert_eq!(max, 0.0);
    }
}
