//! Work accounting: the paper's "work-efficient" claim is quantified here.
//!
//! Every algorithm reports, per iteration, how many point↔centroid distance
//! computations it performed and how many candidates each filter level
//! removed. Standard K-means does exactly `n·k` per iteration; the
//! multi-level filter's whole value proposition is the gap between that and
//! its actual count — reproduced by `fig_filter_ablation`.

/// Statistics for one iteration.
#[derive(Clone, Debug, Default)]
pub struct IterStats {
    /// Point↔centroid distance computations actually executed.
    pub dist_comps: u64,
    /// Candidates eliminated by the global (Hamerly-style) filter:
    /// points whose assignment was proven unchanged without any scan.
    pub filtered_global: u64,
    /// Candidate (point, group) pairs eliminated by the group-level filter.
    pub filtered_group: u64,
    /// Candidate (point, centroid) pairs eliminated by the point-level
    /// (local) filter inside surviving groups.
    pub filtered_point: u64,
    /// Points whose assignment changed this iteration.
    pub reassigned: u64,
    /// Maximum centroid drift after the update step.
    pub max_drift: f32,
    /// Points that survived all filters and required a (partial) scan.
    pub survivors: u64,
}

/// Whole-run statistics.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub iters: Vec<IterStats>,
}

impl RunStats {
    pub fn push(&mut self, it: IterStats) {
        self.iters.push(it);
    }

    /// Total distance computations across the run.
    pub fn total_dist_comps(&self) -> u64 {
        self.iters.iter().map(|i| i.dist_comps).sum()
    }

    /// Distance computations standard K-means would have performed for the
    /// same iteration count.
    pub fn lloyd_equivalent_dist_comps(&self, n: usize, k: usize) -> u64 {
        (self.iters.len() as u64) * (n as u64) * (k as u64)
    }

    /// Fraction of Lloyd's distance work actually performed (≤ 1 for the
    /// filtered algorithms after the first iteration; the first iteration
    /// is always a full scan).
    pub fn work_ratio(&self, n: usize, k: usize) -> f64 {
        let lloyd = self.lloyd_equivalent_dist_comps(n, k);
        if lloyd == 0 {
            return f64::NAN;
        }
        self.total_dist_comps() as f64 / lloyd as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_ratio_accounts_per_iteration() {
        let mut rs = RunStats::default();
        rs.push(IterStats { dist_comps: 100, ..Default::default() });
        rs.push(IterStats { dist_comps: 20, ..Default::default() });
        // n=10, k=10 → lloyd does 100/iter → 200 total.
        assert_eq!(rs.lloyd_equivalent_dist_comps(10, 10), 200);
        assert!((rs.work_ratio(10, 10) - 0.6).abs() < 1e-12);
        assert_eq!(rs.total_dist_comps(), 120);
    }

    #[test]
    fn empty_run_is_nan() {
        let rs = RunStats::default();
        assert!(rs.work_ratio(10, 10).is_nan());
    }
}
