//! Work accounting: the paper's "work-efficient" claim is quantified here.
//!
//! Every algorithm reports, per iteration, how many point↔centroid distance
//! computations it performed and how many candidates each filter level
//! removed. Standard K-means does exactly `n·k` per iteration; the
//! multi-level filter's whole value proposition is the gap between that and
//! its actual count — reproduced by `fig_filter_ablation`.

/// Statistics for one iteration.
#[derive(Clone, Debug, Default)]
pub struct IterStats {
    /// Point↔centroid distance computations actually executed.
    pub dist_comps: u64,
    /// Candidates eliminated by the global (Hamerly-style) filter:
    /// points whose assignment was proven unchanged without any scan.
    pub filtered_global: u64,
    /// Candidate (point, group) pairs eliminated by the group-level filter.
    pub filtered_group: u64,
    /// Candidate (point, centroid) pairs eliminated by the point-level
    /// (local) filter inside surviving groups.
    pub filtered_point: u64,
    /// Points whose assignment changed this iteration.
    pub reassigned: u64,
    /// Maximum centroid drift after the update step.
    pub max_drift: f32,
    /// Points that survived all filters and required a (partial) scan.
    pub survivors: u64,
}

/// Whole-run statistics.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub iters: Vec<IterStats>,
    /// Per-phase wall-time totals from `obs::profile` — `Some` only when
    /// profiling was enabled for the fit (the timers are provably
    /// non-perturbing, DESIGN.md §2, so this is pure annotation).
    pub phases: Option<crate::obs::profile::PhaseTotals>,
}

impl RunStats {
    pub fn push(&mut self, it: IterStats) {
        self.iters.push(it);
    }

    /// Total distance computations across the run.
    pub fn total_dist_comps(&self) -> u64 {
        self.iters.iter().map(|i| i.dist_comps).sum()
    }

    /// Distance computations standard K-means would have performed for the
    /// same iteration count.
    pub fn lloyd_equivalent_dist_comps(&self, n: usize, k: usize) -> u64 {
        (self.iters.len() as u64) * (n as u64) * (k as u64)
    }

    /// Fraction of Lloyd's distance work actually performed (≤ 1 for the
    /// filtered algorithms after the first iteration; the first iteration
    /// is always a full scan).
    pub fn work_ratio(&self, n: usize, k: usize) -> f64 {
        let lloyd = self.lloyd_equivalent_dist_comps(n, k);
        if lloyd == 0 {
            return f64::NAN;
        }
        self.total_dist_comps() as f64 / lloyd as f64
    }

    /// Points pruned whole by the global filter, summed over iterations —
    /// the headline "work-efficiency" count (0 for Lloyd, which filters
    /// nothing).
    pub fn points_pruned(&self) -> u64 {
        self.iters.iter().map(|i| i.filtered_global).sum()
    }

    /// Distance evaluations the filters avoided relative to standard
    /// K-means at the same iteration count. Saturating: a run that did
    /// extra bookkeeping distance work never reports negative savings.
    pub fn dist_comps_avoided(&self, n: usize, k: usize) -> u64 {
        self.lloyd_equivalent_dist_comps(n, k)
            .saturating_sub(self.total_dist_comps())
    }

    /// Group-filter hit rate: the fraction of candidate work settled by
    /// the group-level filter rather than by executed distance
    /// computations — `filtered_group / (filtered_group + dist_comps)`,
    /// summed over the run. 0.0 both for Lloyd (no filters) and for an
    /// empty run.
    pub fn group_hit_rate(&self) -> f64 {
        let hits: u64 = self.iters.iter().map(|i| i.filtered_group).sum();
        let denom = hits + self.total_dist_comps();
        if denom == 0 {
            0.0
        } else {
            hits as f64 / denom as f64
        }
    }

    /// The whole-run work-efficiency rollup, as one copyable record —
    /// what flows into `coordinator::telemetry::RunReport` and up through
    /// `serve::FitSummary` onto the wire (PROTOCOL.md §4).
    pub fn work_efficiency(&self, n: usize, k: usize) -> WorkEfficiency {
        WorkEfficiency {
            dist_comps: self.total_dist_comps(),
            dist_comps_avoided: self.dist_comps_avoided(n, k),
            points_pruned: self.points_pruned(),
            group_hit_rate: self.group_hit_rate(),
        }
    }
}

/// Whole-run filter savings, in the units the paper's evaluation uses.
/// All-zero when per-iteration stats are unavailable (map-reduce fits
/// deliberately do not reproduce them — `cluster::mapreduce`): zero
/// claims "nothing measured", never "everything avoided".
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkEfficiency {
    /// Distance computations actually executed.
    pub dist_comps: u64,
    /// Distance computations avoided vs. Lloyd at the same iteration count.
    pub dist_comps_avoided: u64,
    /// Points pruned whole by the global filter.
    pub points_pruned: u64,
    /// Fraction of candidate work settled by the group-level filter.
    pub group_hit_rate: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_ratio_accounts_per_iteration() {
        let mut rs = RunStats::default();
        rs.push(IterStats { dist_comps: 100, ..Default::default() });
        rs.push(IterStats { dist_comps: 20, ..Default::default() });
        // n=10, k=10 → lloyd does 100/iter → 200 total.
        assert_eq!(rs.lloyd_equivalent_dist_comps(10, 10), 200);
        assert!((rs.work_ratio(10, 10) - 0.6).abs() < 1e-12);
        assert_eq!(rs.total_dist_comps(), 120);
    }

    #[test]
    fn empty_run_is_nan() {
        let rs = RunStats::default();
        assert!(rs.work_ratio(10, 10).is_nan());
    }

    #[test]
    fn work_efficiency_rolls_up_filter_savings() {
        let mut rs = RunStats::default();
        rs.push(IterStats { dist_comps: 100, ..Default::default() });
        rs.push(IterStats {
            dist_comps: 20,
            filtered_global: 6,
            filtered_group: 30,
            ..Default::default()
        });
        // n=10, k=10 → lloyd would do 200; we did 120.
        let eff = rs.work_efficiency(10, 10);
        assert_eq!(eff.dist_comps, 120);
        assert_eq!(eff.dist_comps_avoided, 80);
        assert_eq!(eff.points_pruned, 6);
        // 30 group hits vs 120 executed comps.
        assert!((eff.group_hit_rate - 30.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn work_efficiency_of_an_unfiltered_run_is_zero_savings() {
        // Lloyd: full scans, nothing filtered — and `avoided` must
        // saturate at 0, never go negative, when comps == lloyd-equiv.
        let mut rs = RunStats::default();
        rs.push(IterStats { dist_comps: 100, ..Default::default() });
        let eff = rs.work_efficiency(10, 10);
        assert_eq!(eff, WorkEfficiency { dist_comps: 100, ..Default::default() });
        assert_eq!(RunStats::default().work_efficiency(10, 10), WorkEfficiency::default());
    }
}
