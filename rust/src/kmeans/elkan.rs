//! Elkan's algorithm: per-centroid lower bounds + inter-centroid pruning.
//!
//! The most aggressive pure-software triangle-inequality variant (Elkan
//! 2003): `n·k` lower bounds, `O(k²)` inter-centroid distances per
//! iteration. It removes the most distance computations but its per-point
//! state (`k` bounds) and irregular control flow are exactly what the paper
//! calls "computation irregularity" — the reason KPynq's hardware design
//! uses the group-level scheme instead. Elkan is reproduced here as the
//! software upper bound on filtering effectiveness for the ablation bench.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::bounds::{deflate_lb, filter_safe, inflate_ub};
use crate::kmeans::hamerly::half_nearest_other;
use crate::kmeans::kernel;
use crate::kmeans::{
    centroid_drifts, compute_inertia, metrics::IterStats, recompute_centroids, FitResult,
    KMeansConfig, RunStats,
};
use crate::obs::profile::{Phase, PhaseTimer};
use crate::util::matrix::Matrix;

pub fn fit(ds: &Dataset, cfg: &KMeansConfig, init: Matrix) -> Result<FitResult> {
    let n = ds.n();
    let k = cfg.k;
    let mut centroids = init;
    let mut assignments = vec![0u32; n];
    let mut ub = vec![0.0f32; n];
    // Per-point per-centroid lower bounds, row-major n×k.
    let mut lb = vec![0.0f32; n * k];
    let mut stats = RunStats::default();
    let mut converged = false;
    let mut iterations = 0;
    // obs::profile phase clock — pure annotation, bit-identical on/off.
    let mut timer = PhaseTimer::new();

    // Iteration 1: full scan, initialise ub and all lower bounds exactly.
    // Elkan's bounds live in sqrt space, so each kernel tile is converted
    // entry-wise to distances *before* the argmin compare — bit-identical
    // to the old per-pair `dist` loop.
    {
        iterations += 1;
        timer.enter(Phase::Init);
        let mut it = IterStats::default();
        let mut comps = 0u64;
        let mut tile = vec![0.0f32; kernel::TILE_POINTS * k];
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + kernel::TILE_POINTS).min(n);
            comps += kernel::sq_dist_block(&ds.points, lo, hi, &centroids, &mut tile[..(hi - lo) * k]);
            for j in 0..hi - lo {
                let i = lo + j;
                let lbrow = &mut lb[i * k..(i + 1) * k];
                let mut best = f32::INFINITY;
                let mut arg = 0usize;
                for c in 0..k {
                    let d = tile[j * k + c].sqrt();
                    lbrow[c] = d;
                    if d < best {
                        best = d;
                        arg = c;
                    }
                }
                assignments[i] = arg as u32;
                ub[i] = best;
            }
            lo = hi;
        }
        debug_assert_eq!(comps, (n as u64) * (k as u64));
        it.dist_comps = comps;
        it.survivors = n as u64;
        it.reassigned = n as u64;
        timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(ds, &assignments, &centroids);
        let (drifts, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);
        if (max_drift as f64) <= cfg.tol {
            converged = true;
        } else {
            timer.enter(Phase::Bounds);
            for i in 0..n {
                ub[i] = inflate_ub(ub[i], drifts[assignments[i] as usize]);
                let lbrow = &mut lb[i * k..(i + 1) * k];
                for c in 0..k {
                    lbrow[c] = deflate_lb(lbrow[c], drifts[c]);
                }
            }
        }
        timer.exit();
    }

    while !converged && iterations < cfg.max_iters {
        iterations += 1;
        let mut it = IterStats::default();
        let mut dist_comps = 0u64;

        // Inter-centroid geometry: s[c] = half distance to nearest other.
        timer.enter(Phase::Assign);
        let (s_half, pair_comps) = half_nearest_other(&centroids);
        dist_comps += pair_comps;

        for (i, row) in ds.points.rows_iter().enumerate() {
            let mut a = assignments[i] as usize;
            // Global test: nothing within 2·s_half[a] can win.
            if filter_safe(s_half[a], ub[i]) {
                it.filtered_global += 1;
                continue;
            }
            let lbrow = &mut lb[i * k..(i + 1) * k];
            let mut ub_i = ub[i];
            let mut tight = false; // is ub_i the exact current distance?
            let mut scanned_any = false;
            for c in 0..k {
                if c == a {
                    continue;
                }
                // Point-level filter: c cannot win if either bound blocks it.
                if filter_safe(lbrow[c], ub_i) {
                    it.filtered_point += 1;
                    continue;
                }
                if !tight {
                    // Tighten before paying for d(x, c).
                    ub_i = kernel::dist_pair(row, centroids.row(a));
                    lbrow[a] = ub_i;
                    dist_comps += 1;
                    tight = true;
                    if filter_safe(lbrow[c], ub_i) {
                        it.filtered_point += 1;
                        continue;
                    }
                }
                let d = kernel::dist_pair(row, centroids.row(c));
                dist_comps += 1;
                scanned_any = true;
                lbrow[c] = d;
                if d < ub_i {
                    a = c;
                    ub_i = d;
                }
            }
            if scanned_any || tight {
                it.survivors += 1;
            } else {
                it.filtered_global += 1;
            }
            ub[i] = ub_i;
            if assignments[i] != a as u32 {
                it.reassigned += 1;
                assignments[i] = a as u32;
            }
        }

        it.dist_comps = dist_comps;
        timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(ds, &assignments, &centroids);
        let (drifts, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);

        if (max_drift as f64) <= cfg.tol {
            converged = true;
        } else {
            timer.enter(Phase::Bounds);
            for i in 0..n {
                ub[i] = inflate_ub(ub[i], drifts[assignments[i] as usize]);
                let lbrow = &mut lb[i * k..(i + 1) * k];
                for c in 0..k {
                    lbrow[c] = deflate_lb(lbrow[c], drifts[c]);
                }
            }
        }
        timer.exit();
    }

    stats.phases = timer.totals();
    let inertia = compute_inertia(ds, &centroids, &assignments);
    Ok(FitResult { centroids, assignments, inertia, iterations, converged, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, init, Algorithm, InitMethod};

    fn cfg(k: usize, seed: u64) -> KMeansConfig {
        KMeansConfig { k, seed, init: InitMethod::KMeansPlusPlus, ..Default::default() }
    }

    #[test]
    fn matches_lloyd_on_blobs() {
        let ds = synth::blobs(600, 10, 5, 13);
        let cfg = cfg(5, 2);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let e = fit(&ds, &cfg, c0).unwrap();
        assert_eq!(l.assignments, e.assignments);
        assert_eq!(l.centroids, e.centroids);
        assert_eq!(l.iterations, e.iterations);
    }

    #[test]
    fn filters_hardest_of_all() {
        let ds = synth::blobs(3000, 16, 8, 5);
        let cfg = cfg(8, 3);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let h = kmeans::fit_from(Algorithm::Hamerly, &ds, &cfg, c0.clone()).unwrap();
        let e = fit(&ds, &cfg, c0).unwrap();
        assert!(
            e.stats.total_dist_comps() <= h.stats.total_dist_comps(),
            "elkan {} should not exceed hamerly {}",
            e.stats.total_dist_comps(),
            h.stats.total_dist_comps()
        );
    }

    #[test]
    fn k1_trivially_converges() {
        let ds = synth::blobs(100, 4, 2, 8);
        let cfg = cfg(1, 1);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let e = fit(&ds, &cfg, c0).unwrap();
        assert!(e.converged);
        assert!(e.assignments.iter().all(|&a| a == 0));
    }
}
