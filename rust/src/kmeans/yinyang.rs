//! The paper's algorithm: multi-level (group + point) filtered K-means.
//!
//! KPynq's "Multi-level Filters" block implements the Yinyang K-means
//! scheme (Ding et al. 2015 — same senior author as KPynq): centroids are
//! clustered once into `G` groups, and each point carries one upper bound
//! (to its assigned centroid) plus `G` group lower bounds. Each iteration
//! applies three filters in sequence:
//!
//! 1. **global filter** — if `min_g lb_g ≥ ub`, the assignment provably
//!    cannot change: zero distance computations.
//! 2. **group-level filter** — otherwise, any group with `lb_g ≥ ub` is
//!    skipped whole.
//! 3. **point-level filter** — inside a surviving group, centroid `c` is
//!    skipped when its drift-adjusted old group bound already exceeds the
//!    current upper bound.
//!
//! The decision logic lives in [`step_point`], a free function over
//! explicit state. Both the software [`fit`] below *and* the accelerator
//! model (`hw::accelerator`) drive the same function, so the hardware
//! simulation is functionally bit-identical to the algorithm by
//! construction, and its cycle model consumes the exact per-level work
//! counts ([`StepCounts`]) the filter produced.
//!
//! Exactness: all bound comparisons go through `bounds::filter_safe`, which
//! requires a float-safety margin, so rounding can only cause *extra*
//! distance computations. The equivalence suite asserts assignments match
//! Lloyd's on every random instance.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::bounds::{filter_safe, group_max_drifts, inflate_ub};
use crate::kmeans::kernel::{self, scan_all};
use crate::kmeans::{
    centroid_drifts, compute_inertia, metrics::IterStats, recompute_centroids, FitResult,
    KMeansConfig, RunStats,
};
use crate::obs::profile::{Phase, PhaseTimer};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// A partition of centroids into groups.
#[derive(Clone, Debug)]
pub struct Grouping {
    /// `group_of[c]` = group index of centroid `c`.
    pub group_of: Vec<usize>,
    /// `members[g]` = centroid indices in group `g` (ascending).
    pub members: Vec<Vec<usize>>,
}

impl Grouping {
    pub fn n_groups(&self) -> usize {
        self.members.len()
    }

    /// One group containing everything (degenerates to Hamerly).
    pub fn trivial(k: usize) -> Grouping {
        Grouping { group_of: vec![0; k], members: vec![(0..k).collect()] }
    }

    fn from_assignment(assign: &[usize], n_groups: usize) -> Grouping {
        let mut members = vec![Vec::new(); n_groups];
        for (c, &g) in assign.iter().enumerate() {
            members[g].push(c);
        }
        Grouping { group_of: assign.to_vec(), members }
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn validate(&self, k: usize) -> bool {
        if self.group_of.len() != k {
            return false;
        }
        let mut seen = vec![false; k];
        for (g, m) in self.members.iter().enumerate() {
            for &c in m {
                if c >= k || seen[c] || self.group_of[c] != g {
                    return false;
                }
                seen[c] = true;
            }
        }
        seen.iter().all(|&s| s)
    }
}

/// Cluster the initial centroids into `n_groups` groups (a few Lloyd
/// iterations over the k centroids themselves, per the Yinyang recipe).
/// Deterministic in `seed`; empty groups are re-filled by splitting the
/// largest group so every group is non-empty.
pub fn group_centroids(centroids: &Matrix, n_groups: usize, seed: u64) -> Grouping {
    let k = centroids.rows();
    let n_groups = n_groups.clamp(1, k);
    if n_groups == 1 {
        return Grouping::trivial(k);
    }
    if n_groups == k {
        return Grouping::from_assignment(&(0..k).collect::<Vec<_>>(), k);
    }

    // Mini k-means++ + Lloyd over the centroid set. The D² columns come
    // from the kernel's column scan — the same per-element `sq_dist`
    // values the old per-pair loop produced.
    let mut rng = Rng::new(seed ^ 0x9159_2A5B_71C3_0DEF);
    let mut seeds = Matrix::zeros(n_groups, centroids.cols());
    let first = rng.next_below(k);
    seeds.row_mut(0).copy_from_slice(centroids.row(first));
    let mut col = vec![0.0f32; k];
    kernel::sq_dists_to(centroids, seeds.row(0), &mut col);
    let mut min_d2: Vec<f64> = col.iter().map(|&v| v as f64).collect();
    for s in 1..n_groups {
        let pick = rng.sample_weighted(&min_d2);
        seeds.row_mut(s).copy_from_slice(centroids.row(pick));
        kernel::sq_dists_to(centroids, seeds.row(s), &mut col);
        for (m, &v) in min_d2.iter_mut().zip(&col) {
            *m = m.min(v as f64);
        }
    }

    let mut assign = vec![0usize; k];
    for _ in 0..5 {
        for c in 0..k {
            let (g, _, _) = scan_all(centroids.row(c), &seeds);
            assign[c] = g;
        }
        // Update seed positions.
        let mut sums = vec![0.0f64; n_groups * centroids.cols()];
        let mut counts = vec![0usize; n_groups];
        for c in 0..k {
            counts[assign[c]] += 1;
            let acc = &mut sums[assign[c] * centroids.cols()..(assign[c] + 1) * centroids.cols()];
            for (a, &v) in acc.iter_mut().zip(centroids.row(c)) {
                *a += v as f64;
            }
        }
        for g in 0..n_groups {
            if counts[g] > 0 {
                let inv = 1.0 / counts[g] as f64;
                for j in 0..centroids.cols() {
                    seeds.row_mut(g)[j] = (sums[g * centroids.cols() + j] * inv) as f32;
                }
            }
        }
    }

    // Repair empty groups: steal one member from the largest group.
    let mut grouping = Grouping::from_assignment(&assign, n_groups);
    loop {
        let empty = match (0..n_groups).find(|&g| grouping.members[g].is_empty()) {
            Some(g) => g,
            None => break,
        };
        let largest = (0..n_groups)
            .max_by_key(|&g| grouping.members[g].len())
            .expect("n_groups >= 1");
        let moved = grouping.members[largest].pop().expect("largest group non-empty");
        grouping.members[empty].push(moved);
        grouping.members[empty].sort_unstable();
        grouping.group_of[moved] = empty;
    }
    grouping
}

/// Per-point bound state for the multi-level filter.
#[derive(Clone, Debug)]
pub struct FilterState {
    pub assignments: Vec<u32>,
    /// Upper bound on d(x, assigned centroid); exact right after a scan.
    pub ub: Vec<f32>,
    /// Group lower bounds, row-major `n × n_groups`: min distance to any
    /// member of the group *excluding the assigned centroid*.
    pub lb: Vec<f32>,
    pub n_groups: usize,
}

impl FilterState {
    /// Initialise by full scan: exactly `n·k` distance computations — the
    /// same first iteration the hardware performs with filters disabled.
    /// Runs on kernel tiles; each tile entry is converted to sqrt space
    /// before any comparison, so the argmin and every group bound carry
    /// the exact bits of the old per-pair `dist` loop.
    pub fn init_full_scan(ds: &Dataset, centroids: &Matrix, grouping: &Grouping) -> (Self, u64) {
        let n = ds.n();
        let k = centroids.rows();
        let g_count = grouping.n_groups();
        let mut assignments = vec![0u32; n];
        let mut ub = vec![0.0f32; n];
        let mut lb = vec![f32::INFINITY; n * g_count];
        let mut dists = vec![0.0f32; k];
        let mut tile = vec![0.0f32; kernel::TILE_POINTS * k];
        let mut comps = 0u64;
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + kernel::TILE_POINTS).min(n);
            comps += kernel::sq_dist_block(&ds.points, lo, hi, centroids, &mut tile[..(hi - lo) * k]);
            for j in 0..hi - lo {
                let i = lo + j;
                let mut best = f32::INFINITY;
                let mut arg = 0usize;
                for c in 0..k {
                    let d = tile[j * k + c].sqrt();
                    dists[c] = d;
                    if d < best {
                        best = d;
                        arg = c;
                    }
                }
                assignments[i] = arg as u32;
                ub[i] = best;
                let lbrow = &mut lb[i * g_count..(i + 1) * g_count];
                for (c, &d) in dists.iter().enumerate() {
                    if c == arg {
                        continue;
                    }
                    let g = grouping.group_of[c];
                    if d < lbrow[g] {
                        lbrow[g] = d;
                    }
                }
            }
            lo = hi;
        }
        debug_assert_eq!(comps, (n as u64) * (k as u64));
        (FilterState { assignments, ub, lb, n_groups: g_count }, comps)
    }

    /// Apply post-update drifts to every bound (the host-side part of the
    /// filter; on the FPGA this is a streaming add over the bound BRAM).
    ///
    /// Group bounds are deliberately NOT clamped at zero: `step_point`
    /// reconstructs the pre-drift bound as `lb + Δ_g` for the point-level
    /// filter, and a clamped value would overestimate it — making the
    /// local filter unsound (it once skipped true winners; see the
    /// `yinyang_equals_lloyd_on_random_instances` property test that
    /// caught it). A negative lower bound is mathematically valid and
    /// simply never filters.
    pub fn apply_drifts(&mut self, drifts: &[f32], group_drifts: &[f32]) {
        let n = self.assignments.len();
        for i in 0..n {
            self.ub[i] = inflate_ub(self.ub[i], drifts[self.assignments[i] as usize]);
            let lbrow = &mut self.lb[i * self.n_groups..(i + 1) * self.n_groups];
            for (g, lb) in lbrow.iter_mut().enumerate() {
                *lb -= group_drifts[g];
            }
        }
    }
}

/// Work performed for one point in one iteration (consumed by the cycle
/// model in `hw::accelerator` as well as by the software stats).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCounts {
    /// Exact distance computations (tighten + group scans).
    pub dists: u32,
    /// Groups eliminated by the group-level filter.
    pub groups_skipped: u32,
    /// Groups that had to be scanned.
    pub groups_scanned: u32,
    /// Centroids eliminated by the point-level (local) filter.
    pub points_skipped: u32,
    /// True if the global filter resolved the point (possibly after the
    /// one-distance tighten).
    pub globally_filtered: bool,
    /// True if the point's assignment changed.
    pub reassigned: bool,
}

/// Advance one point through the multi-level filter.
///
/// `drifts` / `group_drifts` are the *previous* update's movements;
/// `st.apply_drifts` must already have been called for this iteration.
/// Decisions and bound updates are purely a function of the arguments, so
/// any executor (software loop, accelerator model, coordinator tile) that
/// feeds the same state gets the same result.
#[allow(clippy::too_many_arguments)]
pub fn step_point(
    row: &[f32],
    centroids: &Matrix,
    grouping: &Grouping,
    drifts: &[f32],
    group_drifts: &[f32],
    i: usize,
    st: &mut FilterState,
) -> StepCounts {
    let g_count = grouping.n_groups();
    let mut counts = StepCounts::default();
    let a_orig = st.assignments[i] as usize;
    let lbrow_start = i * g_count;

    // ---- Level 0: global filter on the stale upper bound ----
    let mut global_lb = f32::INFINITY;
    for g in 0..g_count {
        global_lb = global_lb.min(st.lb[lbrow_start + g]);
    }
    if filter_safe(global_lb, st.ub[i]) {
        counts.globally_filtered = true;
        return counts;
    }

    // ---- Tighten: one exact distance to the current assignment ----
    let d_a_orig = kernel::dist_pair(row, centroids.row(a_orig));
    counts.dists += 1;
    st.ub[i] = d_a_orig;
    if filter_safe(global_lb, st.ub[i]) {
        counts.globally_filtered = true;
        return counts;
    }

    // ---- Levels 1+2: group scan with the point-level filter ----
    let mut a_cur = a_orig;
    let mut ub_cur = d_a_orig;
    // Deferred per-group best/second (value, centroid) for lb finalisation.
    let mut scanned: Vec<(usize, f32, usize, f32)> = Vec::new(); // (g, min1, min1_c, min2)

    for g in 0..g_count {
        let lb_g = st.lb[lbrow_start + g];
        if filter_safe(lb_g, ub_cur) {
            counts.groups_skipped += 1;
            continue;
        }
        counts.groups_scanned += 1;
        // Pre-drift old bound for the local (point-level) filter.
        let lb_pre = lb_g + group_drifts[g];
        let mut min1 = f32::INFINITY;
        let mut min1_c = usize::MAX;
        let mut min2 = f32::INFINITY;
        for &c in &grouping.members[g] {
            if c == a_orig {
                continue; // its exact distance is ub (handled globally)
            }
            // Point-level filter: c's distance is at least lb_pre - drift[c].
            let local_bound = lb_pre - drifts[c];
            let value = if filter_safe(local_bound, ub_cur) {
                counts.points_skipped += 1;
                local_bound // a valid lower bound for the new lb_g
            } else {
                let d = kernel::dist_pair(row, centroids.row(c));
                counts.dists += 1;
                if d < ub_cur {
                    a_cur = c;
                    ub_cur = d;
                }
                d
            };
            if value < min1 {
                min2 = min1;
                min1 = value;
                min1_c = c;
            } else if value < min2 {
                min2 = value;
            }
        }
        scanned.push((g, min1, min1_c, min2));
    }

    // ---- Finalise bounds ----
    for &(g, min1, min1_c, min2) in &scanned {
        st.lb[lbrow_start + g] = if min1_c == a_cur { min2 } else { min1 };
    }
    if a_cur != a_orig {
        counts.reassigned = true;
        st.assignments[i] = a_cur as u32;
        // The old winner becomes a candidate for its own group's bound.
        let g_old = grouping.group_of[a_orig];
        let slot = lbrow_start + g_old;
        if d_a_orig < st.lb[slot] {
            st.lb[slot] = d_a_orig;
        }
    }
    st.ub[i] = ub_cur;
    counts
}

/// Fit with the multi-level filter from explicit initial centroids.
pub fn fit(ds: &Dataset, cfg: &KMeansConfig, init: Matrix) -> Result<FitResult> {
    let n = ds.n();
    let k = cfg.k;
    let n_groups = cfg.effective_groups().clamp(1, k);
    let mut centroids = init;
    let grouping = group_centroids(&centroids, n_groups, cfg.seed);
    debug_assert!(grouping.validate(k));

    let mut stats = RunStats::default();
    let mut converged = false;
    let mut iterations = 0;
    // obs::profile phase clock — pure annotation, bit-identical on/off.
    let mut timer = PhaseTimer::new();

    // Iteration 1: full scan (bound init).
    timer.enter(Phase::Init);
    let (mut st, init_dists) = FilterState::init_full_scan(ds, &centroids, &grouping);
    let mut drifts;
    let mut group_drifts;
    {
        iterations += 1;
        let mut it = IterStats::default();
        it.dist_comps = init_dists;
        it.survivors = n as u64;
        it.reassigned = n as u64;
        timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(ds, &st.assignments, &centroids);
        let (dr, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);
        group_drifts = group_max_drifts(&dr, &grouping.group_of, grouping.n_groups());
        drifts = dr;
        if (max_drift as f64) <= cfg.tol {
            converged = true;
        } else {
            timer.enter(Phase::Bounds);
            st.apply_drifts(&drifts, &group_drifts);
        }
        timer.exit();
    }

    while !converged && iterations < cfg.max_iters {
        iterations += 1;
        let mut it = IterStats::default();
        timer.enter(Phase::Assign);
        for (i, row) in ds.points.rows_iter().enumerate() {
            let c = step_point(row, &centroids, &grouping, &drifts, &group_drifts, i, &mut st);
            it.dist_comps += c.dists as u64;
            it.filtered_group += c.groups_skipped as u64;
            it.filtered_point += c.points_skipped as u64;
            if c.globally_filtered {
                it.filtered_global += 1;
            } else {
                it.survivors += 1;
            }
            if c.reassigned {
                it.reassigned += 1;
            }
        }

        timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(ds, &st.assignments, &centroids);
        let (dr, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);
        group_drifts = group_max_drifts(&dr, &grouping.group_of, grouping.n_groups());
        drifts = dr;

        if (max_drift as f64) <= cfg.tol {
            converged = true;
        } else {
            timer.enter(Phase::Bounds);
            st.apply_drifts(&drifts, &group_drifts);
        }
        timer.exit();
    }

    stats.phases = timer.totals();
    let inertia = compute_inertia(ds, &centroids, &st.assignments);
    Ok(FitResult {
        centroids,
        assignments: st.assignments,
        inertia,
        iterations,
        converged,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, init, Algorithm, InitMethod};

    fn cfg(k: usize, groups: usize, seed: u64) -> KMeansConfig {
        KMeansConfig {
            k,
            groups,
            seed,
            init: InitMethod::KMeansPlusPlus,
            ..Default::default()
        }
    }

    #[test]
    fn grouping_shapes() {
        let c = Matrix::from_vec((0..32).map(|x| x as f32).collect(), 16, 2).unwrap();
        for g in [1, 2, 4, 15, 16] {
            let gr = group_centroids(&c, g, 7);
            assert_eq!(gr.n_groups(), g);
            assert!(gr.validate(16), "invalid grouping for g={g}");
            assert!(gr.members.iter().all(|m| !m.is_empty()), "empty group for g={g}");
        }
    }

    #[test]
    fn grouping_clusters_nearby_centroids() {
        // Two far-apart bundles of centroids must not share a group (G=2).
        let mut vals = Vec::new();
        for i in 0..4 {
            vals.extend_from_slice(&[i as f32 * 0.1, 0.0]);
        }
        for i in 0..4 {
            vals.extend_from_slice(&[100.0 + i as f32 * 0.1, 0.0]);
        }
        let c = Matrix::from_vec(vals, 8, 2).unwrap();
        let gr = group_centroids(&c, 2, 3);
        let g0 = gr.group_of[0];
        assert!((0..4).all(|i| gr.group_of[i] == g0));
        assert!((4..8).all(|i| gr.group_of[i] != g0));
    }

    #[test]
    fn matches_lloyd_on_blobs() {
        let ds = synth::blobs(800, 12, 6, 17);
        for groups in [1, 2, 3, 6] {
            let cfg = cfg(6, groups, 5);
            let c0 = init::initialize(&ds, &cfg).unwrap();
            let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
            let y = fit(&ds, &cfg, c0).unwrap();
            assert_eq!(l.assignments, y.assignments, "groups={groups}");
            assert_eq!(l.centroids, y.centroids, "groups={groups}");
            assert_eq!(l.iterations, y.iterations, "groups={groups}");
        }
    }

    #[test]
    fn beats_lloyd_on_work() {
        let ds = synth::blobs(3000, 16, 8, 23);
        let cfg = cfg(16, 2, 5);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let y = fit(&ds, &cfg, c0).unwrap();
        assert!(
            (y.stats.total_dist_comps() as f64) < 0.5 * l.stats.total_dist_comps() as f64,
            "yinyang {} vs lloyd {}",
            y.stats.total_dist_comps(),
            l.stats.total_dist_comps()
        );
    }

    #[test]
    fn filter_counter_conservation() {
        // For every point each iteration: globally filtered XOR survived;
        // for survivors, skipped + scanned groups == G.
        let ds = synth::blobs(500, 8, 4, 29);
        let cfg = cfg(8, 3, 7);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let y = fit(&ds, &cfg, c0).unwrap();
        for (t, it) in y.stats.iters.iter().enumerate().skip(1) {
            assert_eq!(it.filtered_global + it.survivors, 500, "iter {t}");
            // A survivor inspects each of the G=3 groups at most once, so
            // group-filter eliminations are bounded by survivors × G.
            assert!(it.filtered_group <= it.survivors * 3, "iter {t}");
            // Point-level skips can only happen inside scanned groups.
            assert!(it.filtered_point <= it.survivors * 8, "iter {t}");
        }
    }

    #[test]
    fn works_when_groups_equal_k() {
        let ds = synth::blobs(300, 6, 4, 31);
        let cfg = cfg(4, 4, 3);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let y = fit(&ds, &cfg, c0).unwrap();
        assert_eq!(l.assignments, y.assignments);
    }

    #[test]
    fn single_group_degenerates_to_hamerly_equivalence() {
        let ds = synth::blobs(400, 5, 3, 37);
        let cfg = cfg(3, 1, 9);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let l = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let y = fit(&ds, &cfg, c0).unwrap();
        assert_eq!(l.assignments, y.assignments);
    }
}
