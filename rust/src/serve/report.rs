//! Aggregated serving telemetry: what the pool did, how long tenants
//! waited, and where the engine time went.
//!
//! A [`ServeReport`] is built once per serving session from three sources:
//! the per-job [`FitResponse`]s (latency distribution, per-backend
//! `coordinator::telemetry::RunReport` aggregation), the per-worker
//! counters (busy time, batch sizes) and the admission queue's shed/depth
//! counters. Responses are folded in *streaming* by a
//! `ResponseAccumulator` (crate-private) — the session router observes
//! each response as it is delivered, so a long-lived daemon (`serve::net`)
//! never has to
//! retain the full response history to report on it. The daemon folds its
//! connection counters ([`ServeReport::connections`] and friends) in on
//! top. It renders as a paste-ready table (`util::bench::Table`), the
//! same surface the paper-figure benches use.

use std::collections::BTreeMap;

use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::stats::percentile;

use super::job::{FitResponse, JobStatus};
use super::queue::QueueStats;
use super::worker::WorkerStats;

/// The bucket absorbing tenants past the `max_tracked_tenants`
/// cardinality cap (PROTOCOL.md §3). `~` is outside the tenant-label
/// charset, so a real tenant can never collide with it.
pub const OVERFLOW_TENANT: &str = "~other";

/// Streaming per-tenant accounting (PROTOCOL.md §6, the `stats` reply's
/// `tenants` object). The response router folds every response whose
/// request carried a non-empty `tenant` into one of these; the cluster
/// front keeps the same table over delivered responses. Tenancy drives
/// scheduling (weighted-fair pops, per-tenant queue quotas — PROTOCOL.md
/// §7) but never the result bits of an individual fit.
#[derive(Clone, Debug, Default)]
pub struct TenantAcc {
    /// Responses delivered with `status: "ok"`.
    pub answered: u64,
    /// Responses delivered with `status: "shed"` or `"failed"`.
    pub shed: u64,
    /// Tenant-observed latency samples (queue + service), completed jobs.
    pub latencies_ms: Vec<f64>,
}

impl TenantAcc {
    pub fn observe(&mut self, resp: &FitResponse) {
        match resp.status {
            JobStatus::Ok => {
                self.answered += 1;
                self.latencies_ms.push(resp.latency_seconds() * 1e3);
            }
            JobStatus::Shed | JobStatus::Failed => self.shed += 1,
        }
    }

    /// The tenant's `stats`-reply entry: counts plus nearest-rank
    /// percentiles (0.0, never NaN, when no job completed).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("answered".into(), Json::Num(self.answered as f64));
        m.insert("shed".into(), Json::Num(self.shed as f64));
        let (p50, p95) = if self.latencies_ms.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&self.latencies_ms, 50.0), percentile(&self.latencies_ms, 95.0))
        };
        m.insert("p50_ms".into(), Json::Num(p50));
        m.insert("p95_ms".into(), Json::Num(p95));
        Json::Obj(m)
    }
}

/// Render a tenant table as the `stats` reply's `tenants` object —
/// `{}` when no tenanted job has been seen.
pub fn tenants_json(tenants: &BTreeMap<String, TenantAcc>) -> Json {
    Json::Obj(tenants.iter().map(|(t, acc)| (t.clone(), acc.to_json())).collect())
}

/// [`tenants_json`] plus live queue depths: each tenant's entry gains a
/// `queued` count (0 when drained), and a tenant whose first job is
/// still waiting appears with *only* queue state — the `stats` reply
/// shows it before any response has been delivered (PROTOCOL.md §6).
pub fn tenants_json_with_queue(
    tenants: &BTreeMap<String, TenantAcc>,
    queued: &BTreeMap<String, usize>,
) -> Json {
    let mut out = BTreeMap::new();
    for (t, acc) in tenants {
        let mut entry = match acc.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("TenantAcc::to_json returns an object"),
        };
        let depth = queued.get(t).copied().unwrap_or(0);
        entry.insert("queued".into(), Json::Num(depth as f64));
        out.insert(t.clone(), Json::Obj(entry));
    }
    for (t, depth) in queued {
        if !out.contains_key(t) {
            let mut entry = match TenantAcc::default().to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("TenantAcc::to_json returns an object"),
            };
            entry.insert("queued".into(), Json::Num(*depth as f64));
            out.insert(t.clone(), Json::Obj(entry));
        }
    }
    Json::Obj(out)
}

/// Engine-time accounting for one backend, summed over completed jobs
/// (the serve-level rollup of `coordinator::telemetry::RunReport`).
#[derive(Clone, Debug, Default)]
pub struct BackendUtilization {
    pub backend: String,
    pub jobs: u64,
    /// Sum of per-fit wall-clock (engine backends) — the busy currency.
    pub fit_seconds: f64,
    /// Sum of simulated PL cycles (fpga-sim jobs; 0 otherwise).
    pub total_cycles: u64,
    pub tiles_dispatched: u64,
    pub points_rescanned: u64,
    /// Distance computations actually performed (work-efficiency rollup
    /// of `RunReport::work` across this backend's completed jobs).
    pub dist_comps: u64,
    /// Distance computations the triangle-inequality filters avoided
    /// relative to Lloyd's n·k-per-iteration baseline.
    pub dist_comps_avoided: u64,
}

/// What one serving session cost and delivered.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    /// All shed jobs (queue-full + deadline + closed).
    pub shed: u64,
    pub shed_full: u64,
    pub shed_deadline: u64,
    pub peak_queue_depth: usize,
    pub workers: usize,
    /// Micro-batches executed (solo jobs count as batches of one).
    pub batches: u64,
    pub max_batch: usize,
    /// Jobs that rode in a coalesced batch (size ≥ 2).
    pub batched_jobs: u64,
    /// Summed worker busy time (execution, not queue waits).
    pub busy_seconds: f64,
    /// End-to-end session wall-clock.
    pub wall_seconds: f64,
    /// Tenant-observed latency (queue + service) over completed jobs.
    /// All three are 0.0 (not NaN) for a session that completed nothing —
    /// daemon sessions can drain with every job shed or no traffic at all.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub max_latency_ms: f64,
    pub per_backend: Vec<BackendUtilization>,
    /// Client connections accepted over a daemon's lifetime (`serve::net`;
    /// 0 in batch mode).
    pub connections: u64,
    /// Highest simultaneous connection count (daemon mode).
    pub peak_connections: usize,
    /// Connections refused at the `max_conns` cap (daemon mode).
    pub refused_connections: u64,
    /// Wire frames answered with a protocol-error reply (malformed JSON,
    /// unknown keys, oversized lines, bad handshakes — PROTOCOL.md §5).
    pub protocol_errors: u64,
    /// Responses whose submitter had disconnected before delivery.
    pub dropped_replies: u64,
    /// Shard daemons restarted by a cluster supervisor (`kpynq cluster`;
    /// 0 for single-process sessions).
    pub shard_restarts: u64,
}

/// Streaming fold of [`FitResponse`]s into report form. The session's
/// response router observes every response exactly once on its way to the
/// submitter; [`ResponseAccumulator::into_report`] then joins the fold
/// with the worker/queue counters.
#[derive(Debug, Default)]
pub(crate) struct ResponseAccumulator {
    completed: u64,
    failed: u64,
    shed: u64,
    latencies_ms: Vec<f64>,
    by_backend: BTreeMap<String, BackendUtilization>,
    dropped_replies: u64,
}

impl ResponseAccumulator {
    pub(crate) fn observe(&mut self, resp: &FitResponse) {
        match resp.status {
            JobStatus::Ok => {
                self.completed += 1;
                self.latencies_ms.push(resp.latency_seconds() * 1e3);
                if let Some(rep) = &resp.report {
                    let u = self.by_backend.entry(rep.backend.clone()).or_insert_with(|| {
                        BackendUtilization { backend: rep.backend.clone(), ..Default::default() }
                    });
                    u.jobs += 1;
                    u.fit_seconds += rep.wall_seconds;
                    u.total_cycles += rep.total_cycles;
                    u.tiles_dispatched += rep.tiles_dispatched;
                    u.points_rescanned += rep.points_rescanned;
                    u.dist_comps += rep.work.dist_comps;
                    u.dist_comps_avoided += rep.work.dist_comps_avoided;
                }
            }
            JobStatus::Shed => self.shed += 1,
            JobStatus::Failed => self.failed += 1,
        }
    }

    pub(crate) fn count_dropped_reply(&mut self) {
        self.dropped_replies += 1;
    }

    pub(crate) fn into_report(
        self,
        submitted: u64,
        workers: &[WorkerStats],
        queue: QueueStats,
        wall_seconds: f64,
    ) -> ServeReport {
        let mut r = ServeReport {
            submitted,
            wall_seconds,
            workers: workers.len(),
            completed: self.completed,
            failed: self.failed,
            shed: self.shed,
            shed_full: queue.shed_full,
            shed_deadline: queue.shed_deadline,
            peak_queue_depth: queue.peak_depth,
            dropped_replies: self.dropped_replies,
            per_backend: self.by_backend.into_values().collect(),
            ..Default::default()
        };
        for w in workers {
            r.batches += w.batches;
            r.max_batch = r.max_batch.max(w.max_batch);
            r.batched_jobs += w.batched_jobs;
            r.busy_seconds += w.busy_seconds;
        }
        // An idle daemon window completes nothing; `util::stats::percentile`
        // returns NaN on empty input, so the empty window must short-circuit
        // to the 0.0 defaults (pinned by `empty_accumulator_reports_zeros`).
        if !self.latencies_ms.is_empty() {
            r.p50_latency_ms = percentile(&self.latencies_ms, 50.0);
            r.p95_latency_ms = percentile(&self.latencies_ms, 95.0);
            r.max_latency_ms = self.latencies_ms.iter().cloned().fold(0.0f64, f64::max);
        }
        r
    }
}

impl ServeReport {
    pub(crate) fn build(
        submitted: u64,
        responses: &[FitResponse],
        workers: &[WorkerStats],
        queue: QueueStats,
        wall_seconds: f64,
    ) -> ServeReport {
        let mut acc = ResponseAccumulator::default();
        for resp in responses {
            acc.observe(resp);
        }
        acc.into_report(submitted, workers, queue, wall_seconds)
    }

    /// Fold another session's report into this one — the fan-in side of
    /// multi-shard serving (`kpynq cluster`), also usable by ops tooling
    /// aggregating several daemons. Count fields add; peak fields take
    /// the max. Latency percentiles cannot be merged exactly from
    /// percentiles, so `p50`/`p95`/`max` take the max across the inputs —
    /// a conservative (upper-bound) cluster figure, not a recomputed
    /// distribution. Per-backend rollups merge by backend name.
    pub fn merge(&mut self, other: &ServeReport) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.failed += other.failed;
        self.shed += other.shed;
        self.shed_full += other.shed_full;
        self.shed_deadline += other.shed_deadline;
        self.workers += other.workers;
        self.batches += other.batches;
        self.batched_jobs += other.batched_jobs;
        self.busy_seconds += other.busy_seconds;
        self.connections += other.connections;
        self.refused_connections += other.refused_connections;
        self.protocol_errors += other.protocol_errors;
        self.dropped_replies += other.dropped_replies;
        self.shard_restarts += other.shard_restarts;
        self.peak_queue_depth = self.peak_queue_depth.max(other.peak_queue_depth);
        self.max_batch = self.max_batch.max(other.max_batch);
        self.peak_connections = self.peak_connections.max(other.peak_connections);
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        self.p50_latency_ms = self.p50_latency_ms.max(other.p50_latency_ms);
        self.p95_latency_ms = self.p95_latency_ms.max(other.p95_latency_ms);
        self.max_latency_ms = self.max_latency_ms.max(other.max_latency_ms);
        for u in &other.per_backend {
            match self.per_backend.iter_mut().find(|m| m.backend == u.backend) {
                Some(m) => {
                    m.jobs += u.jobs;
                    m.fit_seconds += u.fit_seconds;
                    m.total_cycles += u.total_cycles;
                    m.tiles_dispatched += u.tiles_dispatched;
                    m.points_rescanned += u.points_rescanned;
                    m.dist_comps += u.dist_comps;
                    m.dist_comps_avoided += u.dist_comps_avoided;
                }
                None => self.per_backend.push(u.clone()),
            }
        }
    }

    /// Completed jobs per wall-clock second.
    pub fn throughput_jobs_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.completed as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Fraction of pool capacity spent executing (1.0 = every worker busy
    /// the whole session).
    pub fn pool_utilization(&self) -> f64 {
        let capacity = self.wall_seconds * self.workers as f64;
        if capacity > 0.0 {
            self.busy_seconds / capacity
        } else {
            0.0
        }
    }

    /// Paste-ready summary (headline + per-backend table).
    pub fn render(&self) -> String {
        let mut out = format!(
            "serve: {} submitted | {} ok, {} failed, {} shed ({} full, {} deadline) | \
             {:.2} jobs/s over {:.3}s wall\n\
             pool: {} workers, {:.1}% busy | {} batches, max batch {}, {} coalesced jobs | \
             peak queue depth {}\n\
             latency: p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms\n",
            self.submitted,
            self.completed,
            self.failed,
            self.shed,
            self.shed_full,
            self.shed_deadline,
            self.throughput_jobs_per_sec(),
            self.wall_seconds,
            self.workers,
            self.pool_utilization() * 100.0,
            self.batches,
            self.max_batch,
            self.batched_jobs,
            self.peak_queue_depth,
            self.p50_latency_ms,
            self.p95_latency_ms,
            self.max_latency_ms,
        );
        if self.connections > 0 || self.refused_connections > 0 || self.protocol_errors > 0 {
            out.push_str(&format!(
                "net: {} connections (peak {}, {} refused) | {} protocol errors | \
                 {} undeliverable replies\n",
                self.connections,
                self.peak_connections,
                self.refused_connections,
                self.protocol_errors,
                self.dropped_replies,
            ));
        }
        if self.shard_restarts > 0 {
            out.push_str(&format!("cluster: {} shard restarts\n", self.shard_restarts));
        }
        if !self.per_backend.is_empty() {
            let mut t = Table::new(&[
                "backend",
                "jobs",
                "fit_s",
                "tiles",
                "rescanned",
                "dist_comps",
                "avoided",
                "sim_cycles",
            ]);
            for u in &self.per_backend {
                t.row(vec![
                    u.backend.clone(),
                    u.jobs.to_string(),
                    format!("{:.3}", u.fit_seconds),
                    u.tiles_dispatched.to_string(),
                    u.points_rescanned.to_string(),
                    u.dist_comps.to_string(),
                    u.dist_comps_avoided.to_string(),
                    u.total_cycles.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunReport;
    use crate::kmeans::metrics::WorkEfficiency;
    use crate::serve::job::FitResponse;

    fn ok_response(id: u64, backend: &str, queue_s: f64, service_s: f64) -> FitResponse {
        FitResponse {
            id,
            status: JobStatus::Ok,
            detail: String::new(),
            backend: backend.into(),
            worker: 0,
            batch_size: 1,
            queue_seconds: queue_s,
            service_seconds: service_s,
            summary: None,
            fit: None,
            report: Some(RunReport {
                backend: backend.into(),
                wall_seconds: service_s,
                tiles_dispatched: 4,
                points_rescanned: 100,
                work: WorkEfficiency {
                    dist_comps: 800,
                    dist_comps_avoided: 200,
                    points_pruned: 50,
                    group_hit_rate: 0.25,
                },
                ..Default::default()
            }),
            trace_id: String::new(),
            tenant: String::new(),
            cached: false,
        }
    }

    #[test]
    fn build_aggregates_statuses_latency_and_backends() {
        let responses = vec![
            ok_response(1, "native", 0.010, 0.090),
            ok_response(2, "native", 0.020, 0.080),
            ok_response(3, "fpga-sim", 0.000, 0.200),
            FitResponse::shed(4, "queue full", 0.001),
        ];
        let workers = vec![
            WorkerStats { worker: 0, jobs: 2, batches: 2, max_batch: 2, batched_jobs: 2, busy_seconds: 0.2 },
            WorkerStats { worker: 1, jobs: 1, batches: 1, max_batch: 1, batched_jobs: 0, busy_seconds: 0.2 },
        ];
        let q = QueueStats { shed_full: 1, shed_deadline: 0, peak_depth: 3 };
        let r = ServeReport::build(4, &responses, &workers, q, 0.4);
        assert_eq!(r.submitted, 4);
        assert_eq!(r.completed, 3);
        assert_eq!(r.shed, 1);
        assert_eq!(r.failed, 0);
        assert_eq!(r.workers, 2);
        assert_eq!(r.max_batch, 2);
        assert_eq!(r.batched_jobs, 2);
        assert_eq!(r.peak_queue_depth, 3);
        // Latencies: 100, 100, 200 ms.
        assert!((r.p50_latency_ms - 100.0).abs() < 1e-9);
        assert!((r.max_latency_ms - 200.0).abs() < 1e-9);
        assert_eq!(r.per_backend.len(), 2);
        let native = r.per_backend.iter().find(|u| u.backend == "native").unwrap();
        assert_eq!(native.jobs, 2);
        assert_eq!(native.tiles_dispatched, 8);
        assert_eq!(native.dist_comps, 1600, "work-efficiency counters sum per backend");
        assert_eq!(native.dist_comps_avoided, 400);
        // 3 jobs / 0.4 s.
        assert!((r.throughput_jobs_per_sec() - 7.5).abs() < 1e-9);
        // 0.4 busy over 0.8 capacity.
        assert!((r.pool_utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn render_contains_the_headline_and_table() {
        let responses = vec![ok_response(1, "native", 0.0, 0.1)];
        let workers = vec![WorkerStats { worker: 0, jobs: 1, batches: 1, max_batch: 1, ..Default::default() }];
        let r = ServeReport::build(1, &responses, &workers, QueueStats::default(), 0.1);
        let text = r.render();
        assert!(text.contains("1 ok"), "{text}");
        assert!(text.contains("| native |") || text.contains("|  native |"), "{text}");
    }

    #[test]
    fn empty_session_reports_zeros() {
        let r = ServeReport::build(0, &[], &[], QueueStats::default(), 0.0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.throughput_jobs_per_sec(), 0.0);
        assert_eq!(r.pool_utilization(), 0.0);
        assert_eq!(r.p50_latency_ms, 0.0);
    }

    #[test]
    fn empty_accumulator_reports_zeros() {
        // An idle daemon window: responses observed = 0. The percentile
        // helper returns NaN on empty input; the report must not leak it.
        let acc = ResponseAccumulator::default();
        let r = acc.into_report(0, &[], QueueStats::default(), 1.0);
        assert_eq!(r.p50_latency_ms, 0.0);
        assert_eq!(r.p95_latency_ms, 0.0);
        assert_eq!(r.max_latency_ms, 0.0);
        assert!(!r.p50_latency_ms.is_nan());
    }

    #[test]
    fn single_sample_window_reports_that_sample() {
        // A daemon window with exactly one completed job: every percentile
        // is that one latency (nearest-rank on a singleton).
        let mut acc = ResponseAccumulator::default();
        acc.observe(&ok_response(1, "native", 0.010, 0.090));
        let r = acc.into_report(1, &[], QueueStats::default(), 0.1);
        assert!((r.p50_latency_ms - 100.0).abs() < 1e-9);
        assert!((r.p95_latency_ms - 100.0).abs() < 1e-9);
        assert!((r.max_latency_ms - 100.0).abs() < 1e-9);
    }

    #[test]
    fn streaming_accumulator_matches_batch_build() {
        let responses = vec![
            ok_response(1, "native", 0.010, 0.090),
            ok_response(2, "fpga-sim", 0.0, 0.2),
            FitResponse::shed(3, "queue full", 0.001),
        ];
        let batch = ServeReport::build(3, &responses, &[], QueueStats::default(), 0.5);
        let mut acc = ResponseAccumulator::default();
        for resp in &responses {
            acc.observe(resp);
        }
        let streamed = acc.into_report(3, &[], QueueStats::default(), 0.5);
        assert_eq!(batch.completed, streamed.completed);
        assert_eq!(batch.shed, streamed.shed);
        assert_eq!(batch.p50_latency_ms, streamed.p50_latency_ms);
        assert_eq!(batch.p95_latency_ms, streamed.p95_latency_ms);
        assert_eq!(batch.per_backend.len(), streamed.per_backend.len());
    }

    #[test]
    fn merge_sums_counts_and_maxes_peaks() {
        let mut a = ServeReport::build(
            3,
            &[ok_response(1, "native", 0.0, 0.1), ok_response(2, "native", 0.0, 0.2)],
            &[WorkerStats { worker: 0, jobs: 2, batches: 2, max_batch: 1, ..Default::default() }],
            QueueStats { shed_full: 1, shed_deadline: 0, peak_depth: 4 },
            0.5,
        );
        let b = ServeReport::build(
            2,
            &[ok_response(1, "native", 0.0, 0.4), ok_response(2, "fpga-sim", 0.0, 0.1)],
            &[WorkerStats { worker: 0, jobs: 2, batches: 1, max_batch: 2, ..Default::default() }],
            QueueStats { shed_full: 0, shed_deadline: 2, peak_depth: 2 },
            0.3,
        );
        a.merge(&b);
        assert_eq!(a.submitted, 5);
        assert_eq!(a.completed, 4);
        assert_eq!(a.shed_full, 1);
        assert_eq!(a.shed_deadline, 2);
        assert_eq!(a.peak_queue_depth, 4, "peaks take the max");
        assert_eq!(a.max_batch, 2);
        assert_eq!(a.wall_seconds, 0.5, "wall is the max, not the sum");
        // 400 ms is b's max latency; the merged upper bound keeps it.
        assert!((a.max_latency_ms - 400.0).abs() < 1e-9);
        let native = a.per_backend.iter().find(|u| u.backend == "native").unwrap();
        assert_eq!(native.jobs, 3, "per-backend rollups merge by name");
        assert_eq!(native.dist_comps, 2400, "work counters merge too");
        assert!(a.per_backend.iter().any(|u| u.backend == "fpga-sim"));
    }

    #[test]
    fn tenant_accounting_rolls_up_latency_and_sheds() {
        let mut by_tenant: BTreeMap<String, TenantAcc> = BTreeMap::new();
        let mut ok = ok_response(1, "native", 0.010, 0.090);
        ok.tenant = "acme".into();
        by_tenant.entry(ok.tenant.clone()).or_default().observe(&ok);
        let mut shed = FitResponse::shed(2, "queue full", 0.001);
        shed.tenant = "acme".into();
        by_tenant.entry(shed.tenant.clone()).or_default().observe(&shed);
        let j = tenants_json(&by_tenant);
        let acme = j.get("acme").unwrap();
        assert_eq!(acme.get("answered").unwrap().as_usize().unwrap(), 1);
        assert_eq!(acme.get("shed").unwrap().as_usize().unwrap(), 1);
        assert!((acme.get("p50_ms").unwrap().as_f64().unwrap() - 100.0).abs() < 1e-9);
        assert!((acme.get("p95_ms").unwrap().as_f64().unwrap() - 100.0).abs() < 1e-9);
        // A tenant with only sheds reports 0.0 percentiles, never NaN.
        let lone = TenantAcc { shed: 3, ..Default::default() };
        assert_eq!(lone.to_json().get("p50_ms").unwrap().as_f64().unwrap(), 0.0);
        // No tenanted traffic at all → an empty object.
        assert!(tenants_json(&BTreeMap::new()).get("acme").is_err());
    }

    #[test]
    fn queue_depths_merge_into_the_tenant_table() {
        let mut by_tenant: BTreeMap<String, TenantAcc> = BTreeMap::new();
        let mut ok = ok_response(1, "native", 0.010, 0.090);
        ok.tenant = "acme".into();
        by_tenant.entry(ok.tenant.clone()).or_default().observe(&ok);
        let mut queued = BTreeMap::new();
        queued.insert("acme".to_string(), 2usize);
        queued.insert("newbie".to_string(), 5usize);
        let j = tenants_json_with_queue(&by_tenant, &queued);
        let acme = j.get("acme").unwrap();
        assert_eq!(acme.get("answered").unwrap().as_usize().unwrap(), 1);
        assert_eq!(acme.get("queued").unwrap().as_usize().unwrap(), 2);
        // A tenant with queued work but no delivered response yet still
        // shows up — zero counts, live depth.
        let newbie = j.get("newbie").unwrap();
        assert_eq!(newbie.get("answered").unwrap().as_usize().unwrap(), 0);
        assert_eq!(newbie.get("queued").unwrap().as_usize().unwrap(), 5);
        // Drained tenants report queued: 0, not a missing key.
        let j = tenants_json_with_queue(&by_tenant, &BTreeMap::new());
        assert_eq!(
            j.get("acme").unwrap().get("queued").unwrap().as_usize().unwrap(),
            0
        );
    }

    #[test]
    fn shard_restarts_render_only_when_nonzero() {
        let mut r = ServeReport::build(0, &[], &[], QueueStats::default(), 0.0);
        assert!(!r.render().contains("shard restarts"), "{}", r.render());
        r.shard_restarts = 2;
        assert!(r.render().contains("cluster: 2 shard restarts"), "{}", r.render());
    }

    #[test]
    fn net_counters_render_only_for_daemon_sessions() {
        let mut r = ServeReport::build(0, &[], &[], QueueStats::default(), 0.0);
        assert!(!r.render().contains("net:"), "batch sessions have no net line");
        r.connections = 3;
        r.peak_connections = 2;
        r.protocol_errors = 1;
        let text = r.render();
        assert!(text.contains("net: 3 connections (peak 2, 0 refused)"), "{text}");
        assert!(text.contains("1 protocol errors"), "{text}");
    }
}
