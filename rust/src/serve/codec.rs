//! The shared wire codec: line framing both protocol peers use.
//!
//! PR 3's daemon owned the only implementation of the NDJSON framing —
//! the bounded [`LineReader`], the 64 KiB line cap, the line-locked
//! writer. The cluster layer ([`crate::cluster`]) puts a *client* on the
//! same wire, and a client that copied the framing would inevitably
//! drift from it (the daemon's writer-side shutdown also used to assume
//! the daemon owns the socket lifetime). So the codec lives here once,
//! and `serve::net` (server side) and `cluster::client` (client side)
//! are both thin users of it. The framing rules themselves are normative
//! in PROTOCOL.md §2; this module implements them and cites them.
//!
//! What lives here:
//!
//! * [`MAX_LINE_BYTES`] — the request-line cap (PROTOCOL.md §2).
//! * [`LineReader`] / [`LineEvent`] — incremental, bounded line framing
//!   over a timeout-ticking stream.
//! * [`write_line`] — one whole protocol line under a writer lock, so
//!   concurrent writers never tear frames.
//! * [`Stream`] — the TCP-or-Unix stream both peers speak over, plus
//!   [`Stream::connect`] for the client side of the `host:port` /
//!   `unix:<path>` address notation `Daemon::bind` accepts.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, Result};

/// Hard cap on one protocol line (PROTOCOL.md §2). Longer lines are
/// answered with a structured error and discarded up to the next newline.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// The minimal stream surface both TCP and Unix-domain sockets provide;
/// connection handling (daemon and client alike) is generic over it.
pub trait WireStream: Read + Write + Send + Sized + 'static {
    fn try_clone_stream(&self) -> io::Result<Self>;
    /// Force blocking mode: whether an accepted socket inherits the
    /// listener's non-blocking flag is platform-dependent, and the read
    /// loop's timeout ticks assume a blocking socket (a non-blocking one
    /// would spin hot instead of sleeping up to the read tick).
    fn set_blocking(&self) -> io::Result<()>;
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()>;
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()>;
    fn shutdown_stream(&self);
}

impl WireStream for TcpStream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        self.try_clone()
    }
    fn set_blocking(&self) -> io::Result<()> {
        self.set_nonblocking(false)
    }
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_write_timeout(d)
    }
    fn shutdown_stream(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

#[cfg(unix)]
impl WireStream for std::os::unix::net::UnixStream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        self.try_clone()
    }
    fn set_blocking(&self) -> io::Result<()> {
        self.set_nonblocking(false)
    }
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_write_timeout(d)
    }
    fn shutdown_stream(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

/// A connected protocol stream: TCP or (on Unix) Unix-domain — the
/// client-side counterpart of the daemon's listener, speaking the same
/// address notation (`host:port` or `unix:<path>`).
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl Stream {
    /// Connect to a daemon at `host:port` or `unix:<path>`.
    pub fn connect(addr: &str) -> Result<Stream> {
        match addr.strip_prefix("unix:") {
            Some(path) => connect_unix(path),
            None => {
                let s = TcpStream::connect(addr)
                    .map_err(|e| Error::Io(io::Error::new(e.kind(), format!("{addr}: {e}"))))?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
        }
    }
}

#[cfg(unix)]
fn connect_unix(path: &str) -> Result<Stream> {
    let s = std::os::unix::net::UnixStream::connect(path)
        .map_err(|e| Error::Io(io::Error::new(e.kind(), format!("unix:{path}: {e}"))))?;
    Ok(Stream::Unix(s))
}

#[cfg(not(unix))]
fn connect_unix(_path: &str) -> Result<Stream> {
    Err(Error::Config("unix-domain sockets are only available on Unix platforms".into()))
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

impl WireStream for Stream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }
    fn set_blocking(&self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(false),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(false),
        }
    }
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_write_timeout(d),
        }
    }
    fn shutdown_stream(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

/// Write one full protocol line under the peer's writer lock.
pub fn write_line<S: Write>(out: &Mutex<S>, line: &str) -> io::Result<()> {
    let mut w = out.lock().expect("wire writer lock poisoned");
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// One step of a connection read loop.
pub enum LineEvent {
    /// A complete line (without its terminator).
    Line(Vec<u8>),
    /// A line exceeded [`MAX_LINE_BYTES`]; its bytes are being discarded
    /// up to the next newline.
    Oversized,
    /// The read timeout elapsed with no data — time to check shutdown
    /// flags and idle budgets. Never produced on a stream with no read
    /// timeout set.
    Tick,
    Eof,
    Error(io::Error),
}

/// Incremental, bounded line reader over a timeout-ticking stream.
/// `BufReader::read_line` can neither bound a hostile line's memory nor
/// surface timeout ticks mid-line, so the accumulation is explicit here.
pub struct LineReader<S: Read> {
    stream: S,
    acc: Vec<u8>,
    discarding: bool,
}

impl<S: Read> LineReader<S> {
    pub fn new(stream: S) -> Self {
        Self { stream, acc: Vec::new(), discarding: false }
    }

    pub fn into_inner(self) -> S {
        self.stream
    }

    /// The wrapped stream (for timeout adjustments mid-conversation).
    pub fn get_ref(&self) -> &S {
        &self.stream
    }

    pub fn next_event(&mut self) -> LineEvent {
        loop {
            if let Some(i) = self.acc.iter().position(|&b| b == b'\n') {
                let rest = self.acc.split_off(i + 1);
                let mut line = std::mem::replace(&mut self.acc, rest);
                line.pop(); // the newline
                if self.discarding {
                    // Tail of an oversized line: drop it and resume normal
                    // framing from the next line.
                    self.discarding = false;
                    continue;
                }
                if line.len() > MAX_LINE_BYTES {
                    return LineEvent::Oversized; // complete, but too long
                }
                return LineEvent::Line(line);
            }
            if self.discarding {
                self.acc.clear(); // bound memory while hunting the newline
            } else if self.acc.len() > MAX_LINE_BYTES {
                self.discarding = true;
                self.acc.clear();
                return LineEvent::Oversized;
            }
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    // A final line without its terminator still counts (a
                    // `printf` without `\n` followed by EOF); discarded
                    // oversize tails do not.
                    if self.acc.is_empty() || self.discarding {
                        return LineEvent::Eof;
                    }
                    return LineEvent::Line(std::mem::take(&mut self.acc));
                }
                Ok(n) => self.acc.extend_from_slice(&buf[..n]),
                Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                    return LineEvent::Tick
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return LineEvent::Error(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted reader: each entry is either bytes to deliver or a
    /// would-block tick.
    struct Script(Vec<Option<Vec<u8>>>);

    impl Read for Script {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.0.pop() {
                None => Ok(0), // EOF
                Some(None) => Err(io::Error::new(io::ErrorKind::WouldBlock, "tick")),
                Some(Some(mut bytes)) => {
                    let n = bytes.len().min(buf.len());
                    buf[..n].copy_from_slice(&bytes[..n]);
                    if n < bytes.len() {
                        // Hand the remainder back as the next read.
                        self.0.push(Some(bytes.split_off(n)));
                    }
                    Ok(n)
                }
            }
        }
    }

    fn reader(script: Vec<Option<&[u8]>>) -> LineReader<Script> {
        LineReader::new(Script(
            script.into_iter().rev().map(|e| e.map(|b| b.to_vec())).collect(),
        ))
    }

    #[test]
    fn line_reader_splits_and_reassembles_partial_lines() {
        let mut r = reader(vec![Some(&b"{\"id\""[..]), Some(&b":1}\n{\"id\":2}\n"[..])]);
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"{\"id\":1}"));
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"{\"id\":2}"));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn line_reader_surfaces_ticks_between_chunks() {
        let mut r = reader(vec![None, Some(&b"x\n"[..]), None]);
        assert!(matches!(r.next_event(), LineEvent::Tick));
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"x"));
        assert!(matches!(r.next_event(), LineEvent::Tick));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn line_reader_discards_oversized_lines_and_recovers() {
        let big = vec![b'a'; MAX_LINE_BYTES + 4096];
        let mut r = reader(vec![Some(&big[..]), Some(&b"bbb\nok\n"[..])]);
        assert!(matches!(r.next_event(), LineEvent::Oversized));
        // The giant line's tail ("bbb\n") is swallowed; framing resumes at
        // the next line.
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"ok"));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn line_reader_yields_an_unterminated_final_line() {
        let mut r = reader(vec![Some(&b"a\nb"[..])]);
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"a"));
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"b"));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn stream_connect_rejects_unreachable_addresses() {
        // Nothing listens here; the point is the error carries the address.
        let err = Stream::connect("127.0.0.1:1").unwrap_err();
        assert!(err.to_string().contains("127.0.0.1:1"), "{err}");
        #[cfg(unix)]
        {
            let err = Stream::connect("unix:/nonexistent/kpynq-test.sock").unwrap_err();
            assert!(err.to_string().contains("kpynq-test.sock"), "{err}");
        }
    }
}
