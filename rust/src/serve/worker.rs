//! The sharded worker pool: one thread per shard, one long-lived engine
//! bank per thread.
//!
//! Engine construction is the expensive part of a request on the XLA path
//! (PJRT client + per-variant AOT compilation) — so each worker owns its
//! engines for the life of the pool and every job it executes reuses them,
//! amortizing setup across requests instead of paying it per fit (the
//! serving analogue of "compile once, execute per tile"). Workers pull
//! micro-batches from the shared admission queue, execute them (lockstep
//! for coalesced batches, solo otherwise) and push [`FitResponse`]s to the
//! collector channel.

use std::path::Path;
use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::coordinator::{driver, SystemConfig, SystemOutput};
use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::Algorithm;
use crate::obs::{SpanEvent, TraceRing};
use crate::runtime::{native::NativeEngine, xla::XlaEngine, Engine};

use super::batch::{fit_lockstep, BackendKind};
use super::job::FitResponse;
use super::queue::{Pending, SharedQueue};
use super::ServeConfig;

/// Per-worker counters, merged into the `ServeReport` after the pool
/// drains.
#[derive(Clone, Debug, Default)]
pub(crate) struct WorkerStats {
    pub worker: usize,
    /// Jobs executed (ok or failed; shed jobs never reach a worker's
    /// engines).
    pub jobs: u64,
    /// Micro-batches pulled (a solo job counts as a batch of one).
    pub batches: u64,
    /// Largest micro-batch executed.
    pub max_batch: usize,
    /// Jobs that rode in a coalesced batch (size ≥ 2).
    pub batched_jobs: u64,
    /// Seconds spent executing (busy, not waiting on the queue).
    pub busy_seconds: f64,
}

/// The engines a worker keeps alive across requests.
#[derive(Default)]
struct EngineBank {
    native: NativeEngine,
    /// One engine per artifact dir, constructed on first use and kept for
    /// the worker's lifetime — tenants alternating artifact dirs must not
    /// re-pay PJRT construction + AOT compilation per batch.
    xla: std::collections::BTreeMap<String, XlaEngine>,
}

impl EngineBank {
    fn xla(&mut self, artifact_dir: &str) -> Result<&mut XlaEngine> {
        if !self.xla.contains_key(artifact_dir) {
            let engine = XlaEngine::new(Path::new(artifact_dir))?;
            self.xla.insert(artifact_dir.to_string(), engine);
        }
        Ok(self.xla.get_mut(artifact_dir).expect("just inserted"))
    }
}

/// Worker main loop: runs until the queue closes and drains. Trace spans
/// for every executed job (`queue-wait`, `dispatch` — PROTOCOL.md §11)
/// land in `ring`.
pub(crate) fn run_worker(
    worker: usize,
    cfg: &ServeConfig,
    queue: &SharedQueue,
    tx: &Sender<FitResponse>,
    ring: &TraceRing,
) -> WorkerStats {
    let mut stats = WorkerStats { worker, ..Default::default() };
    let mut engines = EngineBank::default();
    while let Some(outcome) = queue.take_batch(cfg.max_batch) {
        for p in outcome.shed {
            let mut resp = FitResponse::shed(
                p.req.id,
                "start deadline expired in queue",
                p.queue_seconds(),
            );
            resp.trace_id = p.req.trace_id.clone();
            let _ = tx.send(resp);
        }
        if outcome.batch.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        execute_batch(worker, &mut engines, outcome.batch, tx, &mut stats, ring);
        stats.busy_seconds += t0.elapsed().as_secs_f64();
    }
    stats
}

/// Execute one popped micro-batch. All jobs in a batch of size ≥ 2 share a
/// `BatchKey` (queue invariant), so they target one engine and coalesce
/// into lockstep; solo batches run whichever backend they name.
fn execute_batch(
    worker: usize,
    engines: &mut EngineBank,
    batch: Vec<Pending>,
    tx: &Sender<FitResponse>,
    stats: &mut WorkerStats,
    ring: &TraceRing,
) {
    stats.batches += 1;
    stats.max_batch = stats.max_batch.max(batch.len());
    let batch_size = batch.len();
    for p in &batch {
        if p.req.trace_id.is_empty() {
            continue;
        }
        let queue_ms = p.queue_seconds() * 1e3;
        ring.push(
            SpanEvent::new(&p.req.trace_id, "queue-wait")
                .num("id", p.req.id as f64)
                .num("queue_ms", queue_ms),
        );
        ring.push(
            SpanEvent::new(&p.req.trace_id, "dispatch")
                .num("id", p.req.id as f64)
                .num("worker", worker as f64)
                .num("batch_size", batch_size as f64),
        );
    }

    // Materialise datasets and validate each job up front; a job whose
    // dataset fails to load (or whose k/n combination is invalid) answers
    // Failed without sinking the rest of the batch.
    let mut jobs: Vec<(Pending, Dataset, f64)> = Vec::with_capacity(batch.len());
    for p in batch {
        let queue_s = p.queue_seconds();
        let loaded = p.req.load_dataset().and_then(|ds| {
            p.req.kmeans.validate(ds.n())?;
            Ok(ds)
        });
        match loaded {
            Ok(ds) => jobs.push((p, ds, queue_s)),
            Err(e) => {
                stats.jobs += 1;
                let mut resp = FitResponse::failed(
                    p.req.id,
                    &p.req.backend_name,
                    worker,
                    1,
                    queue_s,
                    &e,
                );
                resp.trace_id = p.req.trace_id.clone();
                let _ = tx.send(resp);
            }
        }
    }
    if jobs.is_empty() {
        return;
    }

    let kind = BackendKind::from_name(&jobs[0].0.req.backend_name);
    match kind {
        // Simulated-FPGA jobs pop solo (queue invariant) and carry their
        // own iteration structure inside the cycle simulator.
        Some(BackendKind::FpgaSim) | None => {
            for (p, ds, queue_s) in &jobs {
                let t0 = Instant::now();
                let res = p.req.to_run_config().and_then(|rc| {
                    driver::run(
                        &SystemConfig { backend: rc.backend(), verify: false },
                        ds,
                        &p.req.kmeans,
                    )
                });
                send_result(tx, stats, worker, p, *queue_s, t0.elapsed().as_secs_f64(), 1, res);
            }
        }
        Some(BackendKind::Native) | Some(BackendKind::Xla) => {
            let engine: &mut dyn Engine = match kind {
                Some(BackendKind::Xla) => {
                    match engines.xla(&jobs[0].0.req.artifact_dir) {
                        Ok(e) => e,
                        Err(e) => {
                            // No engine: every job in the batch fails with
                            // the construction error (e.g. feature off).
                            for (p, _, queue_s) in &jobs {
                                stats.jobs += 1;
                                let mut resp = FitResponse::failed(
                                    p.req.id,
                                    &p.req.backend_name,
                                    worker,
                                    jobs.len(),
                                    *queue_s,
                                    &e,
                                );
                                resp.trace_id = p.req.trace_id.clone();
                                let _ = tx.send(resp);
                            }
                            return;
                        }
                    }
                }
                _ => &mut engines.native,
            };
            let name = engine.name();
            if jobs.len() >= 2 {
                let refs: Vec<(&Dataset, &crate::kmeans::KMeansConfig)> =
                    jobs.iter().map(|(p, ds, _)| (ds, &p.req.kmeans)).collect();
                let t0 = Instant::now();
                match fit_lockstep(engine, name, &refs) {
                    Ok(outs) => {
                        let service_s = t0.elapsed().as_secs_f64();
                        stats.batched_jobs += jobs.len() as u64;
                        for ((p, _, queue_s), out) in jobs.iter().zip(outs) {
                            send_result(
                                tx,
                                stats,
                                worker,
                                p,
                                *queue_s,
                                service_s,
                                jobs.len(),
                                Ok(out),
                            );
                        }
                    }
                    Err(e) => {
                        // Jobs were validated above, so a lockstep error is
                        // an engine fault — not attributable to one job;
                        // fail the batch.
                        for (p, _, queue_s) in &jobs {
                            stats.jobs += 1;
                            let mut resp = FitResponse::failed(
                                p.req.id,
                                &p.req.backend_name,
                                worker,
                                jobs.len(),
                                *queue_s,
                                &e,
                            );
                            resp.trace_id = p.req.trace_id.clone();
                            let _ = tx.send(resp);
                        }
                    }
                }
            } else {
                let (p, ds, queue_s) = &jobs[0];
                let t0 = Instant::now();
                // Explicit-`algorithm` jobs (PROTOCOL.md §3) pop solo
                // (BatchKey invariant) and run the named kernel host-side,
                // so its own filter hierarchy — not the engine loop's
                // global filter — produces the reported work counters.
                let res = if p.req.algorithm.is_empty() {
                    driver::run_with_engine(engine, ds, &p.req.kmeans)
                } else {
                    Algorithm::from_name(&p.req.algorithm)
                        .and_then(|algo| driver::run_algorithm(algo, name, ds, &p.req.kmeans))
                };
                send_result(tx, stats, worker, p, *queue_s, t0.elapsed().as_secs_f64(), 1, res);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn send_result(
    tx: &Sender<FitResponse>,
    stats: &mut WorkerStats,
    worker: usize,
    p: &Pending,
    queue_seconds: f64,
    service_seconds: f64,
    batch_size: usize,
    res: Result<SystemOutput>,
) {
    stats.jobs += 1;
    let mut resp = match res {
        Ok(out) => {
            let backend = out.report.backend.clone();
            FitResponse::ok(
                p.req.id,
                backend,
                worker,
                batch_size,
                queue_seconds,
                service_seconds,
                out.fit,
                out.report,
            )
        }
        Err(e) => {
            let mut r =
                FitResponse::failed(p.req.id, &p.req.backend_name, worker, batch_size, queue_seconds, &e);
            r.service_seconds = service_seconds;
            r
        }
    };
    resp.trace_id = p.req.trace_id.clone();
    let _ = tx.send(resp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::job::{FitRequest, JobStatus};
    use crate::serve::queue::ShedPolicy;
    use std::sync::mpsc;

    fn small_req(id: u64, k: usize, seed: u64) -> FitRequest {
        FitRequest {
            id,
            max_points: 400,
            kmeans: crate::kmeans::KMeansConfig { k, seed, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn worker_drains_queue_and_reports() {
        let cfg = ServeConfig { workers: 1, ..Default::default() };
        let queue = SharedQueue::new(8);
        for id in 1..=3 {
            assert!(matches!(
                queue.submit(small_req(id, 3, id), ShedPolicy::Block),
                crate::serve::queue::Submission::Admitted
            ));
        }
        queue.close();
        let (tx, rx) = mpsc::channel();
        let stats = run_worker(0, &cfg, &queue, &tx, &TraceRing::default());
        drop(tx);
        let responses: Vec<FitResponse> = rx.iter().collect();
        assert_eq!(responses.len(), 3);
        assert!(responses.iter().all(|r| r.status == JobStatus::Ok));
        assert_eq!(stats.jobs, 3);
        assert!(stats.batches >= 1);
        // All three share a key and one worker pulled them together.
        assert_eq!(stats.max_batch, 3);
        assert_eq!(stats.batched_jobs, 3);
    }

    #[test]
    fn bad_job_fails_without_sinking_the_batch() {
        let cfg = ServeConfig { workers: 1, ..Default::default() };
        let queue = SharedQueue::new(8);
        // k larger than the subsampled n: fails validation inside the fit.
        let mut bad = small_req(1, 3, 1);
        bad.kmeans.k = 1000;
        bad.max_points = 100;
        queue.submit(bad, ShedPolicy::Block);
        queue.submit(small_req(2, 3, 2), ShedPolicy::Block);
        queue.close();
        let (tx, rx) = mpsc::channel();
        run_worker(0, &cfg, &queue, &tx, &TraceRing::default());
        drop(tx);
        let mut responses: Vec<FitResponse> = rx.iter().collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].status, JobStatus::Failed);
        assert!(responses[0].detail.contains("exceeds"), "{}", responses[0].detail);
        assert_eq!(responses[1].status, JobStatus::Ok);
    }

    #[test]
    fn pinned_algorithm_jobs_run_solo_with_spans_and_counters() {
        let cfg = ServeConfig { workers: 1, ..Default::default() };
        let queue = SharedQueue::new(8);
        let mut yy = small_req(1, 4, 5);
        yy.algorithm = "yinyang".into();
        yy.trace_id = "feedfacefeedface".into();
        let mut ll = small_req(2, 4, 5);
        ll.algorithm = "lloyd".into();
        queue.submit(yy, ShedPolicy::Block);
        queue.submit(ll, ShedPolicy::Block);
        queue.close();
        let ring = TraceRing::default();
        let (tx, rx) = mpsc::channel();
        run_worker(0, &cfg, &queue, &tx, &ring);
        drop(tx);
        let mut responses: Vec<FitResponse> = rx.iter().collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert_eq!(r.status, JobStatus::Ok, "{}", r.detail);
            assert_eq!(r.batch_size, 1, "pinned kernels never coalesce");
        }
        let yy_work = responses[0].summary.unwrap().work;
        let ll_work = responses[1].summary.unwrap().work;
        assert!(yy_work.points_pruned > 0, "yinyang prunes");
        assert_eq!(ll_work.points_pruned, 0, "lloyd filters nothing");
        assert_eq!(responses[0].trace_id, "feedfacefeedface");
        // The traced job left queue-wait + dispatch spans in the ring;
        // the untraced one (empty trace_id) left none.
        let (events, dropped) = ring.drain();
        assert_eq!(dropped, 0);
        let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["queue-wait", "dispatch"]);
        assert!(events.iter().all(|e| e.trace_id == "feedfacefeedface"));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_jobs_fail_cleanly_without_the_feature() {
        let cfg = ServeConfig { workers: 1, ..Default::default() };
        let queue = SharedQueue::new(4);
        let mut req = small_req(1, 3, 1);
        req.backend_name = "xla".into();
        queue.submit(req, ShedPolicy::Block);
        queue.close();
        let (tx, rx) = mpsc::channel();
        run_worker(0, &cfg, &queue, &tx, &TraceRing::default());
        drop(tx);
        let responses: Vec<FitResponse> = rx.iter().collect();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].status, JobStatus::Failed);
        assert!(responses[0].detail.contains("xla"), "{}", responses[0].detail);
    }
}
