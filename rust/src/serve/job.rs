//! The serve job model: what a tenant submits and what comes back.
//!
//! A [`FitRequest`] is one clustering job — dataset reference, K-means
//! parameters, backend, priority and an optional start deadline. Requests
//! arrive as line-delimited JSON (parsed by the in-crate `util::json`
//! reader) or are built programmatically. A [`FitResponse`] carries the
//! outcome: the full [`FitResult`] + [`RunReport`] for completed jobs (so
//! callers can assert bit-identity with a direct `coordinator` run), or a
//! shed/failure reason.
//!
//! This module is the *implementation* of the NDJSON wire surface; the
//! **normative spec** — every field with types, defaults and units, the
//! shed/error reply shapes, the priority/deadline semantics and the
//! versioning policy — is PROTOCOL.md (§3 requests, §4 responses). When
//! this module and that document disagree, the document wins and the code
//! is the bug; `make check-docs` keeps the field lists aligned in both
//! directions.
//!
//! Dataset loading reuses `config::RunConfig` wholesale — a served job
//! names datasets exactly like `kpynq run --dataset` does, so a request is
//! trivially replayable as a one-shot CLI run when debugging.

use crate::config::RunConfig;
use crate::coordinator::RunReport;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::metrics::WorkEfficiency;
use crate::kmeans::{Algorithm, FitResult, KMeansConfig};
use crate::obs::profile::{Phase, PhaseTotals};
use crate::util::json::Json;

/// Scheduling priority (PROTOCOL.md §7). Lower index pops first; FIFO
/// within a level. Priority affects *when* a job starts, never its
/// result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    High,
    Normal,
    Low,
}

impl Priority {
    /// Number of priority levels (queue lane count).
    pub const LEVELS: usize = 3;

    /// Lane index: 0 (High) pops before 2 (Low).
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    pub fn from_name(name: &str) -> Result<Priority> {
        match name {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            other => Err(Error::Parse(format!("unknown priority '{other}'"))),
        }
    }
}

/// One clustering job.
#[derive(Clone, Debug)]
pub struct FitRequest {
    /// Caller-chosen identifier, echoed in the response.
    pub id: u64,
    /// Dataset name, exactly as `config::RunConfig` accepts it (generator
    /// name, `.kpm` or `.csv` path).
    pub dataset: String,
    /// Generator seed (synthetic datasets).
    pub data_seed: u64,
    /// Subsample cap (0 = full dataset).
    pub max_points: usize,
    /// Normalisation: "minmax", "zscore" or "none".
    pub normalize: String,
    pub kmeans: KMeansConfig,
    /// Backend: "fpga-sim", "native" or "xla".
    pub backend_name: String,
    /// AOT artifact directory (xla backend only).
    pub artifact_dir: String,
    pub priority: Priority,
    /// Start deadline, relative to admission: if the job has not begun
    /// executing within this many milliseconds it is shed instead of run
    /// (semantics are normative in PROTOCOL.md §7). The comparison is
    /// `elapsed >= deadline`, so `0` *always* sheds — a deliberate escape
    /// hatch for probing the shed path. `None` = no deadline.
    pub deadline_ms: Option<u64>,
    /// Explicit kernel variant ("lloyd", "hamerly", "elkan", "yinyang");
    /// empty = the backend's default execution path. Client-optional
    /// (PROTOCOL.md §3/§9): naming an algorithm pins the fit to that
    /// kernel so its work-efficiency counters are the ones reported.
    /// Engine backends run explicit-algorithm jobs solo (never coalesced).
    pub algorithm: String,
    /// Client-supplied trace id (PROTOCOL.md §11); empty = the front
    /// mints one at admission. Propagated on every shard-bound frame and
    /// echoed byte-identically on the response.
    pub trace_id: String,
    /// Tenant the job is accounted to (PROTOCOL.md §3, client-optional).
    /// Empty = untenanted. Constrained to 64 bytes of `[A-Za-z0-9._-]`
    /// ([`validate_tenant_label`]). The label drives per-tenant accounting
    /// (`stats` rollups, `tenant`-labeled series) and *scheduling* — the
    /// queue's weighted-fair rotation and per-tenant quota (PROTOCOL.md
    /// §7) — but never the result: a fit's bits are tenant-independent.
    pub tenant: String,
}

impl Default for FitRequest {
    fn default() -> Self {
        Self {
            id: 0,
            dataset: "blobs".into(),
            data_seed: 0xC0FFEE,
            max_points: 0,
            normalize: "minmax".into(),
            kmeans: KMeansConfig::default(),
            backend_name: "native".into(),
            artifact_dir: "artifacts".into(),
            priority: Priority::Normal,
            deadline_ms: None,
            algorithm: String::new(),
            trace_id: String::new(),
            tenant: String::new(),
            cached: false,
        }
    }
}

impl FitRequest {
    /// Parse one line of the NDJSON wire format (PROTOCOL.md §3). Only
    /// `"id"` is required; every other key falls back to the [`Default`]
    /// value. Unknown keys are rejected so typos fail loudly at
    /// admission, not silently at fit time.
    ///
    /// ```text
    /// {"id":1,"dataset":"kegg","k":16,"backend":"native","priority":"high"}
    /// ```
    pub fn from_json_line(line: &str) -> Result<FitRequest> {
        Self::from_json(&Json::parse(line)?)
    }

    pub fn from_json(j: &Json) -> Result<FitRequest> {
        let map = match j {
            Json::Obj(m) => m,
            other => {
                return Err(Error::Parse(format!("job must be a JSON object, got {other:?}")))
            }
        };
        const KNOWN: &[&str] = &[
            "id",
            "dataset",
            "data_seed",
            "max_points",
            "normalize",
            "k",
            "groups",
            "max_iters",
            "tol",
            "seed",
            "backend",
            "artifact_dir",
            "priority",
            "deadline_ms",
            "algorithm",
            "trace_id",
            "tenant",
        ];
        if let Some(unknown) = map.keys().find(|k| !KNOWN.contains(&k.as_str())) {
            return Err(Error::Parse(format!("unknown job key '{unknown}'")));
        }
        let mut req = FitRequest { id: j.get("id")?.as_usize()? as u64, ..Default::default() };
        if let Some(v) = map.get("dataset") {
            req.dataset = v.as_str()?.to_string();
        }
        if let Some(v) = map.get("data_seed") {
            req.data_seed = v.as_usize()? as u64;
        }
        if let Some(v) = map.get("max_points") {
            req.max_points = v.as_usize()?;
        }
        if let Some(v) = map.get("normalize") {
            req.normalize = v.as_str()?.to_string();
        }
        if let Some(v) = map.get("k") {
            req.kmeans.k = v.as_usize()?;
        }
        if let Some(v) = map.get("groups") {
            req.kmeans.groups = v.as_usize()?;
        }
        if let Some(v) = map.get("max_iters") {
            req.kmeans.max_iters = v.as_usize()?;
        }
        if let Some(v) = map.get("tol") {
            req.kmeans.tol = v.as_f64()?;
        }
        if let Some(v) = map.get("seed") {
            req.kmeans.seed = v.as_usize()? as u64;
        }
        if let Some(v) = map.get("backend") {
            req.backend_name = v.as_str()?.to_string();
        }
        if let Some(v) = map.get("artifact_dir") {
            req.artifact_dir = v.as_str()?.to_string();
        }
        if let Some(v) = map.get("priority") {
            req.priority = Priority::from_name(v.as_str()?)?;
        }
        if let Some(v) = map.get("deadline_ms") {
            req.deadline_ms = Some(v.as_usize()? as u64);
        }
        if let Some(v) = map.get("algorithm") {
            req.algorithm = v.as_str()?.to_string();
            if !req.algorithm.is_empty() {
                // Fail unknown kernel names at admission, like backends.
                Algorithm::from_name(&req.algorithm)?;
                if req.backend_name == "fpga-sim" {
                    return Err(Error::Parse(
                        "the fpga-sim backend runs the accelerator's own multi-level \
                         filter pipeline; 'algorithm' applies to engine backends only"
                            .into(),
                    ));
                }
            }
        }
        if let Some(v) = map.get("trace_id") {
            req.trace_id = v.as_str()?.to_string();
        }
        if let Some(v) = map.get("tenant") {
            req.tenant = v.as_str()?.to_string();
            // Arbitrary client strings become accounting labels and
            // scheduler lanes — bound them at admission (PROTOCOL.md §3).
            validate_tenant_label(&req.tenant)?;
        }
        // Fail malformed names (backend / normalize) at parse time.
        req.to_run_config()?;
        Ok(req)
    }

    /// Parse the §3 job surface out of a frame that carries extra
    /// op-specific keys — the `partial_fit` request (PROTOCOL.md §10)
    /// embeds a full job description alongside its own `op` /
    /// `algorithm` / `shard_index` / `shard_count` / `history` keys.
    /// Keys named in `ignore` are stripped before the strict
    /// [`FitRequest::from_json`] parse, so the unknown-key rejection
    /// still fires for genuine typos.
    pub fn from_json_ignoring(j: &Json, ignore: &[&str]) -> Result<FitRequest> {
        let map = match j {
            Json::Obj(m) => m,
            other => {
                return Err(Error::Parse(format!("job must be a JSON object, got {other:?}")))
            }
        };
        let filtered: std::collections::BTreeMap<String, Json> = map
            .iter()
            .filter(|(k, _)| !ignore.contains(&k.as_str()))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Self::from_json(&Json::Obj(filtered))
    }

    /// Serialize onto the NDJSON wire (PROTOCOL.md §3) — the client side
    /// of [`FitRequest::from_json`], used when forwarding a request to a
    /// daemon (`cluster::client`). Exactly the §3 surface crosses the
    /// wire: every documented key is emitted explicitly (`deadline_ms`
    /// only when set), and fields outside it — notably `kmeans.init`,
    /// which has no wire key — do not survive a round-trip.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".into(), Json::Num(self.id as f64));
        m.insert("dataset".into(), Json::Str(self.dataset.clone()));
        m.insert("data_seed".into(), Json::Num(self.data_seed as f64));
        m.insert("max_points".into(), Json::Num(self.max_points as f64));
        m.insert("normalize".into(), Json::Str(self.normalize.clone()));
        m.insert("k".into(), Json::Num(self.kmeans.k as f64));
        m.insert("groups".into(), Json::Num(self.kmeans.groups as f64));
        m.insert("max_iters".into(), Json::Num(self.kmeans.max_iters as f64));
        m.insert("tol".into(), Json::Num(self.kmeans.tol));
        m.insert("seed".into(), Json::Num(self.kmeans.seed as f64));
        m.insert("backend".into(), Json::Str(self.backend_name.clone()));
        m.insert("artifact_dir".into(), Json::Str(self.artifact_dir.clone()));
        m.insert("priority".into(), Json::Str(self.priority.name().into()));
        if let Some(d) = self.deadline_ms {
            m.insert("deadline_ms".into(), Json::Num(d as f64));
        }
        // Client-optional keys (§9): absent when unset, so pre-§11 wire
        // shapes are reproduced byte-for-byte by default requests.
        if !self.algorithm.is_empty() {
            m.insert("algorithm".into(), Json::Str(self.algorithm.clone()));
        }
        if !self.trace_id.is_empty() {
            m.insert("trace_id".into(), Json::Str(self.trace_id.clone()));
        }
        if !self.tenant.is_empty() {
            m.insert("tenant".into(), Json::Str(self.tenant.clone()));
        }
        Json::Obj(m)
    }

    /// The equivalent one-shot run configuration — served jobs reuse the
    /// `RunConfig` dataset/backend machinery verbatim, so a served fit and
    /// `kpynq run` with the same parameters see the same bytes.
    pub fn to_run_config(&self) -> Result<RunConfig> {
        let cfg = RunConfig {
            dataset: self.dataset.clone(),
            data_seed: self.data_seed,
            max_points: self.max_points,
            normalize: self.normalize.clone(),
            kmeans: self.kmeans.clone(),
            backend_name: self.backend_name.clone(),
            artifact_dir: std::path::PathBuf::from(&self.artifact_dir),
            ..RunConfig::default()
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Materialise (and normalise) the dataset this request names.
    pub fn load_dataset(&self) -> Result<Dataset> {
        self.to_run_config()?.load_dataset()
    }
}

/// Terminal state of a served job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Fit completed; `fit`/`report` are populated.
    Ok,
    /// Dropped by the admission queue (full, closed, or deadline expired)
    /// without executing; `detail` names the reason.
    Shed,
    /// Admitted but execution failed; `detail` carries the error.
    Failed,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Ok => "ok",
            JobStatus::Shed => "shed",
            JobStatus::Failed => "failed",
        }
    }

    pub fn from_name(name: &str) -> Result<JobStatus> {
        match name {
            "ok" => Ok(JobStatus::Ok),
            "shed" => Ok(JobStatus::Shed),
            "failed" => Ok(JobStatus::Failed),
            other => Err(Error::Parse(format!("unknown job status '{other}'"))),
        }
    }
}

/// The scalar fit summary that crosses the wire for an `ok` response
/// (PROTOCOL.md §4): what a protocol peer knows about a completed
/// clustering without holding the n-point assignment vector. Populated
/// from the full [`FitResult`] by the worker that ran the job, or parsed
/// back off the wire by [`FitResponse::from_wire_json`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FitSummary {
    /// Final within-cluster sum of squared distances.
    pub inertia: f64,
    /// Iterations executed.
    pub iterations: usize,
    /// Whether the tolerance was met before `max_iters`.
    pub converged: bool,
    /// FNV-1a fingerprint of the assignment vector (PROTOCOL.md §8).
    pub assignments_fnv: u64,
    /// Whole-run triangle-inequality savings (PROTOCOL.md §4). All-zero
    /// when the executing path tracked no per-iteration stats (map-reduce
    /// fits) — "nothing measured", never "everything avoided".
    pub work: WorkEfficiency,
    /// Per-phase wall-time split (`obs::profile`) — `Some` only when
    /// profiling was enabled on the executing daemon. Additive §9 keys
    /// (`phase_*_ms`): absent from the wire when profiling is off, so
    /// pre-profiling response lines are reproduced byte-for-byte.
    pub phases: Option<PhaseTotals>,
}

impl FitSummary {
    pub fn of(fit: &FitResult) -> FitSummary {
        FitSummary {
            inertia: fit.inertia,
            iterations: fit.iterations,
            converged: fit.converged,
            assignments_fnv: assignments_checksum(&fit.assignments),
            work: fit.stats.work_efficiency(fit.assignments.len(), fit.centroids.rows()),
            phases: fit.stats.phases,
        }
    }
}

/// Outcome of one served job.
#[derive(Clone, Debug)]
pub struct FitResponse {
    pub id: u64,
    pub status: JobStatus,
    /// Shed reason or error text; empty for [`JobStatus::Ok`].
    pub detail: String,
    /// Backend that ran (or would have run) the job.
    pub backend: String,
    /// Worker shard that executed the job (0 for jobs shed at admission).
    pub worker: usize,
    /// Size of the micro-batch this job rode in (1 = solo, 0 = never ran).
    pub batch_size: usize,
    /// Seconds spent queued before execution (or before being shed).
    pub queue_seconds: f64,
    /// Execution seconds. For coalesced jobs this is the whole batch
    /// dispatch — the latency the tenant observed, not a per-job share.
    pub service_seconds: f64,
    /// Wire-level fit summary (`Some` exactly for [`JobStatus::Ok`]). For
    /// locally executed jobs it is derived from `fit`; for responses
    /// parsed off the wire ([`FitResponse::from_wire_json`]) it is all a
    /// peer gets — the full clustering never crosses the NDJSON surface.
    pub summary: Option<FitSummary>,
    /// The clustering, bit-identical to a direct `coordinator` run with
    /// the same request parameters. `None` for shed/failed jobs and for
    /// responses received over the wire.
    pub fit: Option<FitResult>,
    pub report: Option<RunReport>,
    /// The trace id this job ran under (PROTOCOL.md §11) — the client's
    /// own if it supplied one, else the id the front minted. Empty only
    /// on paths that never saw a request (batch-mode fronts without
    /// tracing). Echoed byte-identically across fan-out/fan-in hops.
    pub trace_id: String,
    /// Tenant the job was accounted to — echoed from the request by the
    /// response router (workers never see tenants). Empty = untenanted;
    /// the key is absent from the wire in that case (PROTOCOL.md §4).
    pub tenant: String,
    /// True when this reply was answered from the result cache
    /// (PROTOCOL.md §8 request fingerprint) instead of a fresh fit. The
    /// wire key is emitted only when true, so cold-fit response lines are
    /// byte-identical to their pre-cache shape (PROTOCOL.md §4).
    pub cached: bool,
}

impl FitResponse {
    pub(crate) fn shed(id: u64, reason: &str, queue_seconds: f64) -> Self {
        Self {
            id,
            status: JobStatus::Shed,
            detail: reason.to_string(),
            backend: String::new(),
            worker: 0,
            batch_size: 0,
            queue_seconds,
            service_seconds: 0.0,
            summary: None,
            fit: None,
            report: None,
            trace_id: String::new(),
            tenant: String::new(),
            cached: false,
        }
    }

    pub(crate) fn failed(
        id: u64,
        backend: &str,
        worker: usize,
        batch_size: usize,
        queue_seconds: f64,
        err: &Error,
    ) -> Self {
        Self {
            id,
            status: JobStatus::Failed,
            detail: err.to_string(),
            backend: backend.to_string(),
            worker,
            batch_size,
            queue_seconds,
            service_seconds: 0.0,
            summary: None,
            fit: None,
            report: None,
            trace_id: String::new(),
            tenant: String::new(),
            cached: false,
        }
    }

    /// A completed job's response: the summary is derived from the fit
    /// here, once, so every later render (or wire crossing) agrees.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn ok(
        id: u64,
        backend: String,
        worker: usize,
        batch_size: usize,
        queue_seconds: f64,
        service_seconds: f64,
        fit: FitResult,
        report: RunReport,
    ) -> Self {
        Self {
            id,
            status: JobStatus::Ok,
            detail: String::new(),
            backend,
            worker,
            batch_size,
            queue_seconds,
            service_seconds,
            summary: Some(FitSummary::of(&fit)),
            fit: Some(fit),
            report: Some(report),
            trace_id: String::new(),
            tenant: String::new(),
            cached: false,
        }
    }

    /// Total tenant-observed latency (queue + service).
    pub fn latency_seconds(&self) -> f64 {
        self.queue_seconds + self.service_seconds
    }

    /// NDJSON summary line (PROTOCOL.md §4): scalars only — the
    /// assignment vector is replaced by the §8 fingerprint so responses
    /// stay one short line each; callers needing the clustering use the
    /// library API.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".into(), Json::Num(self.id as f64));
        m.insert("status".into(), Json::Str(self.status.name().into()));
        if !self.detail.is_empty() {
            m.insert("detail".into(), Json::Str(self.detail.clone()));
        }
        if !self.backend.is_empty() {
            m.insert("backend".into(), Json::Str(self.backend.clone()));
        }
        m.insert("worker".into(), Json::Num(self.worker as f64));
        m.insert("batch_size".into(), Json::Num(self.batch_size as f64));
        m.insert("queue_ms".into(), Json::Num(self.queue_seconds * 1e3));
        m.insert("service_ms".into(), Json::Num(self.service_seconds * 1e3));
        if let Some(s) = &self.summary {
            m.insert("inertia".into(), Json::Num(s.inertia));
            m.insert("iterations".into(), Json::Num(s.iterations as f64));
            m.insert("converged".into(), Json::Bool(s.converged));
            m.insert(
                "assignments_fnv".into(),
                Json::Str(format!("{:016x}", s.assignments_fnv)),
            );
            // Work-efficiency counters (PROTOCOL.md §4): always present on
            // an `ok` line so peers can tell measured-zero from absent.
            m.insert("dist_comps".into(), Json::Num(s.work.dist_comps as f64));
            m.insert(
                "dist_comps_avoided".into(),
                Json::Num(s.work.dist_comps_avoided as f64),
            );
            m.insert("points_pruned".into(), Json::Num(s.work.points_pruned as f64));
            m.insert("group_hit_rate".into(), Json::Num(s.work.group_hit_rate));
            // Per-phase timings (PROTOCOL.md §4, additive §9 keys): only
            // present when profiling was enabled on the executing daemon.
            if let Some(p) = &s.phases {
                for ph in Phase::ALL {
                    m.insert(format!("phase_{}_ms", ph.name()), Json::Num(p.get(ph)));
                }
            }
        }
        if !self.trace_id.is_empty() {
            m.insert("trace_id".into(), Json::Str(self.trace_id.clone()));
        }
        if !self.tenant.is_empty() {
            m.insert("tenant".into(), Json::Str(self.tenant.clone()));
        }
        if self.cached {
            m.insert("cached".into(), Json::Bool(true));
        }
        Json::Obj(m)
    }

    /// Parse a response line back off the wire (PROTOCOL.md §4) — the
    /// client side of [`FitResponse::to_json`], used by `cluster::client`
    /// when collecting from a daemon. `fit`/`report` are `None` (the full
    /// clustering never crosses the NDJSON surface); an `ok` response
    /// carries its [`FitSummary`], so re-serializing is lossless and the
    /// §8 fingerprint survives every fan-out/fan-in hop unchanged.
    pub fn from_wire_json(j: &Json) -> Result<FitResponse> {
        let map = match j {
            Json::Obj(m) => m,
            other => {
                return Err(Error::Parse(format!("response must be a JSON object, got {other:?}")))
            }
        };
        let id = j.get("id")?.as_usize()? as u64;
        let status = JobStatus::from_name(j.get("status")?.as_str()?)?;
        let get_str = |key: &str| -> Result<String> {
            Ok(map.get(key).map(|v| v.as_str()).transpose()?.unwrap_or("").to_string())
        };
        let get_num = |key: &str| -> Result<f64> {
            Ok(map.get(key).map(|v| v.as_f64()).transpose()?.unwrap_or(0.0))
        };
        let summary = if status == JobStatus::Ok {
            let fnv_hex = j.get("assignments_fnv")?.as_str()?;
            let assignments_fnv = u64::from_str_radix(fnv_hex, 16).map_err(|_| {
                Error::Parse(format!("assignments_fnv '{fnv_hex}' is not 16 hex digits"))
            })?;
            // Work counters are additive §9 keys: absent (an older peer)
            // reads as zero, exactly the "nothing measured" convention.
            let get_u64 = |key: &str| -> Result<u64> {
                Ok(map.get(key).map(|v| v.as_usize()).transpose()?.unwrap_or(0) as u64)
            };
            // Phase timings reconstruct to `Some` iff any `phase_*_ms`
            // key is present — symmetric with `to_json`, so re-serializing
            // a parsed response is byte-stable with profiling on or off.
            let mut phases: Option<PhaseTotals> = None;
            for ph in Phase::ALL {
                if let Some(v) = map.get(&format!("phase_{}_ms", ph.name())) {
                    phases.get_or_insert_with(PhaseTotals::default).ms[ph as usize] =
                        v.as_f64()?;
                }
            }
            Some(FitSummary {
                inertia: j.get("inertia")?.as_f64()?,
                iterations: j.get("iterations")?.as_usize()?,
                converged: matches!(j.get("converged")?, Json::Bool(true)),
                assignments_fnv,
                work: WorkEfficiency {
                    dist_comps: get_u64("dist_comps")?,
                    dist_comps_avoided: get_u64("dist_comps_avoided")?,
                    points_pruned: get_u64("points_pruned")?,
                    group_hit_rate: get_num("group_hit_rate")?,
                },
                phases,
            })
        } else {
            None
        };
        Ok(FitResponse {
            id,
            status,
            detail: get_str("detail")?,
            backend: get_str("backend")?,
            worker: map.get("worker").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
            batch_size: map.get("batch_size").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
            queue_seconds: get_num("queue_ms")? / 1e3,
            service_seconds: get_num("service_ms")? / 1e3,
            summary,
            fit: None,
            report: None,
            trace_id: get_str("trace_id")?,
            tenant: get_str("tenant")?,
            cached: matches!(map.get("cached"), Some(Json::Bool(true))),
        })
    }
}

/// Validate a §3 `tenant` label: at most 64 bytes drawn from
/// `[A-Za-z0-9._-]` (PROTOCOL.md §3). Empty is allowed (untenanted).
/// Tenant labels become metric label values, accounting-table keys and
/// scheduler lanes, so they are bounded at admission; `~` is excluded on
/// purpose so the server-side `~other` overflow bucket can never collide
/// with a real tenant.
pub fn validate_tenant_label(tenant: &str) -> Result<()> {
    if tenant.len() > 64 {
        return Err(Error::Parse(format!(
            "tenant label is {} bytes, limit 64",
            tenant.len()
        )));
    }
    if let Some(c) = tenant
        .chars()
        .find(|c| !(c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')))
    {
        return Err(Error::Parse(format!(
            "tenant label contains '{c}'; allowed characters are A-Z a-z 0-9 . _ -"
        )));
    }
    Ok(())
}

/// FNV-1a (64-bit) over the little-endian assignment words — the stable
/// fingerprint for cross-process "same clustering?" checks on the NDJSON
/// surface. This is the reference implementation of PROTOCOL.md §8; the
/// constants and byte order there are normative.
pub fn assignments_checksum(assignments: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &a in assignments {
        for b in a.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_job_line() {
        let req = FitRequest::from_json_line(
            r#"{"id": 7, "dataset": "kegg", "data_seed": 3, "max_points": 2000,
                "k": 12, "seed": 9, "max_iters": 30, "tol": 0.001,
                "backend": "native", "priority": "high", "deadline_ms": 250}"#,
        )
        .unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.dataset, "kegg");
        assert_eq!(req.max_points, 2000);
        assert_eq!(req.kmeans.k, 12);
        assert_eq!(req.kmeans.seed, 9);
        assert_eq!(req.kmeans.max_iters, 30);
        assert_eq!(req.backend_name, "native");
        assert_eq!(req.priority, Priority::High);
        assert_eq!(req.deadline_ms, Some(250));
    }

    #[test]
    fn minimal_line_uses_defaults() {
        let req = FitRequest::from_json_line(r#"{"id": 1}"#).unwrap();
        assert_eq!(req.dataset, "blobs");
        assert_eq!(req.backend_name, "native");
        assert_eq!(req.priority, Priority::Normal);
        assert_eq!(req.deadline_ms, None);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(FitRequest::from_json_line(r#"{"id": 1, "kay": 8}"#).is_err());
        assert!(FitRequest::from_json_line(r#"{"dataset": "blobs"}"#).is_err(), "id required");
        assert!(FitRequest::from_json_line(r#"{"id": 1, "backend": "gpu"}"#).is_err());
        assert!(FitRequest::from_json_line(r#"{"id": 1, "priority": "urgent"}"#).is_err());
        assert!(FitRequest::from_json_line(r#"[1, 2]"#).is_err());
    }

    #[test]
    fn priorities_roundtrip_and_order() {
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::from_name(p.name()).unwrap(), p);
        }
        assert!(Priority::High.index() < Priority::Normal.index());
        assert!(Priority::Normal.index() < Priority::Low.index());
    }

    #[test]
    fn response_json_is_parseable_and_compact() {
        let resp = FitResponse::shed(42, "queue full", 0.004);
        let j = resp.to_json().to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.get("id").unwrap().as_usize().unwrap(), 42);
        assert_eq!(back.get("status").unwrap().as_str().unwrap(), "shed");
        assert_eq!(back.get("detail").unwrap().as_str().unwrap(), "queue full");
    }

    #[test]
    fn request_round_trips_through_its_wire_form() {
        let req = FitRequest {
            id: 41,
            dataset: "kegg".into(),
            data_seed: 9,
            max_points: 1234,
            normalize: "zscore".into(),
            kmeans: KMeansConfig { k: 5, seed: 77, max_iters: 31, tol: 2e-3, groups: 2, ..Default::default() },
            backend_name: "native".into(),
            artifact_dir: "arts".into(),
            priority: Priority::High,
            deadline_ms: Some(900),
            algorithm: "yinyang".into(),
            trace_id: "deadbeefcafef00d".into(),
            tenant: "acme".into(),
        };
        let back = FitRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.dataset, req.dataset);
        assert_eq!(back.data_seed, req.data_seed);
        assert_eq!(back.max_points, req.max_points);
        assert_eq!(back.normalize, req.normalize);
        assert_eq!(back.kmeans.k, req.kmeans.k);
        assert_eq!(back.kmeans.seed, req.kmeans.seed);
        assert_eq!(back.kmeans.max_iters, req.kmeans.max_iters);
        assert_eq!(back.kmeans.tol, req.kmeans.tol);
        assert_eq!(back.kmeans.groups, req.kmeans.groups);
        assert_eq!(back.backend_name, req.backend_name);
        assert_eq!(back.artifact_dir, req.artifact_dir);
        assert_eq!(back.priority, req.priority);
        assert_eq!(back.deadline_ms, req.deadline_ms);
        assert_eq!(back.algorithm, req.algorithm);
        assert_eq!(back.trace_id, req.trace_id);
        assert_eq!(back.tenant, req.tenant);
        // No deadline ⇒ no key on the wire (absent, not 0 — PROTOCOL.md §3);
        // same for the client-optional §9 keys when unset.
        let none = FitRequest { deadline_ms: None, ..FitRequest::default() };
        assert!(none.to_json().get("deadline_ms").is_err());
        assert!(none.to_json().get("algorithm").is_err());
        assert!(none.to_json().get("trace_id").is_err());
        assert!(none.to_json().get("tenant").is_err());
    }

    #[test]
    fn phase_timings_round_trip_when_present_and_stay_absent_when_off() {
        let req = FitRequest { id: 4, max_points: 200, ..Default::default() };
        let ds = req.load_dataset().unwrap();
        let out = crate::coordinator::driver::run_with_engine(
            &mut crate::runtime::native::NativeEngine,
            &ds,
            &req.kmeans,
        )
        .unwrap();
        let mut resp =
            FitResponse::ok(4, "native".into(), 0, 1, 0.001, 0.02, out.fit, out.report);
        // Profiling off (the default): no phase_* keys on the wire.
        let wire = resp.to_json();
        for ph in Phase::ALL {
            assert!(wire.get(&format!("phase_{}_ms", ph.name())).is_err());
        }
        // Simulate a profiled run: the summary carries totals, every
        // phase key crosses the wire, and re-serializing is byte-stable.
        let mut totals = PhaseTotals::default();
        totals.ms = [1.5, 20.0, 3.25, 7.0, 0.0];
        resp.summary.as_mut().unwrap().phases = Some(totals);
        resp.tenant = "acme".into();
        let line = resp.to_json().to_string();
        let back = FitResponse::from_wire_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.summary.unwrap().phases, Some(totals));
        assert_eq!(back.tenant, "acme");
        assert_eq!(back.to_json().to_string(), line);
    }

    #[test]
    fn explicit_algorithm_is_validated_at_admission() {
        let req =
            FitRequest::from_json_line(r#"{"id": 1, "algorithm": "lloyd"}"#).unwrap();
        assert_eq!(req.algorithm, "lloyd");
        assert!(
            FitRequest::from_json_line(r#"{"id": 1, "algorithm": "kmedoids"}"#).is_err(),
            "unknown kernel names fail at parse time"
        );
        // Empty string means "backend default", identical to key-absent.
        let blank = FitRequest::from_json_line(r#"{"id": 1, "algorithm": ""}"#).unwrap();
        assert_eq!(blank.algorithm, "");
        assert!(
            FitRequest::from_json_line(
                r#"{"id": 1, "backend": "fpga-sim", "algorithm": "lloyd"}"#
            )
            .is_err(),
            "the simulator's filter pipeline is not pinnable"
        );
    }

    #[test]
    fn ok_response_round_trips_its_summary_over_the_wire() {
        let req = FitRequest { id: 3, max_points: 300, ..Default::default() };
        let ds = req.load_dataset().unwrap();
        let out = crate::coordinator::driver::run_with_engine(
            &mut crate::runtime::native::NativeEngine,
            &ds,
            &req.kmeans,
        )
        .unwrap();
        let fnv = assignments_checksum(&out.fit.assignments);
        let mut resp =
            FitResponse::ok(3, "native".into(), 1, 2, 0.004, 0.09, out.fit, out.report);
        resp.trace_id = "00c0ffee00c0ffee".into();
        let wire = resp.to_json().to_string();
        let back = FitResponse::from_wire_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.id, 3);
        assert_eq!(back.status, JobStatus::Ok);
        assert_eq!(back.summary, resp.summary);
        assert_eq!(back.summary.unwrap().assignments_fnv, fnv);
        assert_eq!(back.worker, 1);
        assert_eq!(back.batch_size, 2);
        assert_eq!(back.trace_id, "00c0ffee00c0ffee");
        assert!(back.fit.is_none(), "the clustering itself never crosses the wire");
        // Re-serializing the parsed response is byte-stable: the summary
        // (fingerprint, work counters, trace id included) survives a
        // fan-out/fan-in hop unchanged.
        assert_eq!(back.to_json().to_string(), wire);
    }

    #[test]
    fn shed_and_failed_responses_round_trip_too() {
        let shed = FitResponse::shed(9, "queue full", 0.001);
        let back = FitResponse::from_wire_json(&shed.to_json()).unwrap();
        assert_eq!(back.status, JobStatus::Shed);
        assert_eq!(back.detail, "queue full");
        assert!(back.summary.is_none());
        assert!(JobStatus::from_name("bogus").is_err());
        assert!(
            FitResponse::from_wire_json(&Json::parse(r#"{"id":1}"#).unwrap()).is_err(),
            "status is required"
        );
    }

    #[test]
    fn tenant_labels_are_validated_at_admission() {
        for good in ["", "acme", "team-7", "a.b_c-d", &"x".repeat(64)] {
            assert!(validate_tenant_label(good).is_ok(), "'{good}' should pass");
        }
        for bad in ["~other", "two words", "acme/eu", "emoji🙂", &"x".repeat(65)] {
            assert!(validate_tenant_label(bad).is_err(), "'{bad}' should fail");
        }
        assert!(FitRequest::from_json_line(r#"{"id": 1, "tenant": "acme"}"#).is_ok());
        let err = FitRequest::from_json_line(r#"{"id": 1, "tenant": "no spaces"}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tenant label"), "got: {err}");
        let long = format!(r#"{{"id": 1, "tenant": "{}"}}"#, "y".repeat(65));
        assert!(FitRequest::from_json_line(&long).is_err());
    }

    #[test]
    fn cached_marker_round_trips_and_stays_absent_when_cold() {
        let mut resp = FitResponse::shed(5, "queue full", 0.0);
        assert!(resp.to_json().get("cached").is_err(), "cold replies carry no key");
        resp.cached = true;
        let line = resp.to_json().to_string();
        let back = FitResponse::from_wire_json(&Json::parse(&line).unwrap()).unwrap();
        assert!(back.cached);
        assert_eq!(back.to_json().to_string(), line, "byte-stable with the marker");
    }

    #[test]
    fn checksum_is_order_sensitive_and_stable() {
        let a = assignments_checksum(&[0, 1, 2]);
        let b = assignments_checksum(&[2, 1, 0]);
        assert_ne!(a, b);
        assert_eq!(a, assignments_checksum(&[0, 1, 2]));
        assert_ne!(assignments_checksum(&[]), 0);
    }

    #[test]
    fn run_config_bridge_loads_the_named_dataset() {
        let req = FitRequest {
            id: 1,
            dataset: "blobs".into(),
            max_points: 300,
            ..Default::default()
        };
        let ds = req.load_dataset().unwrap();
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d(), 16);
    }
}
