//! `kpynq::serve` — the sharded, batching, multi-tenant serving layer.
//!
//! The coordinator ([`crate::coordinator`]) runs *one* fit for *one*
//! caller; this module turns it into a request-serving system, the shape
//! every later scaling step (more shards, remote shards, new backends)
//! plugs into:
//!
//! * **Job model** ([`job`]) — [`FitRequest`]/[`FitResponse`] with
//!   priorities and start deadlines; line-delimited JSON on the wire
//!   (`kpynq serve`).
//! * **Admission** ([`queue`]) — a bounded queue with per-priority FIFO
//!   lanes, backpressure ([`ShedPolicy::Block`]) or load-shedding
//!   ([`ShedPolicy::ShedArrivals`]), and deadline shedding at pop time.
//! * **Micro-batching** ([`batch`]) — compatible requests (same `d`, same
//!   engine backend) coalesce at pop time and execute in lockstep, one
//!   `Engine::assign_batch` crossing per iteration for the whole batch.
//! * **Sharded workers** (`worker`, private) — one thread per shard, each
//!   owning a long-lived engine bank, so engine construction / AOT
//!   compilation amortizes across requests instead of being paid per fit.
//! * **Session core** ([`session`]) — the long-lived pool every front-end
//!   drives: queue + workers + a response router that restores
//!   client-chosen job ids, so id spaces from different submitters can
//!   collide safely.
//! * **Wire codec** ([`codec`]) — the NDJSON line framing (bounded
//!   reader, line cap, locked whole-line writes) shared by the daemon and
//!   by protocol *clients* ([`crate::cluster`]), so both ends of the wire
//!   run one implementation of PROTOCOL.md §2.
//! * **Socket front-end** ([`net`]) — `kpynq serve --listen`: a persistent
//!   daemon multiplexing concurrent TCP / Unix-domain connections into one
//!   shared session, speaking the wire protocol specified in PROTOCOL.md.
//!   Its accept loop is generic over a [`net::FrontCore`], which is how
//!   the cross-process cluster front ([`crate::cluster`]) reuses it.
//! * **Telemetry** ([`report`]) — [`ServeReport`]: p50/p95 latency, shed
//!   counts, queue depth, batch sizes, connection counters and per-backend
//!   rollups of `coordinator::telemetry::RunReport`.
//!
//! The contract tenants rely on: **serving never changes a clustering**.
//! A served fit is bit-identical to `coordinator::KpynqSystem::cluster`
//! with the same request parameters, whether it ran solo or coalesced,
//! from a job vector or over a socket — asserted end to end by
//! `rust/tests/serve_integration.rs` and `rust/tests/serve_net.rs`.
//!
//! ```no_run
//! use kpynq::serve::{FitRequest, ServeConfig, Server};
//!
//! let jobs: Vec<FitRequest> = (0..8)
//!     .map(|i| FitRequest { id: i, max_points: 2_000, ..Default::default() })
//!     .collect();
//! let outcome = Server::new(ServeConfig::default()).unwrap().run(jobs).unwrap();
//! println!("{}", outcome.report.render());
//! ```

pub mod batch;
pub mod cache;
pub mod codec;
pub mod job;
pub mod net;
pub mod queue;
pub mod report;
pub mod session;
mod worker;

use std::sync::mpsc;

use crate::error::{Error, Result};

pub use cache::ResultCache;
pub use job::{FitRequest, FitResponse, FitSummary, JobStatus, Priority};
pub use net::{Daemon, NetConfig};
pub use queue::{FairConfig, ShedPolicy};
pub use report::ServeReport;
pub use session::{PartialSession, ServeSession};

/// Pool configuration (the `[serve]` section of the run config).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker shards (threads), each with its own long-lived engines.
    pub workers: usize,
    /// Admission queue capacity (jobs queued, not executing).
    pub queue_capacity: usize,
    /// Micro-batch cap: up to this many compatible jobs coalesce into one
    /// dispatch. 1 disables coalescing.
    pub max_batch: usize,
    /// What happens to arrivals when the queue is full.
    pub shed_policy: ShedPolicy,
    /// Per-tenant weighted-fair weights (`tenant_weights = ["acme=3"]`):
    /// a tenant with weight `w` takes up to `w` consecutive pops per
    /// scheduler rotation while it has queued work (PROTOCOL.md §7).
    pub tenant_weights: std::collections::BTreeMap<String, u32>,
    /// Weight for tenants absent from `tenant_weights` (min 1).
    pub default_tenant_weight: u32,
    /// Max jobs one tenant may hold in the queue at once; 0 disables the
    /// per-tenant quota.
    pub tenant_queue_cap: usize,
    /// Result-cache capacity in entries (fingerprint → finished reply,
    /// PROTOCOL.md §8); 0 disables caching.
    pub cache_capacity: usize,
    /// Cardinality cap on distinct tenants tracked by the accounting
    /// table and tenant-labeled series; overflow lands in the `~other`
    /// bucket (PROTOCOL.md §3).
    pub max_tracked_tenants: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 64,
            max_batch: 8,
            shed_policy: ShedPolicy::Block,
            tenant_weights: std::collections::BTreeMap::new(),
            default_tenant_weight: 1,
            tenant_queue_cap: 0,
            cache_capacity: 64,
            max_tracked_tenants: 64,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.queue_capacity == 0 || self.max_batch == 0 {
            return Err(Error::Config(
                "serve workers/queue_capacity/max_batch must be positive".into(),
            ));
        }
        if self.default_tenant_weight == 0 {
            return Err(Error::Config(
                "serve default_tenant_weight must be positive".into(),
            ));
        }
        if let Some((t, _)) = self.tenant_weights.iter().find(|(_, w)| **w == 0) {
            return Err(Error::Config(format!(
                "serve tenant_weights: tenant '{t}' has zero weight"
            )));
        }
        if self.max_tracked_tenants == 0 {
            return Err(Error::Config(
                "serve max_tracked_tenants must be positive".into(),
            ));
        }
        Ok(())
    }

    /// The queue-side view of the tenant scheduling knobs.
    pub fn fair(&self) -> queue::FairConfig {
        queue::FairConfig {
            weights: self.tenant_weights.clone(),
            default_weight: self.default_tenant_weight,
            tenant_queue_cap: self.tenant_queue_cap,
        }
    }

    /// Parse `"tenant=weight"` entries (the `[serve] tenant_weights`
    /// array and the `--tenant-weights` CLI list).
    pub fn parse_tenant_weights(
        entries: &[String],
    ) -> Result<std::collections::BTreeMap<String, u32>> {
        let mut out = std::collections::BTreeMap::new();
        for entry in entries {
            let (tenant, weight) = entry.split_once('=').ok_or_else(|| {
                Error::Config(format!(
                    "tenant weight '{entry}' must look like 'tenant=weight'"
                ))
            })?;
            job::validate_tenant_label(tenant).map_err(|e| {
                Error::Config(format!("tenant weight '{entry}': {e}"))
            })?;
            if tenant.is_empty() {
                return Err(Error::Config(format!(
                    "tenant weight '{entry}' names an empty tenant"
                )));
            }
            let w: u32 = weight.parse().map_err(|_| {
                Error::Config(format!(
                    "tenant weight '{entry}' has a non-numeric weight"
                ))
            })?;
            if w == 0 {
                return Err(Error::Config(format!(
                    "tenant weight '{entry}' must be at least 1"
                )));
            }
            out.insert(tenant.to_string(), w);
        }
        Ok(out)
    }
}

/// Everything one serving session produced.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// One response per submitted job, ordered by job id.
    pub responses: Vec<FitResponse>,
    pub report: ServeReport,
}

/// The serving system: admission queue + sharded worker pool.
pub struct Server {
    cfg: ServeConfig,
}

impl Server {
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { cfg })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Start a long-lived [`ServeSession`] with this pool shape — the
    /// entry point for front-ends that submit over time instead of all at
    /// once (the socket daemon, [`net::Daemon`], uses this).
    pub fn session(&self) -> Result<ServeSession> {
        ServeSession::start(self.cfg.clone())
    }

    /// Serve a finite stream of jobs to completion: start a session, feed
    /// the admission queue (applying backpressure or shedding per policy),
    /// drain, and aggregate. Jobs are admitted in order; they complete in
    /// whatever order the shards and priorities dictate — responses are
    /// re-sorted by job id.
    pub fn run(&self, jobs: Vec<FitRequest>) -> Result<ServeOutcome> {
        let session = self.session()?;
        let (tx, rx) = mpsc::channel::<FitResponse>();
        for req in jobs {
            session.submit(req, &tx);
        }
        drop(tx);
        // Every submitted job yields exactly one routed response; the
        // channel disconnects once the last reply-sender clone leaves the
        // route map, so this drains without knowing the count up front.
        let mut responses: Vec<FitResponse> = rx.iter().collect();
        responses.sort_by_key(|r| r.id);
        let report = session.shutdown();
        Ok(ServeOutcome { responses, report })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::KMeansConfig;

    fn job(id: u64, k: usize) -> FitRequest {
        FitRequest {
            id,
            max_points: 400,
            kmeans: KMeansConfig { k, seed: id, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn config_validation() {
        ServeConfig::default().validate().unwrap();
        assert!(ServeConfig { workers: 0, ..Default::default() }.validate().is_err());
        assert!(ServeConfig { max_batch: 0, ..Default::default() }.validate().is_err());
        assert!(Server::new(ServeConfig { queue_capacity: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn serves_a_small_stream_end_to_end() {
        let server = Server::new(ServeConfig::default()).unwrap();
        let outcome = server.run((1..=5).map(|i| job(i, 3)).collect()).unwrap();
        assert_eq!(outcome.responses.len(), 5);
        assert!(outcome.responses.iter().all(|r| r.status == JobStatus::Ok));
        // Sorted by id.
        let ids: Vec<u64> = outcome.responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        assert_eq!(outcome.report.completed, 5);
        assert_eq!(outcome.report.submitted, 5);
        assert!(outcome.report.wall_seconds > 0.0);
    }

    #[test]
    fn empty_job_stream_is_fine() {
        let outcome = Server::new(ServeConfig::default()).unwrap().run(Vec::new()).unwrap();
        assert!(outcome.responses.is_empty());
        assert_eq!(outcome.report.completed, 0);
    }

    #[test]
    fn zero_deadline_jobs_are_shed_not_run() {
        let mut late = job(1, 3);
        late.deadline_ms = Some(0);
        let outcome = Server::new(ServeConfig::default())
            .unwrap()
            .run(vec![late, job(2, 3)])
            .unwrap();
        assert_eq!(outcome.responses[0].status, JobStatus::Shed);
        assert_eq!(outcome.responses[1].status, JobStatus::Ok);
        assert_eq!(outcome.report.shed, 1);
        assert_eq!(outcome.report.completed, 1);
    }
}
