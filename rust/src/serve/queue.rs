//! Bounded admission with priorities, deadlines, shedding and
//! per-tenant weighted-fair scheduling.
//!
//! [`AdmissionQueue`] is the pure (single-threaded, deterministic) core:
//! one sub-lane set per tenant (a FIFO lane per [`Priority`] level), a
//! hard capacity, optional per-tenant in-queue quotas, and a pop that
//! enforces deadline shedding, rotates tenants under a weighted
//! round-robin credit scheme, and performs micro-batch coalescing (see
//! `serve::batch` for the compatibility key). [`SharedQueue`] wraps it
//! in a mutex + two condvars for the worker pool:
//!
//! * **Backpressure** — under [`ShedPolicy::Block`] a submitter sleeps
//!   until a worker frees a slot (the `space` condvar) *or its own start
//!   deadline passes*, whichever comes first; under
//!   [`ShedPolicy::ShedArrivals`] a full queue rejects the newcomer
//!   immediately (load-shedding, the "fail fast under overload" contract).
//! * **Start deadlines** — a job that has not begun executing within its
//!   `deadline_ms` is shed, never executed: a tenant that has stopped
//!   waiting should not consume engine time. The clock starts at
//!   *submission* (`SharedQueue::submit` entry), so time spent blocked on
//!   a full queue counts — deadlines must not silently stretch exactly
//!   when the system is overloaded.
//! * **Fairness** — pops rotate across tenants, each tenant taking up to
//!   `weight` consecutive pops per rotation (PROTOCOL.md §7). Priority
//!   ordering is preserved *within* a tenant's entitlement. Batch riders
//!   are exempt: compatible queued jobs coalesce with the head regardless
//!   of tenant (they are a free upgrade, not a scheduling decision).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::batch::BatchKey;
use super::job::{FitRequest, Priority};

/// What happens to an arrival when the queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Block the submitter until a slot frees (backpressure).
    Block,
    /// Reject the newcomer immediately with a shed response.
    ShedArrivals,
}

impl ShedPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ShedPolicy::Block => "block",
            ShedPolicy::ShedArrivals => "shed",
        }
    }

    pub fn from_name(name: &str) -> Result<ShedPolicy> {
        match name {
            "block" => Ok(ShedPolicy::Block),
            "shed" => Ok(ShedPolicy::ShedArrivals),
            other => Err(Error::Config(format!("unknown shed policy '{other}'"))),
        }
    }
}

/// Per-tenant scheduling knobs (`[serve] tenant_weights`, PROTOCOL.md §7).
#[derive(Clone, Debug)]
pub struct FairConfig {
    /// Explicit per-tenant weights; tenants not listed get
    /// `default_weight`. A weight of `w` entitles the tenant to `w`
    /// consecutive pops per rotation while it has queued work.
    pub weights: BTreeMap<String, u32>,
    /// Weight for tenants absent from `weights` (including the anonymous
    /// `""` tenant). Clamped to at least 1.
    pub default_weight: u32,
    /// Maximum jobs one tenant may hold in the queue at once; `0`
    /// disables the per-tenant cap (only the global capacity applies).
    pub tenant_queue_cap: usize,
}

impl Default for FairConfig {
    fn default() -> Self {
        Self { weights: BTreeMap::new(), default_weight: 1, tenant_queue_cap: 0 }
    }
}

impl FairConfig {
    fn weight_of(&self, tenant: &str) -> u64 {
        u64::from(
            self.weights
                .get(tenant)
                .copied()
                .unwrap_or(self.default_weight)
                .max(1),
        )
    }
}

/// A job waiting in the queue.
#[derive(Debug)]
pub struct Pending {
    pub req: FitRequest,
    /// When the client handed the job to [`SharedQueue::submit`] — *not*
    /// when a slot freed up. Deadlines and queue-wait are measured from
    /// here so overload-time blocking is visible.
    pub submitted_at: Instant,
}

impl Pending {
    /// True once the job's start deadline has passed.
    pub fn expired(&self) -> bool {
        match self.req.deadline_ms {
            Some(ms) => self.submitted_at.elapsed() >= Duration::from_millis(ms),
            None => false,
        }
    }

    /// Seconds since submission — the `queue_wait` a client observes.
    pub fn queue_seconds(&self) -> f64 {
        self.submitted_at.elapsed().as_secs_f64()
    }
}

/// One tenant's sub-lanes: a FIFO per priority level.
#[derive(Debug)]
struct TenantLane {
    tenant: String,
    weight: u64,
    lanes: [VecDeque<Pending>; Priority::LEVELS],
}

impl TenantLane {
    fn new(tenant: String, weight: u64) -> Self {
        Self {
            tenant,
            weight,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
        }
    }

    fn len(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }

    fn is_empty(&self) -> bool {
        self.lanes.iter().all(VecDeque::is_empty)
    }

    /// Oldest job in the highest non-empty priority lane.
    fn pop_head(&mut self) -> Option<Pending> {
        self.lanes.iter_mut().find(|l| !l.is_empty())?.pop_front()
    }
}

/// Result of [`AdmissionQueue::try_admit`].
#[derive(Debug)]
pub enum Admission {
    Admitted,
    /// At capacity — the request is handed back for the policy to decide.
    /// `tenant_cap` distinguishes a per-tenant quota rejection from the
    /// global queue being full.
    Full { req: FitRequest, tenant_cap: bool },
    /// Queue closed — no further admissions.
    Closed(FitRequest),
}

/// Result of [`AdmissionQueue::pop_batch`]: the coalesced batch plus any
/// expired jobs encountered (and removed) along the way. `batch` can be
/// empty when everything reachable had expired.
#[derive(Debug, Default)]
pub struct PopOutcome {
    pub batch: Vec<Pending>,
    pub shed: Vec<Pending>,
}

/// Counters the queue accumulates over its lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Arrivals rejected because the queue (or a tenant quota) was full
    /// (ShedArrivals only).
    pub shed_full: u64,
    /// Jobs shed because their start deadline passed — at pop time, or
    /// while their submitter was blocked on a full queue.
    pub shed_deadline: u64,
    /// Highest simultaneous queue depth observed.
    pub peak_depth: usize,
}

/// The pure bounded priority queue. Not thread-safe — see [`SharedQueue`].
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    fair: FairConfig,
    /// Tenant sub-lanes in first-arrival order; empty lanes are garbage
    /// collected after every pop/remove, so each entry has queued work.
    tenants: Vec<TenantLane>,
    /// Weighted round-robin position: `tenants[cursor]` may take
    /// `credits` more pops before the rotation advances.
    cursor: usize,
    credits: u64,
    closed: bool,
    stats: QueueStats,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        Self::with_fair(capacity, FairConfig::default())
    }

    pub fn with_fair(capacity: usize, fair: FairConfig) -> Self {
        assert!(capacity >= 1, "queue capacity must be positive");
        Self {
            capacity,
            fair,
            tenants: Vec::new(),
            cursor: 0,
            credits: 0,
            closed: false,
            stats: QueueStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.tenants.iter().map(TenantLane::len).sum()
    }

    /// Per-priority-lane depths, indexed by [`Priority::index`] (high,
    /// normal, low), summed across tenants — the `queue_lanes` field of
    /// the `stats` control frame (PROTOCOL.md §6).
    pub fn lane_depths(&self) -> [usize; Priority::LEVELS] {
        let mut out = [0usize; Priority::LEVELS];
        for t in &self.tenants {
            for (slot, lane) in out.iter_mut().zip(t.lanes.iter()) {
                *slot += lane.len();
            }
        }
        out
    }

    /// Queued jobs per named tenant — the `serve.queue.depth{tenant=…}`
    /// series and the `queued` key of the `stats` tenants object. The
    /// anonymous `""` tenant is folded into the unlabeled total only.
    pub fn tenant_depths(&self) -> BTreeMap<String, usize> {
        self.tenants
            .iter()
            .filter(|t| !t.tenant.is_empty() && !t.is_empty())
            .map(|t| (t.tenant.clone(), t.len()))
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.iter().all(TenantLane::is_empty)
    }

    /// Stop admitting; queued jobs still drain.
    pub fn close(&mut self) {
        self.closed = true;
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    pub(crate) fn count_shed_full(&mut self) {
        self.stats.shed_full += 1;
    }

    pub(crate) fn count_shed_deadline(&mut self) {
        self.stats.shed_deadline += 1;
    }

    /// Admit one job stamped now — see [`Self::try_admit_at`].
    pub fn try_admit(&mut self, req: FitRequest) -> Admission {
        self.try_admit_at(req, Instant::now())
    }

    /// Admit one job carrying its original submission instant, or hand it
    /// back if the queue (or the tenant's quota) is full, or closed.
    pub fn try_admit_at(&mut self, req: FitRequest, submitted_at: Instant) -> Admission {
        if self.closed {
            return Admission::Closed(req);
        }
        if self.len() >= self.capacity {
            return Admission::Full { req, tenant_cap: false };
        }
        let cap = self.fair.tenant_queue_cap;
        if cap > 0 {
            let depth = self
                .tenants
                .iter()
                .find(|t| t.tenant == req.tenant)
                .map(TenantLane::len)
                .unwrap_or(0);
            if depth >= cap {
                return Admission::Full { req, tenant_cap: true };
            }
        }
        let ti = match self.tenants.iter().position(|t| t.tenant == req.tenant) {
            Some(i) => i,
            None => {
                let weight = self.fair.weight_of(&req.tenant);
                self.tenants.push(TenantLane::new(req.tenant.clone(), weight));
                if self.tenants.len() == 1 {
                    // First lane: start the rotation here with a full
                    // credit allotment.
                    self.cursor = 0;
                    self.credits = self.tenants[0].weight;
                }
                self.tenants.len() - 1
            }
        };
        let lane = req.priority.index();
        self.tenants[ti].lanes[lane].push_back(Pending { req, submitted_at });
        let depth = self.len();
        if depth > self.stats.peak_depth {
            self.stats.peak_depth = depth;
        }
        Admission::Admitted
    }

    /// Remove a queued job by its (session-rewritten) id — the queue side
    /// of per-request cancellation (PROTOCOL.md §6 `cancel`). Returns the
    /// removed entry, or `None` when no queued job carries that id (it
    /// already popped, or never existed). Ids are session tickets, so at
    /// most one queued job can match.
    pub fn remove(&mut self, id: u64) -> Option<Pending> {
        for t in self.tenants.iter_mut() {
            for lane in t.lanes.iter_mut() {
                if let Some(i) = lane.iter().position(|p| p.req.id == id) {
                    let removed = lane.remove(i);
                    self.gc_lanes();
                    return removed;
                }
            }
        }
        None
    }

    /// Drop emptied tenant lanes, keeping the rotation cursor coherent.
    fn gc_lanes(&mut self) {
        let mut i = 0;
        while i < self.tenants.len() {
            if self.tenants[i].is_empty() {
                self.tenants.remove(i);
                if self.cursor > i {
                    self.cursor -= 1;
                } else if self.cursor == i {
                    // The lane under the cursor vanished; whichever lane
                    // slid (or wrapped) into its place starts fresh.
                    if self.cursor >= self.tenants.len() {
                        self.cursor = 0;
                    }
                    self.credits = self
                        .tenants
                        .get(self.cursor)
                        .map(|t| t.weight)
                        .unwrap_or(0);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Advance the weighted round-robin to the lane that owns the next
    /// pop. Callers must ensure the queue is non-empty; after
    /// [`Self::gc_lanes`] every lane has work, so only exhausted credits
    /// move the cursor.
    fn fair_head_index(&mut self) -> usize {
        let n = self.tenants.len();
        debug_assert!(n > 0, "fair_head_index on an empty queue");
        if self.cursor >= n {
            self.cursor = 0;
            self.credits = self.tenants[0].weight;
        }
        if self.credits == 0 {
            self.cursor = (self.cursor + 1) % n;
            self.credits = self.tenants[self.cursor].weight;
        }
        self.cursor
    }

    /// Shed every job whose start deadline has passed, queue-wide.
    /// Work-efficiency at the scheduling layer: expired jobs are removed
    /// before they can occupy a batch slot or a fairness credit.
    fn shed_expired(&mut self, out: &mut PopOutcome) {
        let mut shed_deadline = 0u64;
        for t in self.tenants.iter_mut() {
            for lane in t.lanes.iter_mut() {
                let mut i = 0;
                while i < lane.len() {
                    if lane[i].expired() {
                        out.shed.push(lane.remove(i).expect("index checked"));
                        shed_deadline += 1;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        self.stats.shed_deadline += shed_deadline;
        self.gc_lanes();
    }

    /// Pop the next job under the weighted-fair rotation (oldest
    /// highest-priority job of the tenant whose turn it is) plus up to
    /// `max_batch - 1` queued jobs sharing its [`BatchKey`], scanned in
    /// rotation order across all tenants (riders are a free upgrade — a
    /// high-priority head coalesces compatible lower-priority riders from
    /// any tenant without spending that tenant's credits). Jobs whose key
    /// is unknown (file datasets) or unbatchable (fpga-sim) always pop
    /// solo. Expired jobs are removed first and returned in `shed`.
    pub fn pop_batch(&mut self, max_batch: usize) -> PopOutcome {
        assert!(max_batch >= 1, "max_batch must be positive");
        let mut out = PopOutcome::default();
        self.shed_expired(&mut out);
        if self.is_empty() {
            return out;
        }
        let head_idx = self.fair_head_index();
        self.credits = self.credits.saturating_sub(1);
        let head = self.tenants[head_idx]
            .pop_head()
            .expect("gc left only non-empty lanes");
        let key = BatchKey::of(&head.req);
        out.batch.push(head);
        if key.is_none() || max_batch == 1 {
            self.gc_lanes();
            return out;
        }
        let n = self.tenants.len();
        'riders: for step in 0..n {
            let ti = (head_idx + step) % n;
            for lane in self.tenants[ti].lanes.iter_mut() {
                let mut i = 0;
                while i < lane.len() {
                    if out.batch.len() >= max_batch {
                        break 'riders;
                    }
                    if BatchKey::of(&lane[i].req) == key {
                        out.batch.push(lane.remove(i).expect("index checked"));
                    } else {
                        i += 1;
                    }
                }
            }
        }
        self.gc_lanes();
        out
    }
}

/// Outcome of a [`SharedQueue::submit`].
#[derive(Debug)]
pub enum Submission {
    Admitted,
    /// Rejected; the reason is queue-full or tenant-quota (ShedArrivals),
    /// deadline-expired-while-blocked (Block), or queue-closed.
    /// `waited_seconds` is how long the submitter spent blocked before
    /// the verdict — zero on immediate rejections.
    Shed { req: FitRequest, reason: &'static str, waited_seconds: f64 },
}

/// Thread-safe wrapper: the admission side of the serve subsystem.
#[derive(Debug)]
pub struct SharedQueue {
    inner: Mutex<AdmissionQueue>,
    /// Signalled when a slot frees (wakes blocked submitters).
    space: Condvar,
    /// Signalled when work arrives or the queue closes (wakes workers).
    work: Condvar,
}

impl SharedQueue {
    pub fn new(capacity: usize) -> Self {
        Self::with_fair(capacity, FairConfig::default())
    }

    pub fn with_fair(capacity: usize, fair: FairConfig) -> Self {
        Self {
            inner: Mutex::new(AdmissionQueue::with_fair(capacity, fair)),
            space: Condvar::new(),
            work: Condvar::new(),
        }
    }

    /// Submit one job under the given policy. Blocks only under
    /// [`ShedPolicy::Block`] with a full queue — and even then never past
    /// the job's own start deadline: a deadline that expires while the
    /// submitter is blocked unblocks it with a shed verdict (the clock
    /// runs from submission, PROTOCOL.md §7).
    pub fn submit(&self, req: FitRequest, policy: ShedPolicy) -> Submission {
        let submitted_at = Instant::now();
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        let mut req = req;
        loop {
            match q.try_admit_at(req, submitted_at) {
                Admission::Admitted => {
                    self.work.notify_one();
                    return Submission::Admitted;
                }
                Admission::Closed(r) => {
                    return Submission::Shed {
                        req: r,
                        reason: "queue closed",
                        waited_seconds: submitted_at.elapsed().as_secs_f64(),
                    };
                }
                Admission::Full { req: r, tenant_cap } => match policy {
                    ShedPolicy::ShedArrivals => {
                        q.count_shed_full();
                        let reason = if tenant_cap {
                            "tenant queue quota exceeded"
                        } else {
                            "queue full"
                        };
                        return Submission::Shed {
                            req: r,
                            reason,
                            waited_seconds: submitted_at.elapsed().as_secs_f64(),
                        };
                    }
                    ShedPolicy::Block => {
                        let wait = match r.deadline_ms {
                            Some(ms) => {
                                let deadline = submitted_at + Duration::from_millis(ms);
                                let now = Instant::now();
                                if now >= deadline {
                                    q.count_shed_deadline();
                                    return Submission::Shed {
                                        req: r,
                                        reason:
                                            "start deadline expired while blocked on a full queue",
                                        waited_seconds: submitted_at.elapsed().as_secs_f64(),
                                    };
                                }
                                Some(deadline - now)
                            }
                            None => None,
                        };
                        req = r;
                        q = match wait {
                            Some(d) => {
                                self.space
                                    .wait_timeout(q, d)
                                    .expect("queue mutex poisoned")
                                    .0
                            }
                            None => self.space.wait(q).expect("queue mutex poisoned"),
                        };
                    }
                },
            }
        }
    }

    /// Take the next micro-batch, blocking while the queue is empty.
    /// Returns `None` once the queue is closed *and* drained — the worker
    /// shutdown signal.
    pub fn take_batch(&self, max_batch: usize) -> Option<PopOutcome> {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        loop {
            if !q.is_empty() {
                let out = q.pop_batch(max_batch);
                self.space.notify_all();
                return Some(out);
            }
            if q.is_closed() {
                return None;
            }
            q = self.work.wait(q).expect("queue mutex poisoned");
        }
    }

    /// Remove a queued job by id (see [`AdmissionQueue::remove`]); a
    /// successful removal frees a slot, so blocked submitters are woken.
    pub fn remove(&self, id: u64) -> Option<Pending> {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        let removed = q.remove(id);
        if removed.is_some() {
            self.space.notify_all();
        }
        removed
    }

    /// Jobs currently queued (admitted, not yet popped) — the live
    /// `queue_depth` the `stats` control frame reports (PROTOCOL.md §6).
    pub fn depth(&self) -> usize {
        self.inner.lock().expect("queue mutex poisoned").len()
    }

    /// Per-priority-lane depths (high, normal, low) — see
    /// [`AdmissionQueue::lane_depths`].
    pub fn lane_depths(&self) -> [usize; Priority::LEVELS] {
        self.inner.lock().expect("queue mutex poisoned").lane_depths()
    }

    /// Queued jobs per named tenant — see [`AdmissionQueue::tenant_depths`].
    pub fn tenant_depths(&self) -> BTreeMap<String, usize> {
        self.inner.lock().expect("queue mutex poisoned").tenant_depths()
    }

    /// Close the queue and wake everyone (submitters shed, workers drain
    /// and exit).
    pub fn close(&self) {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        q.close();
        self.work.notify_all();
        self.space.notify_all();
    }

    pub fn stats(&self) -> QueueStats {
        self.inner.lock().expect("queue mutex poisoned").stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn req(id: u64, priority: Priority) -> FitRequest {
        FitRequest { id, priority, ..Default::default() }
    }

    fn treq(id: u64, tenant: &str) -> FitRequest {
        FitRequest { id, tenant: tenant.into(), ..Default::default() }
    }

    fn weights(pairs: &[(&str, u32)]) -> FairConfig {
        FairConfig {
            weights: pairs.iter().map(|(t, w)| (t.to_string(), *w)).collect(),
            ..FairConfig::default()
        }
    }

    #[test]
    fn capacity_is_enforced() {
        let mut q = AdmissionQueue::new(2);
        assert!(matches!(q.try_admit(req(1, Priority::Normal)), Admission::Admitted));
        assert!(matches!(q.try_admit(req(2, Priority::Normal)), Admission::Admitted));
        match q.try_admit(req(3, Priority::Normal)) {
            Admission::Full { req: r, tenant_cap } => {
                assert_eq!(r.id, 3);
                assert!(!tenant_cap, "global capacity, not a tenant quota");
            }
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.stats().peak_depth, 2);
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Low));
        q.try_admit(req(2, Priority::Normal));
        q.try_admit(req(3, Priority::High));
        q.try_admit(req(4, Priority::High));
        assert_eq!(q.lane_depths(), [2, 1, 1], "high, normal, low");
        let order: Vec<u64> = (0..4)
            .map(|_| q.pop_batch(1).batch.remove(0).req.id)
            .collect();
        assert_eq!(order, vec![3, 4, 2, 1]);
        assert_eq!(q.lane_depths(), [0, 0, 0]);
    }

    #[test]
    fn coalesces_compatible_jobs_up_to_max_batch() {
        let mut q = AdmissionQueue::new(8);
        for id in 1..=5 {
            q.try_admit(req(id, Priority::Normal)); // all blobs/native: same key
        }
        let out = q.pop_batch(3);
        assert_eq!(out.batch.len(), 3);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn incompatible_jobs_do_not_ride_along() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Normal)); // blobs (d=16)
        let mut kegg = req(2, Priority::Normal);
        kegg.dataset = "kegg".into(); // d=20 — different key
        q.try_admit(kegg);
        q.try_admit(req(3, Priority::Normal));
        let out = q.pop_batch(8);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![1, 3],
            "the d=20 job must be skipped, not coalesced"
        );
        assert_eq!(q.pop_batch(8).batch[0].req.id, 2);
    }

    #[test]
    fn fpga_sim_jobs_pop_solo() {
        let mut q = AdmissionQueue::new(8);
        let mut sim = req(1, Priority::Normal);
        sim.backend_name = "fpga-sim".into();
        q.try_admit(sim);
        q.try_admit(req(2, Priority::Normal));
        let out = q.pop_batch(8);
        assert_eq!(out.batch.len(), 1);
        assert_eq!(out.batch[0].req.id, 1);
    }

    #[test]
    fn high_priority_head_coalesces_lower_priority_riders() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Low));
        q.try_admit(req(2, Priority::High));
        let out = q.pop_batch(4);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![2, 1],
            "the high-priority job leads; the low-priority one rides"
        );
    }

    #[test]
    fn expired_jobs_are_shed_at_pop() {
        let mut q = AdmissionQueue::new(8);
        let mut dead = req(1, Priority::High);
        dead.deadline_ms = Some(0); // expires immediately on admission
        q.try_admit(dead);
        q.try_admit(req(2, Priority::Normal));
        let out = q.pop_batch(4);
        assert_eq!(out.shed.len(), 1);
        assert_eq!(out.shed[0].req.id, 1);
        assert_eq!(out.batch.len(), 1);
        assert_eq!(out.batch[0].req.id, 2);
        assert_eq!(q.stats().shed_deadline, 1);
    }

    #[test]
    fn remove_by_id_pulls_a_queued_job_and_only_that_job() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Normal));
        q.try_admit(req(2, Priority::High));
        q.try_admit(req(3, Priority::Low));
        let removed = q.remove(2).expect("id 2 is queued");
        assert_eq!(removed.req.id, 2);
        assert_eq!(q.len(), 2);
        assert!(q.remove(2).is_none(), "a second remove finds nothing");
        assert!(q.remove(99).is_none(), "unknown ids find nothing");
        // The survivors still pop in priority/FIFO order.
        assert_eq!(q.pop_batch(1).batch[0].req.id, 1);
        assert_eq!(q.pop_batch(1).batch[0].req.id, 3);
    }

    #[test]
    fn shared_queue_remove_and_depth() {
        let q = SharedQueue::new(4);
        assert_eq!(q.depth(), 0);
        q.submit(req(7, Priority::Normal), ShedPolicy::Block);
        q.submit(req(8, Priority::Normal), ShedPolicy::Block);
        assert_eq!(q.depth(), 2);
        assert_eq!(q.remove(7).unwrap().req.id, 7);
        assert_eq!(q.depth(), 1);
        assert!(q.remove(7).is_none());
    }

    #[test]
    fn closed_queue_rejects_and_reports() {
        let mut q = AdmissionQueue::new(2);
        q.try_admit(req(1, Priority::Normal));
        q.close();
        assert!(matches!(q.try_admit(req(2, Priority::Normal)), Admission::Closed(_)));
        assert!(q.is_closed());
        assert_eq!(q.len(), 1, "queued work still drains after close");
    }

    #[test]
    fn shared_queue_hands_work_across_threads() {
        let q = SharedQueue::new(4);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for id in 1..=3 {
                    assert!(matches!(
                        q.submit(req(id, Priority::Normal), ShedPolicy::Block),
                        Submission::Admitted
                    ));
                }
                q.close();
            });
            let mut seen = Vec::new();
            while let Some(out) = q.take_batch(1) {
                for p in out.batch {
                    seen.push(p.req.id);
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, vec![1, 2, 3]);
        });
    }

    #[test]
    fn shed_arrivals_policy_rejects_when_full() {
        let q = SharedQueue::new(1);
        assert!(matches!(
            q.submit(req(1, Priority::Normal), ShedPolicy::ShedArrivals),
            Submission::Admitted
        ));
        match q.submit(req(2, Priority::Normal), ShedPolicy::ShedArrivals) {
            Submission::Shed { req, reason, .. } => {
                assert_eq!(req.id, 2);
                assert_eq!(reason, "queue full");
            }
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(q.stats().shed_full, 1);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [ShedPolicy::Block, ShedPolicy::ShedArrivals] {
            assert_eq!(ShedPolicy::from_name(p.name()).unwrap(), p);
        }
        assert!(ShedPolicy::from_name("drop").is_err());
    }

    // ---- submission-clock deadlines (the overload-time bugfix) ----

    #[test]
    fn blocked_submitter_sheds_on_its_own_deadline() {
        let q = SharedQueue::new(1);
        assert!(matches!(
            q.submit(req(1, Priority::Normal), ShedPolicy::Block),
            Submission::Admitted
        ));
        let mut late = req(2, Priority::Normal);
        late.deadline_ms = Some(40);
        let start = Instant::now();
        match q.submit(late, ShedPolicy::Block) {
            Submission::Shed { req, reason, waited_seconds } => {
                assert_eq!(req.id, 2);
                assert!(reason.contains("deadline"), "reason was '{reason}'");
                assert!(waited_seconds >= 0.03, "waited only {waited_seconds}s");
            }
            other => panic!("expected a deadline shed, got {other:?}"),
        }
        assert!(
            start.elapsed() >= Duration::from_millis(30),
            "the submitter must block until its deadline, not spin"
        );
        assert_eq!(q.stats().shed_deadline, 1);
        assert_eq!(q.depth(), 1, "the queued job is untouched");
    }

    #[test]
    fn queue_wait_clock_starts_at_submission_not_admission() {
        let q = SharedQueue::new(1);
        assert!(matches!(
            q.submit(req(1, Priority::Normal), ShedPolicy::Block),
            Submission::Admitted
        ));
        std::thread::scope(|scope| {
            scope.spawn(|| {
                // Blocks on the full queue until the first pop frees a slot.
                assert!(matches!(
                    q.submit(req(2, Priority::Normal), ShedPolicy::Block),
                    Submission::Admitted
                ));
            });
            std::thread::sleep(Duration::from_millis(60));
            assert_eq!(q.take_batch(1).unwrap().batch[0].req.id, 1);
            let second = q.take_batch(1).unwrap();
            let p = &second.batch[0];
            assert_eq!(p.req.id, 2);
            assert!(
                p.queue_seconds() >= 0.05,
                "queue-wait must include blocked time, got {}s",
                p.queue_seconds()
            );
        });
    }

    // ---- weighted-fair tenant scheduling ----

    #[test]
    fn weighted_fair_pop_interleaves_tenants_by_weight() {
        let mut q = AdmissionQueue::with_fair(16, weights(&[("acme", 2), ("free", 1)]));
        q.try_admit(treq(1, "acme"));
        q.try_admit(treq(11, "free"));
        q.try_admit(treq(2, "acme"));
        q.try_admit(treq(12, "free"));
        q.try_admit(treq(3, "acme"));
        q.try_admit(treq(4, "acme"));
        let order: Vec<u64> = (0..6)
            .map(|_| q.pop_batch(1).batch.remove(0).req.id)
            .collect();
        assert_eq!(
            order,
            vec![1, 2, 11, 3, 4, 12],
            "two acme pops, one free pop, repeating"
        );
    }

    #[test]
    fn flooding_tenant_cannot_starve_a_light_one() {
        let mut q = AdmissionQueue::with_fair(32, FairConfig::default());
        for id in 1..=6 {
            q.try_admit(treq(id, "flood"));
        }
        q.try_admit(treq(100, "light"));
        let first_two: Vec<u64> = (0..2)
            .map(|_| q.pop_batch(1).batch.remove(0).req.id)
            .collect();
        assert!(
            first_two.contains(&100),
            "the light tenant must pop within one rotation, got {first_two:?}"
        );
    }

    #[test]
    fn tenant_queue_cap_rejects_only_the_hog() {
        let fair = FairConfig { tenant_queue_cap: 2, ..FairConfig::default() };
        let mut q = AdmissionQueue::with_fair(8, fair);
        assert!(matches!(q.try_admit(treq(1, "hog")), Admission::Admitted));
        assert!(matches!(q.try_admit(treq(2, "hog")), Admission::Admitted));
        match q.try_admit(treq(3, "hog")) {
            Admission::Full { req: r, tenant_cap } => {
                assert_eq!(r.id, 3);
                assert!(tenant_cap, "a quota rejection, not global capacity");
            }
            other => panic!("expected Full, got {other:?}"),
        }
        assert!(
            matches!(q.try_admit(treq(4, "other")), Admission::Admitted),
            "other tenants still have room"
        );
        assert_eq!(q.tenant_depths().get("hog"), Some(&2));
        assert_eq!(q.tenant_depths().get("other"), Some(&1));
    }

    #[test]
    fn tenant_quota_shed_reason_names_the_quota() {
        let fair = FairConfig { tenant_queue_cap: 1, ..FairConfig::default() };
        let q = SharedQueue::with_fair(8, fair);
        assert!(matches!(
            q.submit(treq(1, "hog"), ShedPolicy::ShedArrivals),
            Submission::Admitted
        ));
        match q.submit(treq(2, "hog"), ShedPolicy::ShedArrivals) {
            Submission::Shed { reason, .. } => {
                assert_eq!(reason, "tenant queue quota exceeded");
            }
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(q.stats().shed_full, 1);
    }

    #[test]
    fn riders_coalesce_across_tenants_without_spending_credits() {
        let mut q = AdmissionQueue::with_fair(16, weights(&[("a", 1), ("b", 1)]));
        q.try_admit(treq(1, "a"));
        q.try_admit(treq(2, "b"));
        q.try_admit(treq(3, "b"));
        let out = q.pop_batch(8);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "compatible jobs coalesce across tenant lanes"
        );
        assert!(q.is_empty());
    }

    #[test]
    fn fair_pop_order_is_deterministic() {
        proptest::run_cases("fair-pop-deterministic", 0xFA1A, |rng| {
            let tenants = ["", "a", "b", "c"];
            let njobs = 4 + rng.next_below(24);
            let mut reqs = Vec::with_capacity(njobs);
            for id in 0..njobs {
                let mut r = treq(id as u64 + 1, tenants[rng.next_below(tenants.len())]);
                r.priority = [Priority::High, Priority::Normal, Priority::Low]
                    [rng.next_below(3)];
                reqs.push(r);
            }
            let fair = weights(&[("a", 3), ("b", 1)]);
            let mut q1 = AdmissionQueue::with_fair(64, fair.clone());
            let mut q2 = AdmissionQueue::with_fair(64, fair);
            for r in &reqs {
                q1.try_admit(r.clone());
                q2.try_admit(r.clone());
            }
            for _ in 0..njobs {
                let a = q1.pop_batch(1).batch.remove(0).req.id;
                let b = q2.pop_batch(1).batch.remove(0).req.id;
                if a != b {
                    return Err(format!("pop order diverged: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn saturated_rotation_gives_each_tenant_exactly_its_weight() {
        proptest::run_cases("fair-rotation-weights", 0x0F41, |rng| {
            let ntenants = 2 + rng.next_below(3); // 2..=4 tenants
            let mut fair = FairConfig::default();
            let mut per_tenant = Vec::new();
            for i in 0..ntenants {
                let w = 1 + rng.next_below(3) as u32; // weights 1..=3
                fair.weights.insert(format!("t{i}"), w);
                per_tenant.push(w as usize);
            }
            let rotation: usize = per_tenant.iter().sum();
            let mut q = AdmissionQueue::with_fair(256, fair);
            // Keep every lane saturated: two full rotations of backlog each.
            let mut id = 0u64;
            for (i, w) in per_tenant.iter().enumerate() {
                for _ in 0..(w * 2 + 1) {
                    id += 1;
                    q.try_admit(treq(id, &format!("t{i}")));
                }
            }
            let mut counts = BTreeMap::new();
            for _ in 0..rotation {
                let t = q.pop_batch(1).batch.remove(0).req.tenant.clone();
                *counts.entry(t).or_insert(0usize) += 1;
            }
            for (i, w) in per_tenant.iter().enumerate() {
                let got = counts.get(&format!("t{i}")).copied().unwrap_or(0);
                if got != *w {
                    return Err(format!(
                        "tenant t{i} took {got} pops in a rotation of {rotation}, want {w}"
                    ));
                }
            }
            Ok(())
        });
    }
}
