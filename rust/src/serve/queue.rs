//! Bounded admission with priorities, deadlines and shedding.
//!
//! [`AdmissionQueue`] is the pure (single-threaded, deterministic) core:
//! one FIFO lane per [`Priority`] level, a hard capacity, and a pop that
//! both enforces deadline shedding and performs micro-batch coalescing
//! (see `serve::batch` for the compatibility key). [`SharedQueue`] wraps
//! it in a mutex + two condvars for the worker pool:
//!
//! * **Backpressure** — under [`ShedPolicy::Block`] a submitter sleeps
//!   until a worker frees a slot (the `space` condvar); under
//!   [`ShedPolicy::ShedArrivals`] a full queue rejects the newcomer
//!   immediately (load-shedding, the "fail fast under overload" contract).
//! * **Start deadlines** — a job that has not begun executing within its
//!   `deadline_ms` is shed at pop time, never executed: a tenant that has
//!   stopped waiting should not consume engine time.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::batch::BatchKey;
use super::job::{FitRequest, Priority};

/// What happens to an arrival when the queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Block the submitter until a slot frees (backpressure).
    Block,
    /// Reject the newcomer immediately with a shed response.
    ShedArrivals,
}

impl ShedPolicy {
    pub fn name(self) -> &'static str {
        match self {
            ShedPolicy::Block => "block",
            ShedPolicy::ShedArrivals => "shed",
        }
    }

    pub fn from_name(name: &str) -> Result<ShedPolicy> {
        match name {
            "block" => Ok(ShedPolicy::Block),
            "shed" => Ok(ShedPolicy::ShedArrivals),
            other => Err(Error::Config(format!("unknown shed policy '{other}'"))),
        }
    }
}

/// A job waiting in the queue.
#[derive(Debug)]
pub struct Pending {
    pub req: FitRequest,
    pub admitted_at: Instant,
}

impl Pending {
    /// True once the job's start deadline has passed.
    pub fn expired(&self) -> bool {
        match self.req.deadline_ms {
            Some(ms) => self.admitted_at.elapsed() >= Duration::from_millis(ms),
            None => false,
        }
    }

    /// Seconds this job has been queued so far.
    pub fn queue_seconds(&self) -> f64 {
        self.admitted_at.elapsed().as_secs_f64()
    }
}

/// Result of [`AdmissionQueue::try_admit`].
#[derive(Debug)]
pub enum Admission {
    Admitted,
    /// At capacity — the request is handed back for the policy to decide.
    Full(FitRequest),
    /// Queue closed — no further admissions.
    Closed(FitRequest),
}

/// Result of [`AdmissionQueue::pop_batch`]: the coalesced batch plus any
/// expired jobs encountered (and removed) along the way. `batch` can be
/// empty when everything reachable had expired.
#[derive(Debug, Default)]
pub struct PopOutcome {
    pub batch: Vec<Pending>,
    pub shed: Vec<Pending>,
}

/// Counters the queue accumulates over its lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Arrivals rejected because the queue was full (ShedArrivals only).
    pub shed_full: u64,
    /// Jobs shed at pop time because their start deadline had passed.
    pub shed_deadline: u64,
    /// Highest simultaneous queue depth observed.
    pub peak_depth: usize,
}

/// The pure bounded priority queue. Not thread-safe — see [`SharedQueue`].
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    lanes: [VecDeque<Pending>; Priority::LEVELS],
    closed: bool,
    stats: QueueStats,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "queue capacity must be positive");
        Self {
            capacity,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            closed: false,
            stats: QueueStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }

    /// Per-priority-lane depths, indexed by [`Priority::index`] (high,
    /// normal, low) — the `queue_lanes` field of the `stats` control
    /// frame (PROTOCOL.md §6).
    pub fn lane_depths(&self) -> [usize; Priority::LEVELS] {
        let mut out = [0usize; Priority::LEVELS];
        for (slot, lane) in out.iter_mut().zip(self.lanes.iter()) {
            *slot = lane.len();
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(VecDeque::is_empty)
    }

    /// Stop admitting; queued jobs still drain.
    pub fn close(&mut self) {
        self.closed = true;
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    pub(crate) fn count_shed_full(&mut self) {
        self.stats.shed_full += 1;
    }

    /// Admit one job, or hand it back if the queue is full/closed.
    pub fn try_admit(&mut self, req: FitRequest) -> Admission {
        if self.closed {
            return Admission::Closed(req);
        }
        if self.len() >= self.capacity {
            return Admission::Full(req);
        }
        let lane = req.priority.index();
        self.lanes[lane].push_back(Pending { req, admitted_at: Instant::now() });
        let depth = self.len();
        if depth > self.stats.peak_depth {
            self.stats.peak_depth = depth;
        }
        Admission::Admitted
    }

    /// Remove a queued job by its (session-rewritten) id — the queue side
    /// of per-request cancellation (PROTOCOL.md §6 `cancel`). Returns the
    /// removed entry, or `None` when no queued job carries that id (it
    /// already popped, or never existed). Ids are session tickets, so at
    /// most one queued job can match.
    pub fn remove(&mut self, id: u64) -> Option<Pending> {
        for lane in self.lanes.iter_mut() {
            if let Some(i) = lane.iter().position(|p| p.req.id == id) {
                return lane.remove(i);
            }
        }
        None
    }

    /// Pop the oldest highest-priority live job plus up to `max_batch - 1`
    /// queued jobs sharing its [`BatchKey`], scanned in pop order (so a
    /// high-priority head coalesces compatible lower-priority riders —
    /// they get a free upgrade, never the reverse). Jobs whose key is
    /// unknown (file datasets) or unbatchable (fpga-sim) always pop solo.
    /// Expired jobs encountered during the scan are removed and returned
    /// in `shed`.
    pub fn pop_batch(&mut self, max_batch: usize) -> PopOutcome {
        assert!(max_batch >= 1, "max_batch must be positive");
        let mut out = PopOutcome::default();
        let mut shed_deadline = 0u64;
        let mut key: Option<BatchKey> = None;
        'lanes: for lane in self.lanes.iter_mut() {
            let mut i = 0;
            while i < lane.len() {
                if out.batch.len() >= max_batch {
                    break 'lanes;
                }
                if lane[i].expired() {
                    out.shed.push(lane.remove(i).expect("index checked"));
                    shed_deadline += 1;
                    continue; // `i` now addresses the next element
                }
                if out.batch.is_empty() {
                    let head = lane.remove(i).expect("index checked");
                    key = BatchKey::of(&head.req);
                    out.batch.push(head);
                    if key.is_none() || max_batch == 1 {
                        break 'lanes; // unbatchable head pops solo
                    }
                    continue;
                }
                if BatchKey::of(&lane[i].req) == key {
                    out.batch.push(lane.remove(i).expect("index checked"));
                    continue;
                }
                i += 1;
            }
        }
        self.stats.shed_deadline += shed_deadline;
        out
    }
}

/// Outcome of a [`SharedQueue::submit`].
#[derive(Debug)]
pub enum Submission {
    Admitted,
    /// Rejected; the reason is queue-full (ShedArrivals) or queue-closed.
    Shed { req: FitRequest, reason: &'static str },
}

/// Thread-safe wrapper: the admission side of the serve subsystem.
#[derive(Debug)]
pub struct SharedQueue {
    inner: Mutex<AdmissionQueue>,
    /// Signalled when a slot frees (wakes blocked submitters).
    space: Condvar,
    /// Signalled when work arrives or the queue closes (wakes workers).
    work: Condvar,
}

impl SharedQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(AdmissionQueue::new(capacity)),
            space: Condvar::new(),
            work: Condvar::new(),
        }
    }

    /// Submit one job under the given policy. Blocks only under
    /// [`ShedPolicy::Block`] with a full queue.
    pub fn submit(&self, req: FitRequest, policy: ShedPolicy) -> Submission {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        let mut req = req;
        loop {
            match q.try_admit(req) {
                Admission::Admitted => {
                    self.work.notify_one();
                    return Submission::Admitted;
                }
                Admission::Closed(r) => {
                    return Submission::Shed { req: r, reason: "queue closed" };
                }
                Admission::Full(r) => match policy {
                    ShedPolicy::ShedArrivals => {
                        q.count_shed_full();
                        return Submission::Shed { req: r, reason: "queue full" };
                    }
                    ShedPolicy::Block => {
                        req = r;
                        q = self.space.wait(q).expect("queue mutex poisoned");
                    }
                },
            }
        }
    }

    /// Take the next micro-batch, blocking while the queue is empty.
    /// Returns `None` once the queue is closed *and* drained — the worker
    /// shutdown signal.
    pub fn take_batch(&self, max_batch: usize) -> Option<PopOutcome> {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        loop {
            if !q.is_empty() {
                let out = q.pop_batch(max_batch);
                self.space.notify_all();
                return Some(out);
            }
            if q.is_closed() {
                return None;
            }
            q = self.work.wait(q).expect("queue mutex poisoned");
        }
    }

    /// Remove a queued job by id (see [`AdmissionQueue::remove`]); a
    /// successful removal frees a slot, so blocked submitters are woken.
    pub fn remove(&self, id: u64) -> Option<Pending> {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        let removed = q.remove(id);
        if removed.is_some() {
            self.space.notify_all();
        }
        removed
    }

    /// Jobs currently queued (admitted, not yet popped) — the live
    /// `queue_depth` the `stats` control frame reports (PROTOCOL.md §6).
    pub fn depth(&self) -> usize {
        self.inner.lock().expect("queue mutex poisoned").len()
    }

    /// Per-priority-lane depths (high, normal, low) — see
    /// [`AdmissionQueue::lane_depths`].
    pub fn lane_depths(&self) -> [usize; Priority::LEVELS] {
        self.inner.lock().expect("queue mutex poisoned").lane_depths()
    }

    /// Close the queue and wake everyone (submitters shed, workers drain
    /// and exit).
    pub fn close(&self) {
        let mut q = self.inner.lock().expect("queue mutex poisoned");
        q.close();
        self.work.notify_all();
        self.space.notify_all();
    }

    pub fn stats(&self) -> QueueStats {
        self.inner.lock().expect("queue mutex poisoned").stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, priority: Priority) -> FitRequest {
        FitRequest { id, priority, ..Default::default() }
    }

    #[test]
    fn capacity_is_enforced() {
        let mut q = AdmissionQueue::new(2);
        assert!(matches!(q.try_admit(req(1, Priority::Normal)), Admission::Admitted));
        assert!(matches!(q.try_admit(req(2, Priority::Normal)), Admission::Admitted));
        match q.try_admit(req(3, Priority::Normal)) {
            Admission::Full(r) => assert_eq!(r.id, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.stats().peak_depth, 2);
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Low));
        q.try_admit(req(2, Priority::Normal));
        q.try_admit(req(3, Priority::High));
        q.try_admit(req(4, Priority::High));
        assert_eq!(q.lane_depths(), [2, 1, 1], "high, normal, low");
        let order: Vec<u64> = (0..4)
            .map(|_| q.pop_batch(1).batch.remove(0).req.id)
            .collect();
        assert_eq!(order, vec![3, 4, 2, 1]);
        assert_eq!(q.lane_depths(), [0, 0, 0]);
    }

    #[test]
    fn coalesces_compatible_jobs_up_to_max_batch() {
        let mut q = AdmissionQueue::new(8);
        for id in 1..=5 {
            q.try_admit(req(id, Priority::Normal)); // all blobs/native: same key
        }
        let out = q.pop_batch(3);
        assert_eq!(out.batch.len(), 3);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn incompatible_jobs_do_not_ride_along() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Normal)); // blobs (d=16)
        let mut kegg = req(2, Priority::Normal);
        kegg.dataset = "kegg".into(); // d=20 — different key
        q.try_admit(kegg);
        q.try_admit(req(3, Priority::Normal));
        let out = q.pop_batch(8);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![1, 3],
            "the d=20 job must be skipped, not coalesced"
        );
        assert_eq!(q.pop_batch(8).batch[0].req.id, 2);
    }

    #[test]
    fn fpga_sim_jobs_pop_solo() {
        let mut q = AdmissionQueue::new(8);
        let mut sim = req(1, Priority::Normal);
        sim.backend_name = "fpga-sim".into();
        q.try_admit(sim);
        q.try_admit(req(2, Priority::Normal));
        let out = q.pop_batch(8);
        assert_eq!(out.batch.len(), 1);
        assert_eq!(out.batch[0].req.id, 1);
    }

    #[test]
    fn high_priority_head_coalesces_lower_priority_riders() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Low));
        q.try_admit(req(2, Priority::High));
        let out = q.pop_batch(4);
        assert_eq!(
            out.batch.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![2, 1],
            "the high-priority job leads; the low-priority one rides"
        );
    }

    #[test]
    fn expired_jobs_are_shed_at_pop() {
        let mut q = AdmissionQueue::new(8);
        let mut dead = req(1, Priority::High);
        dead.deadline_ms = Some(0); // expires immediately on admission
        q.try_admit(dead);
        q.try_admit(req(2, Priority::Normal));
        let out = q.pop_batch(4);
        assert_eq!(out.shed.len(), 1);
        assert_eq!(out.shed[0].req.id, 1);
        assert_eq!(out.batch.len(), 1);
        assert_eq!(out.batch[0].req.id, 2);
        assert_eq!(q.stats().shed_deadline, 1);
    }

    #[test]
    fn remove_by_id_pulls_a_queued_job_and_only_that_job() {
        let mut q = AdmissionQueue::new(8);
        q.try_admit(req(1, Priority::Normal));
        q.try_admit(req(2, Priority::High));
        q.try_admit(req(3, Priority::Low));
        let removed = q.remove(2).expect("id 2 is queued");
        assert_eq!(removed.req.id, 2);
        assert_eq!(q.len(), 2);
        assert!(q.remove(2).is_none(), "a second remove finds nothing");
        assert!(q.remove(99).is_none(), "unknown ids find nothing");
        // The survivors still pop in priority/FIFO order.
        assert_eq!(q.pop_batch(1).batch[0].req.id, 1);
        assert_eq!(q.pop_batch(1).batch[0].req.id, 3);
    }

    #[test]
    fn shared_queue_remove_and_depth() {
        let q = SharedQueue::new(4);
        assert_eq!(q.depth(), 0);
        q.submit(req(7, Priority::Normal), ShedPolicy::Block);
        q.submit(req(8, Priority::Normal), ShedPolicy::Block);
        assert_eq!(q.depth(), 2);
        assert_eq!(q.remove(7).unwrap().req.id, 7);
        assert_eq!(q.depth(), 1);
        assert!(q.remove(7).is_none());
    }

    #[test]
    fn closed_queue_rejects_and_reports() {
        let mut q = AdmissionQueue::new(2);
        q.try_admit(req(1, Priority::Normal));
        q.close();
        assert!(matches!(q.try_admit(req(2, Priority::Normal)), Admission::Closed(_)));
        assert!(q.is_closed());
        assert_eq!(q.len(), 1, "queued work still drains after close");
    }

    #[test]
    fn shared_queue_hands_work_across_threads() {
        let q = SharedQueue::new(4);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for id in 1..=3 {
                    assert!(matches!(
                        q.submit(req(id, Priority::Normal), ShedPolicy::Block),
                        Submission::Admitted
                    ));
                }
                q.close();
            });
            let mut seen = Vec::new();
            while let Some(out) = q.take_batch(1) {
                for p in out.batch {
                    seen.push(p.req.id);
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, vec![1, 2, 3]);
        });
    }

    #[test]
    fn shed_arrivals_policy_rejects_when_full() {
        let q = SharedQueue::new(1);
        assert!(matches!(
            q.submit(req(1, Priority::Normal), ShedPolicy::ShedArrivals),
            Submission::Admitted
        ));
        match q.submit(req(2, Priority::Normal), ShedPolicy::ShedArrivals) {
            Submission::Shed { req, reason } => {
                assert_eq!(req.id, 2);
                assert_eq!(reason, "queue full");
            }
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(q.stats().shed_full, 1);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [ShedPolicy::Block, ShedPolicy::ShedArrivals] {
            assert_eq!(ShedPolicy::from_name(p.name()).unwrap(), p);
        }
        assert!(ShedPolicy::from_name("drop").is_err());
    }
}
