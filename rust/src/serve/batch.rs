//! Micro-batch coalescing: compatible requests share one engine dispatch.
//!
//! Two requests are *compatible* when they target the same engine backend
//! and their datasets have the same dimensionality ([`BatchKey`]) — the
//! two properties that decide which AOT kernel variant (and therefore
//! which padded tile geometry) a dispatch compiles against. The queue
//! coalesces compatible jobs at pop time; [`fit_lockstep`] then drives
//! their [`FitState`]s iteration-by-iteration, collecting every state's
//! survivor tile into **one** [`Engine::assign_batch`] call per round.
//!
//! Exactness: `assign_batch` guarantees group-by-group numerics identical
//! to solo `assign_tile` calls, and `FitState` guarantees the stepwise
//! trajectory equals the monolithic loop — so a batched fit is
//! bit-identical to the same request served alone (asserted by
//! `rust/tests/serve_integration.rs`). Batching changes *when* work runs,
//! never *what* it computes.

use crate::coordinator::driver::{Dispatch, FitState};
use crate::coordinator::SystemOutput;
use crate::data::{synth, Dataset};
use crate::error::Result;
use crate::kmeans::KMeansConfig;
use crate::runtime::Engine;
use crate::util::matrix::Matrix;

use super::job::FitRequest;

/// Which execution backend a request targets (the serve-side mirror of
/// `coordinator::Backend`, comparable and hashable for batching).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    FpgaSim,
    Native,
    Xla,
}

impl BackendKind {
    pub fn from_name(name: &str) -> Option<BackendKind> {
        match name {
            "fpga-sim" => Some(BackendKind::FpgaSim),
            "native" => Some(BackendKind::Native),
            "xla" => Some(BackendKind::Xla),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::FpgaSim => "fpga-sim",
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Batching compatibility key: same `d`, same backend — and, for the XLA
/// backend, the same artifact directory (different artifact dirs mean
/// different compiled programs; coalescing across them would execute a
/// tenant against kernels it never asked for).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub d: usize,
    pub backend: BackendKind,
    /// `Some` only for xla jobs (the engine is per-artifact-dir).
    pub artifact_dir: Option<String>,
}

impl BatchKey {
    /// The request's key, when it is batchable at all: engine backends
    /// with a generator dataset (whose `d` is known without materialising
    /// anything). `None` marks a job that must run solo — fpga-sim (its
    /// whole iteration structure lives inside the cycle simulator), file
    /// datasets (unknown `d` until loaded), and explicit-`algorithm`
    /// requests (a pinned kernel variant runs its own iteration loop, not
    /// the lockstep engine loop).
    pub fn of(req: &FitRequest) -> Option<BatchKey> {
        let backend = BackendKind::from_name(&req.backend_name)?;
        if backend == BackendKind::FpgaSim || !req.algorithm.is_empty() {
            return None;
        }
        let d = dataset_dim(&req.dataset)?;
        let artifact_dir =
            (backend == BackendKind::Xla).then(|| req.artifact_dir.clone());
        Some(BatchKey { d, backend, artifact_dir })
    }
}

/// Dimensionality of a named generator dataset; `None` for file paths.
pub fn dataset_dim(name: &str) -> Option<usize> {
    if name == "blobs" || name == "uniform" {
        return Some(crate::config::SYNTH_DEFAULT_DIM);
    }
    synth::uci_specs().into_iter().find(|s| s.name == name).map(|s| s.d)
}

/// Run several jobs to completion in lockstep on one engine: each round
/// advances every unfinished fit by one iteration, and all their dispatches
/// cross the engine boundary as a single [`Engine::assign_batch`] call.
/// Jobs converge independently and drop out of the round as they finish.
pub fn fit_lockstep(
    engine: &mut dyn Engine,
    backend_name: &str,
    jobs: &[(&Dataset, &KMeansConfig)],
) -> Result<Vec<SystemOutput>> {
    let mut states = jobs
        .iter()
        .map(|&(ds, kcfg)| FitState::new(ds, kcfg))
        .collect::<Result<Vec<_>>>()?;
    loop {
        let live: Vec<usize> = (0..states.len()).filter(|&i| !states[i].done()).collect();
        if live.is_empty() {
            break;
        }
        let mut disps: Vec<(usize, Dispatch)> = Vec::with_capacity(live.len());
        for &i in &live {
            disps.push((i, states[i].begin_iteration()));
        }
        // One engine crossing for the whole round.
        let mut groups: Vec<(&Matrix, &Matrix)> = Vec::new();
        for (i, d) in &disps {
            match d {
                Dispatch::Dense => groups.push((states[*i].points(), states[*i].centroids())),
                Dispatch::Survivors(pts) => groups.push((pts, states[*i].centroids())),
                Dispatch::Skip => {}
            }
        }
        let outs = if groups.is_empty() { Vec::new() } else { engine.assign_batch(&groups)? };
        drop(groups);
        let mut next_out = outs.iter();
        for (i, d) in &disps {
            let out = match d {
                Dispatch::Skip => None,
                _ => next_out.next(),
            };
            states[*i].complete_iteration(out)?;
        }
    }
    Ok(states.into_iter().map(|s| s.finish(backend_name)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::run_with_engine;
    use crate::data::synth;
    use crate::runtime::native::NativeEngine;

    #[test]
    fn generator_dims_are_known() {
        assert_eq!(dataset_dim("blobs"), Some(16));
        assert_eq!(dataset_dim("uniform"), Some(16));
        assert_eq!(dataset_dim("kegg"), Some(20));
        assert_eq!(dataset_dim("gassensor"), Some(128));
        assert_eq!(dataset_dim("data/points.csv"), None);
    }

    #[test]
    fn batch_key_separates_backend_and_dim() {
        let blobs = FitRequest::default();
        let key = BatchKey::of(&blobs).unwrap();
        assert_eq!(
            key,
            BatchKey { d: 16, backend: BackendKind::Native, artifact_dir: None }
        );

        let mut kegg = FitRequest::default();
        kegg.dataset = "kegg".into();
        assert_ne!(BatchKey::of(&kegg).unwrap(), key);

        let mut sim = FitRequest::default();
        sim.backend_name = "fpga-sim".into();
        assert_eq!(BatchKey::of(&sim), None);

        let mut file = FitRequest::default();
        file.dataset = "points.csv".into();
        assert_eq!(BatchKey::of(&file), None);

        let mut pinned = FitRequest::default();
        pinned.algorithm = "yinyang".into();
        assert_eq!(BatchKey::of(&pinned), None, "pinned kernels run solo");
    }

    #[test]
    fn xla_keys_separate_artifact_dirs() {
        let mut a = FitRequest::default();
        a.backend_name = "xla".into();
        let mut b = a.clone();
        b.artifact_dir = "other-artifacts".into();
        let (ka, kb) = (BatchKey::of(&a).unwrap(), BatchKey::of(&b).unwrap());
        assert_eq!(ka.artifact_dir.as_deref(), Some("artifacts"));
        assert_ne!(ka, kb, "different compiled programs must not coalesce");
        // Same dir → compatible again.
        let c = a.clone();
        assert_eq!(BatchKey::of(&c).unwrap(), ka);
    }

    #[test]
    fn lockstep_batch_is_bit_identical_to_solo_runs() {
        // Three jobs, same d, different k / seeds / sizes — they converge
        // at different iterations, exercising the drop-out path.
        let a = synth::blobs(900, 12, 4, 1);
        let b = synth::blobs(600, 12, 3, 2);
        let c = synth::blobs(1200, 12, 6, 3);
        let ka = KMeansConfig { k: 4, seed: 11, ..Default::default() };
        let kb = KMeansConfig { k: 3, seed: 22, ..Default::default() };
        let kc = KMeansConfig { k: 6, seed: 33, max_iters: 7, ..Default::default() };

        let solo: Vec<_> = [(&a, &ka), (&b, &kb), (&c, &kc)]
            .iter()
            .map(|&(ds, kcfg)| run_with_engine(&mut NativeEngine, ds, kcfg).unwrap())
            .collect();

        let batched = fit_lockstep(
            &mut NativeEngine,
            "native",
            &[(&a, &ka), (&b, &kb), (&c, &kc)],
        )
        .unwrap();

        assert_eq!(batched.len(), 3);
        for (s, g) in solo.iter().zip(&batched) {
            assert_eq!(s.fit.assignments, g.fit.assignments);
            assert_eq!(s.fit.centroids, g.fit.centroids);
            assert_eq!(s.fit.iterations, g.fit.iterations);
            assert_eq!(s.fit.inertia, g.fit.inertia);
            assert_eq!(s.report.tiles_dispatched, g.report.tiles_dispatched);
            assert_eq!(s.report.points_rescanned, g.report.points_rescanned);
        }
    }

    #[test]
    fn lockstep_of_one_job_degenerates_to_solo() {
        let ds = synth::blobs(500, 8, 3, 9);
        let kcfg = KMeansConfig { k: 3, seed: 4, ..Default::default() };
        let solo = run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap();
        let batched = fit_lockstep(&mut NativeEngine, "native", &[(&ds, &kcfg)]).unwrap();
        assert_eq!(solo.fit.assignments, batched[0].fit.assignments);
        assert_eq!(solo.fit.iterations, batched[0].fit.iterations);
    }

    #[test]
    fn lockstep_of_nothing_is_empty() {
        let out = fit_lockstep(&mut NativeEngine, "native", &[]).unwrap();
        assert!(out.is_empty());
    }
}
