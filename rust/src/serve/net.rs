//! The persistent socket front-end: `kpynq serve --listen`.
//!
//! PR 2's `kpynq serve` was a batch filter — drain stdin, answer, exit —
//! so every client paid engine construction (and, on the XLA path, AOT
//! compilation) per invocation. [`Daemon`] keeps one [`ServeSession`]
//! alive behind a listener instead: concurrent TCP (and, on Unix,
//! `unix:<path>` Unix-domain) connections all multiplex into the same
//! admission queue and the same per-worker engine banks, so warm engines
//! finally span *clients*, not just the requests of one stream.
//!
//! The wire format is the NDJSON job model `serve::job` already speaks —
//! one `FitRequest` object per line in, one response line per job out —
//! prefixed by a single server greeting line and with a handful of
//! control frames (`ping`, `stats`, `cancel`, `bye`, `shutdown`). The
//! protocol is specified normatively in PROTOCOL.md; this module
//! implements it and cites it rather than restating it. The line framing
//! itself is shared with the client side in [`super::codec`]. Connection
//! lifecycle and backpressure contracts live in DESIGN.md §2.
//!
//! The accept loop and per-connection protocol machinery are generic
//! over a [`FrontCore`] — the thing that actually answers jobs. The
//! local [`ServeSession`] is one core (`kpynq serve --listen`); the
//! cross-process fan-out front in [`crate::cluster`] is another
//! (`kpynq cluster`), so both fronts present one identical wire surface.
//!
//! Malformed lines never kill a connection, let alone the daemon: every
//! frame the server cannot accept is answered with a structured error
//! reply (PROTOCOL.md §5) and the session keeps reading. A client that
//! disconnects mid-stream forfeits its undelivered responses (counted in
//! the report) but leaves the pool untouched.
//!
//! ```no_run
//! use kpynq::serve::net::{Daemon, NetConfig};
//! use kpynq::serve::ServeConfig;
//!
//! let daemon = Daemon::bind("127.0.0.1:7071", NetConfig::default(),
//!                           ServeConfig::default()).unwrap();
//! println!("listening on {}", daemon.local_addr());
//! let report = daemon.run().unwrap(); // blocks until {"op":"shutdown"}
//! println!("{}", report.render());
//! ```

use std::collections::{BTreeMap, HashMap};
use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::json::Json;

use super::codec::{write_line, LineEvent, LineReader, WireStream};
use super::job::{FitRequest, FitResponse};
use super::session::{PartialSession, ServeSession};
use super::{ServeConfig, ServeReport};

pub use super::codec::MAX_LINE_BYTES;

/// Wire protocol revision this build speaks (PROTOCOL.md §1).
pub const PROTO_VERSION: u64 = 1;

/// Read-timeout tick: how often a blocked connection reader wakes to check
/// the shutdown flag and its idle budget.
const READ_TICK: Duration = Duration::from_millis(50);
/// Accept-poll tick for the (non-blocking) listener loop.
const ACCEPT_TICK: Duration = Duration::from_millis(20);
/// Writer-side timeout: a client that stops reading for this long has its
/// responses dropped instead of wedging a worker-fed writer thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// What the connection layer needs from whatever answers the jobs behind
/// it. [`ServeSession`] is the in-process core (`kpynq serve --listen`);
/// `cluster::front` implements it over N child daemons (`kpynq cluster`).
/// Everything protocol-visible — framing, greeting, control frames, error
/// replies — lives in the connection layer, so every core serves one
/// identical wire surface (PROTOCOL.md).
pub trait FrontCore: Send + Sync + 'static {
    /// Submit one job; the single reply arrives on `reply` with the
    /// request's own id restored. Returns the core-unique ticket the
    /// job runs under (the handle [`FrontCore::cancel`] takes).
    fn submit(&self, req: FitRequest, reply: &mpsc::Sender<FitResponse>) -> u64;

    /// Try to cancel a submitted job by ticket (PROTOCOL.md §6): `true`
    /// when the job was still queued and was removed — its single reply
    /// then arrives as `status:"shed"` / `detail:"cancelled by client"`.
    /// `false` when it already started, finished, or is unknown (its
    /// normal reply, if any is still owed, arrives unchanged).
    fn cancel(&self, ticket: u64) -> bool;

    /// Core-specific greeting keys (PROTOCOL.md §2), added on top of the
    /// common ones (`kpynq`, `proto`, `version`, `max_line_bytes`).
    fn greeting_fields(&self, m: &mut BTreeMap<String, Json>);

    /// Core-specific `stats` reply keys (PROTOCOL.md §6), added on top of
    /// the connection-level ones (`connections`, `active_conns`,
    /// `pending_here`).
    fn stats_fields(&self, m: &mut BTreeMap<String, Json>);

    /// Drain the core's trace span ring into the `{"op":"trace"}` reply
    /// shape (PROTOCOL.md §11). Destructive: each span is delivered to
    /// exactly one drainer.
    fn drain_trace(&self) -> Json;

    /// Non-destructive snapshot of the trace span ring — the
    /// `{"op":"trace","peek":true}` form (PROTOCOL.md §11). Dashboards
    /// poll with this so they never race a log shipper's drain.
    fn peek_trace(&self) -> Json;

    /// Snapshot the core's metrics registry (`obs::metrics`) — the body
    /// of the `{"op":"metrics"}` reply (PROTOCOL.md §6), and the source
    /// the `GET /metrics` Prometheus endpoint renders.
    fn metrics(&self) -> Json;

    /// Handle the `{"op":"cache"}` control frame (PROTOCOL.md §6): report
    /// the result cache's size/capacity, clearing it first when `clear`
    /// is set. Both cores own a fingerprint-keyed result cache
    /// (PROTOCOL.md §8), so the frame is part of the shared wire surface.
    fn cache_control(&self, clear: bool) -> Json;
}

impl FrontCore for ServeSession {
    fn submit(&self, req: FitRequest, reply: &mpsc::Sender<FitResponse>) -> u64 {
        ServeSession::submit(self, req, reply)
    }

    fn cancel(&self, ticket: u64) -> bool {
        ServeSession::cancel(self, ticket)
    }

    fn greeting_fields(&self, m: &mut BTreeMap<String, Json>) {
        let cfg = self.config();
        m.insert("workers".to_string(), Json::Num(cfg.workers as f64));
        m.insert("max_batch".to_string(), Json::Num(cfg.max_batch as f64));
        m.insert("backends".to_string(), Json::Arr(advertised_backends()));
    }

    fn stats_fields(&self, m: &mut BTreeMap<String, Json>) {
        let q = self.queue_stats();
        m.insert("submitted".to_string(), Json::Num(self.submitted() as f64));
        m.insert("queue_depth".to_string(), Json::Num(self.queue_depth() as f64));
        m.insert("shed_full".to_string(), Json::Num(q.shed_full as f64));
        m.insert("shed_deadline".to_string(), Json::Num(q.shed_deadline as f64));
        m.insert("peak_queue_depth".to_string(), Json::Num(q.peak_depth as f64));
        m.insert("uptime_ms".to_string(), Json::Num(self.uptime_ms() as f64));
        m.insert(
            "queue_lanes".to_string(),
            Json::Arr(self.lane_depths().iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        m.insert("tenants".to_string(), self.tenants_json());
    }

    fn drain_trace(&self) -> Json {
        ServeSession::drain_trace(self)
    }

    fn peek_trace(&self) -> Json {
        ServeSession::peek_trace(self)
    }

    fn metrics(&self) -> Json {
        ServeSession::metrics(self)
    }

    fn cache_control(&self, clear: bool) -> Json {
        ServeSession::cache_control(self, clear)
    }
}

/// Only backends this *build* can actually execute (PROTOCOL.md §2):
/// without the `xla` cargo feature the engine is a stub whose
/// construction errors, so advertising it would invite guaranteed-to-
/// fail jobs.
pub(crate) fn advertised_backends() -> Vec<Json> {
    let mut backends = vec![Json::Str("fpga-sim".into()), Json::Str("native".into())];
    if cfg!(feature = "xla") {
        backends.push(Json::Str("xla".into()));
    }
    backends
}

/// Listener configuration (the `[serve.net]` config section).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Simultaneous-connection cap; extras are refused with an error line.
    pub max_conns: usize,
    /// Close a connection that has sent no traffic and has no pending
    /// responses for this many milliseconds. 0 disables the idle timeout.
    pub idle_timeout_ms: u64,
    /// Append drained trace spans (PROTOCOL.md §11) to this file as JSONL
    /// (`kpynq serve --trace-log <path>`): every `{"op":"trace"}` drain is
    /// teed here, plus one final drain at shutdown.
    pub trace_log: Option<String>,
    /// Also serve `GET /metrics` (Prometheus text 0.0.4) over plain HTTP
    /// on this `host:port` (`kpynq serve --metrics-listen <addr>`). The
    /// scrape endpoint is read-only and separate from the NDJSON listener
    /// so scrapers never consume a job-connection slot (PROTOCOL.md §11).
    pub metrics_listen: Option<String>,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self { max_conns: 32, idle_timeout_ms: 0, trace_log: None, metrics_listen: None }
    }
}

impl NetConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_conns == 0 {
            return Err(Error::Config("serve.net max_conns must be positive".into()));
        }
        Ok(())
    }
}

/// A bound listener: TCP (`host:port`) or, on Unix, `unix:<path>`.
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener, std::path::PathBuf),
}

/// One accept-poll outcome.
enum Accepted {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
    Pending,
}

impl Listener {
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(true),
        }
    }

    fn poll_accept(&self) -> io::Result<Accepted> {
        let accepted = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Accepted::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Accepted::Unix(s)),
        };
        match accepted {
            Ok(a) => Ok(a),
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted) =>
            {
                Ok(Accepted::Pending)
            }
            Err(e) => Err(e),
        }
    }
}

/// Daemon-wide connection counters, folded into the final [`ServeReport`].
#[derive(Debug, Default)]
struct NetCounters {
    accepted: AtomicU64,
    active: AtomicUsize,
    peak: AtomicUsize,
    refused: AtomicU64,
    protocol_errors: AtomicU64,
}

/// Everything a connection handler needs a handle on.
struct ConnCtx {
    core: Arc<dyn FrontCore>,
    counters: Arc<NetCounters>,
    shutdown: Arc<AtomicBool>,
    net: NetConfig,
    /// The open `--trace-log` sink, shared by every connection (trace
    /// drains tee into it) and the shutdown path (final drain).
    trace_sink: Option<Arc<Mutex<std::fs::File>>>,
}

/// A bound-but-not-yet-running daemon. [`Daemon::run`] drives the accept
/// loop to completion: it returns after a graceful drain — triggered by a
/// client's `{"op":"shutdown"}` frame (PROTOCOL.md §6) or by
/// [`DaemonHandle::shutdown`] — with the session's [`ServeReport`].
pub struct Daemon {
    listener: Listener,
    net: NetConfig,
    serve: ServeConfig,
    shutdown: Arc<AtomicBool>,
    /// Bound eagerly in [`Daemon::bind`] (so `--metrics-listen 127.0.0.1:0`
    /// has a readable port before `run`), served by a sidecar thread in
    /// `run_with`.
    metrics_listener: Option<TcpListener>,
}

/// A cloneable remote control for a running daemon (the embedding test /
/// bench equivalent of the on-wire `shutdown` frame).
#[derive(Clone)]
pub struct DaemonHandle {
    shutdown: Arc<AtomicBool>,
}

impl DaemonHandle {
    /// Begin a graceful drain: stop accepting, let connections finish
    /// their pending replies, then shut the session down.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

impl Daemon {
    /// Bind the listener (`host:port`, or `unix:<path>` on Unix) and
    /// validate both configs. Port 0 binds an ephemeral port — read it
    /// back with [`Daemon::local_addr`]. A stale Unix socket *file* left
    /// by a dead daemon is removed before binding; any other file type at
    /// that path makes the bind fail rather than be deleted.
    pub fn bind(addr: &str, net: NetConfig, serve: ServeConfig) -> Result<Daemon> {
        net.validate()?;
        serve.validate()?;
        let listener = match addr.strip_prefix("unix:") {
            Some(path) => bind_unix(path)?,
            None => Listener::Tcp(TcpListener::bind(addr).map_err(|e| {
                Error::Config(format!("cannot listen on '{addr}': {e}"))
            })?),
        };
        let metrics_listener = match &net.metrics_listen {
            Some(maddr) => Some(TcpListener::bind(maddr).map_err(|e| {
                Error::Config(format!("cannot serve metrics on '{maddr}': {e}"))
            })?),
            None => None,
        };
        Ok(Daemon {
            listener,
            net,
            serve,
            shutdown: Arc::new(AtomicBool::new(false)),
            metrics_listener,
        })
    }

    /// The bound `GET /metrics` scrape address, when `metrics_listen` was
    /// configured (PROTOCOL.md §11).
    pub fn metrics_addr(&self) -> Option<String> {
        self.metrics_listener
            .as_ref()
            .and_then(|l| l.local_addr().ok())
            .map(|a| a.to_string())
    }

    /// The bound address, in the same notation `bind` accepts.
    pub fn local_addr(&self) -> String {
        match &self.listener {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".into()),
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }

    /// A handle that can trigger a graceful drain from another thread.
    pub fn handle(&self) -> DaemonHandle {
        DaemonHandle { shutdown: Arc::clone(&self.shutdown) }
    }

    /// The pool shape this daemon will serve with.
    pub fn serve_config(&self) -> &ServeConfig {
        &self.serve
    }

    /// Serve until shutdown with a local [`ServeSession`] as the core:
    /// accept connections (refusing extras beyond `max_conns`), multiplex
    /// them all into the shared session, and on the shutdown signal stop
    /// accepting, join every connection (each drains its pending replies
    /// first), drain the pool and return the session report with the
    /// connection counters folded in.
    pub fn run(self) -> Result<ServeReport> {
        let session = Arc::new(ServeSession::start(self.serve.clone())?);
        let fin = Arc::clone(&session);
        self.run_with(session, move || {
            Ok(Arc::into_inner(fin).expect("all connections joined").shutdown())
        })
    }

    /// The generalized serve loop: accept until shutdown against an
    /// arbitrary [`FrontCore`], then call `finish` (which must consume
    /// the caller's remaining core handles and produce the report). The
    /// connection counters are folded into whatever report `finish`
    /// returns.
    pub(crate) fn run_with(
        self,
        core: Arc<dyn FrontCore>,
        finish: impl FnOnce() -> Result<ServeReport>,
    ) -> Result<ServeReport> {
        let Daemon { listener, net, serve: _, shutdown, metrics_listener } = self;
        let counters = Arc::new(NetCounters::default());
        // The Prometheus scrape sidecar (PROTOCOL.md §11): its own
        // listener and thread, so scrapers never consume an NDJSON
        // connection slot and a wedged scraper cannot wedge serving.
        let metrics_thread = metrics_listener.map(|l| {
            let core = Arc::clone(&core);
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || serve_metrics_http(&l, &*core, &shutdown))
        });
        let trace_sink = match &net.trace_log {
            Some(path) => Some(Arc::new(Mutex::new(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(path)
                    .map_err(|e| Error::Config(format!("cannot open trace log '{path}': {e}")))?,
            ))),
            None => None,
        };
        listener.set_nonblocking()?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();

        while !shutdown.load(Ordering::SeqCst) {
            match listener.poll_accept() {
                // Transient accept failures — ECONNABORTED from a client
                // that reset mid-handshake, EMFILE under fd pressure —
                // must not kill a daemon holding live connections; back
                // off one tick and keep serving.
                Err(_) | Ok(Accepted::Pending) => std::thread::sleep(ACCEPT_TICK),
                Ok(Accepted::Tcp(stream)) => {
                    let _ = stream.set_nodelay(true);
                    if let Some(h) =
                        spawn_conn(stream, &core, &counters, &shutdown, &net, &trace_sink)
                    {
                        conns.push(h);
                    }
                }
                #[cfg(unix)]
                Ok(Accepted::Unix(stream)) => {
                    if let Some(h) =
                        spawn_conn(stream, &core, &counters, &shutdown, &net, &trace_sink)
                    {
                        conns.push(h);
                    }
                }
            }
            // Bound the handle list on long uptimes; finished threads are
            // already joined-equivalent (dropping a finished handle is
            // detach-after-exit).
            if conns.len() > 64 {
                conns.retain(|h| !h.is_finished());
            }
        }

        for h in conns {
            let _ = h.join();
        }
        match &listener {
            #[cfg(unix)]
            Listener::Unix(_, path) => {
                let _ = std::fs::remove_file(path);
            }
            _ => {}
        }
        drop(listener);
        // Spans nobody drained over the wire still reach the trace log.
        if let Some(sink) = &trace_sink {
            append_trace(sink, &core.drain_trace());
        }
        // The scrape sidecar holds a core clone; it exits on the shutdown
        // flag (set before we got here) within one accept tick.
        if let Some(h) = metrics_thread {
            let _ = h.join();
        }
        drop(core); // `finish` must now hold the only core reference

        let mut report = finish()?;
        report.connections = counters.accepted.load(Ordering::SeqCst);
        report.peak_connections = counters.peak.load(Ordering::SeqCst);
        report.refused_connections = counters.refused.load(Ordering::SeqCst);
        report.protocol_errors = counters.protocol_errors.load(Ordering::SeqCst);
        Ok(report)
    }
}

#[cfg(unix)]
fn bind_unix(path: &str) -> Result<Listener> {
    use std::os::unix::fs::FileTypeExt;
    let path = std::path::PathBuf::from(path);
    // Remove only a stale *socket* at the target path; a regular file or
    // directory there is someone else's data and must fail the bind.
    if let Ok(meta) = std::fs::metadata(&path) {
        if meta.file_type().is_socket() {
            std::fs::remove_file(&path)?;
        }
    }
    let listener = std::os::unix::net::UnixListener::bind(&path)
        .map_err(|e| Error::Config(format!("cannot listen on 'unix:{}': {e}", path.display())))?;
    Ok(Listener::Unix(listener, path))
}

#[cfg(not(unix))]
fn bind_unix(_path: &str) -> Result<Listener> {
    Err(Error::Config("unix-domain listeners are only available on Unix platforms".into()))
}

/// Admit-or-refuse one accepted stream; on admit, spawn its handler.
fn spawn_conn<S: WireStream>(
    stream: S,
    core: &Arc<dyn FrontCore>,
    counters: &Arc<NetCounters>,
    shutdown: &Arc<AtomicBool>,
    net: &NetConfig,
    trace_sink: &Option<Arc<Mutex<std::fs::File>>>,
) -> Option<std::thread::JoinHandle<()>> {
    if counters.active.load(Ordering::SeqCst) >= net.max_conns {
        counters.refused.fetch_add(1, Ordering::SeqCst);
        let mut stream = stream;
        let _ = stream.set_write_timeout_dur(Some(WRITE_TIMEOUT));
        let _ = stream.write_all(
            format!(
                "{}\n",
                error_reply(0, &format!("server at max connections ({})", net.max_conns))
            )
            .as_bytes(),
        );
        stream.shutdown_stream();
        return None;
    }
    counters.accepted.fetch_add(1, Ordering::SeqCst);
    let active = counters.active.fetch_add(1, Ordering::SeqCst) + 1;
    counters.peak.fetch_max(active, Ordering::SeqCst);
    let ctx = ConnCtx {
        core: Arc::clone(core),
        counters: Arc::clone(counters),
        shutdown: Arc::clone(shutdown),
        net: net.clone(),
        trace_sink: trace_sink.clone(),
    };
    Some(std::thread::spawn(move || {
        handle_conn(stream, &ctx);
        ctx.counters.active.fetch_sub(1, Ordering::SeqCst);
    }))
}

/// Per-connection protocol loop. The reader (this thread) parses frames
/// and submits jobs; a paired writer thread serializes routed responses
/// back. Both write whole lines under one lock, so control replies and
/// job responses interleave without tearing. Teardown — EOF, `bye`,
/// idle timeout, read error or daemon shutdown — always drains pending
/// responses before closing (PROTOCOL.md §2).
fn handle_conn<S: WireStream>(stream: S, ctx: &ConnCtx) {
    let _ = stream.set_blocking();
    let _ = stream.set_read_timeout_dur(Some(READ_TICK));
    let writer = match stream.try_clone_stream() {
        Ok(w) => w,
        Err(_) => return,
    };
    let _ = writer.set_write_timeout_dur(Some(WRITE_TIMEOUT));
    let out = Arc::new(Mutex::new(writer));
    let pending = Arc::new(AtomicUsize::new(0));

    let _ = write_line(&out, &greeting(ctx));

    // Client id → core ticket of the most recent submission with that id,
    // so `{"op":"cancel","id":N}` can address jobs in the core's ticket
    // space (PROTOCOL.md §6). The writer prunes an id's entry as its
    // reply is delivered — without that, a long-lived connection (every
    // cluster shard link is one) would grow this map per job forever.
    // Pruning is by client id, not ticket: when several in-flight jobs
    // share an id, an earlier job's reply can drop the newer job's entry
    // (a later cancel then answers `false`) — acceptable for an advisory
    // ack, bounded either way.
    let tickets: Arc<Mutex<HashMap<u64, u64>>> = Arc::new(Mutex::new(HashMap::new()));

    let (resp_tx, resp_rx) = mpsc::channel::<FitResponse>();
    let writer_thread = {
        let out = Arc::clone(&out);
        let pending = Arc::clone(&pending);
        let tickets = Arc::clone(&tickets);
        std::thread::spawn(move || {
            for resp in resp_rx {
                let _ = write_line(&out, &resp.to_json().to_string());
                tickets.lock().expect("ticket map poisoned").remove(&resp.id);
                // Decrement even on write failure: the job is answered as
                // far as the session is concerned, and the reader's drain
                // must not wait on a dead peer.
                pending.fetch_sub(1, Ordering::SeqCst);
            }
        })
    };

    let idle_limit =
        (ctx.net.idle_timeout_ms > 0).then(|| Duration::from_millis(ctx.net.idle_timeout_ms));
    let mut reader = LineReader::new(stream);
    let mut last_activity = Instant::now();
    let mut lineno = 0u64;
    // Map-reduce fit state (PROTOCOL.md §10) is connection-scoped: it
    // lives and dies with this reader, so a dropped shard link implicitly
    // discards its partial fits (the front re-dispatches with history).
    let mut partial = PartialSession::new();
    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            break; // daemon draining: stop reading, deliver what's pending
        }
        match reader.next_event() {
            LineEvent::Line(bytes) => {
                lineno += 1;
                last_activity = Instant::now();
                if !handle_frame(&bytes, lineno, ctx, &out, &resp_tx, &pending, &tickets, &mut partial) {
                    break;
                }
            }
            LineEvent::Oversized => {
                lineno += 1;
                last_activity = Instant::now();
                proto_error(
                    ctx,
                    &out,
                    lineno,
                    &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                );
            }
            LineEvent::Tick => {
                if let Some(limit) = idle_limit {
                    if pending.load(Ordering::SeqCst) == 0 && last_activity.elapsed() >= limit {
                        let mut m = BTreeMap::new();
                        m.insert("op".to_string(), Json::Str("idle-timeout".into()));
                        m.insert("idle_ms".to_string(), Json::Num(ctx.net.idle_timeout_ms as f64));
                        let _ = write_line(&out, &Json::Obj(m).to_string());
                        break;
                    }
                }
            }
            LineEvent::Eof | LineEvent::Error(_) => break,
        }
    }

    // Drain: every submitted job produces exactly one routed response, so
    // `pending` reaches zero once the session has answered them all (the
    // writer decrements even when the peer is gone).
    while pending.load(Ordering::SeqCst) > 0 {
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(resp_tx);
    let _ = writer_thread.join();
    reader.into_inner().shutdown_stream();
}

/// Dispatch one parsed-or-not frame; returns `false` when the connection
/// should stop reading (`bye`, `shutdown`, handshake mismatch).
#[allow(clippy::too_many_arguments)]
fn handle_frame<S: WireStream>(
    bytes: &[u8],
    lineno: u64,
    ctx: &ConnCtx,
    out: &Mutex<S>,
    resp_tx: &mpsc::Sender<FitResponse>,
    pending: &AtomicUsize,
    tickets: &Mutex<HashMap<u64, u64>>,
    partial: &mut PartialSession,
) -> bool {
    let text = match std::str::from_utf8(bytes) {
        Ok(t) => t,
        Err(_) => {
            proto_error(ctx, out, lineno, "request line is not valid UTF-8");
            return true;
        }
    };
    let line = text.trim();
    if line.is_empty() || line.starts_with('#') {
        return true; // blank lines and comments, as in the --jobs file format
    }
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            proto_error(ctx, out, lineno, &format!("malformed JSON: {e}"));
            return true;
        }
    };
    if let Json::Obj(map) = &parsed {
        if map.contains_key("op") {
            return control_frame(map, lineno, ctx, out, pending, tickets, partial);
        }
        if map.contains_key("proto") && !map.contains_key("id") {
            // Client handshake (PROTOCOL.md §2): optional, but if sent it
            // must name a protocol revision this server speaks.
            return match map.get("proto").map(|v| v.as_usize()) {
                Some(Ok(v)) if v as u64 == PROTO_VERSION => true,
                _ => {
                    proto_error(
                        ctx,
                        out,
                        lineno,
                        &format!("unsupported protocol revision (server speaks {PROTO_VERSION})"),
                    );
                    false
                }
            };
        }
    }
    match FitRequest::from_json(&parsed) {
        Ok(req) => {
            let client_id = req.id;
            pending.fetch_add(1, Ordering::SeqCst);
            let ticket = ctx.core.submit(req, resp_tx);
            // Registered after submit (the ticket does not exist before);
            // the writer's prune-on-delivery cannot plausibly beat this
            // insert — a reply must cross the core, the router and a
            // thread wakeup first — and even then the stale entry is
            // overwritten the next time the client reuses the id.
            tickets.lock().expect("ticket map poisoned").insert(client_id, ticket);
            true
        }
        Err(e) => {
            proto_error(ctx, out, lineno, &e.to_string());
            true
        }
    }
}

/// Handle a `{"op": ...}` control frame (PROTOCOL.md §6); returns `false`
/// when the connection should stop reading.
#[allow(clippy::too_many_arguments)]
fn control_frame<S: WireStream>(
    map: &BTreeMap<String, Json>,
    lineno: u64,
    ctx: &ConnCtx,
    out: &Mutex<S>,
    pending: &AtomicUsize,
    tickets: &Mutex<HashMap<u64, u64>>,
    partial: &mut PartialSession,
) -> bool {
    let op = match map.get("op").map(|v| v.as_str()) {
        Some(Ok(op)) => op,
        _ => {
            proto_error(ctx, out, lineno, "control frame 'op' must be a string");
            return true;
        }
    };
    match op {
        "ping" => {
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("pong".into()));
            m.insert("proto".to_string(), Json::Num(PROTO_VERSION as f64));
            let _ = write_line(out, &Json::Obj(m).to_string());
            true
        }
        "stats" => {
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("stats".into()));
            m.insert(
                "connections".to_string(),
                Json::Num(ctx.counters.accepted.load(Ordering::SeqCst) as f64),
            );
            m.insert(
                "active_conns".to_string(),
                Json::Num(ctx.counters.active.load(Ordering::SeqCst) as f64),
            );
            m.insert("pending_here".to_string(), Json::Num(pending.load(Ordering::SeqCst) as f64));
            ctx.core.stats_fields(&mut m);
            let _ = write_line(out, &Json::Obj(m).to_string());
            true
        }
        "cancel" => {
            // Cancel the most recent in-flight job this connection
            // submitted with the given id (PROTOCOL.md §6). The ack is
            // advisory; the job's own single reply stays authoritative.
            let id = match map.get("id").map(|v| v.as_usize()) {
                Some(Ok(id)) => id as u64,
                _ => {
                    proto_error(ctx, out, lineno, "cancel needs a non-negative integer 'id'");
                    return true;
                }
            };
            let ticket = tickets.lock().expect("ticket map poisoned").get(&id).copied();
            let cancelled = match ticket {
                Some(ticket) => ctx.core.cancel(ticket),
                None => false,
            };
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("cancelled".into()));
            m.insert("id".to_string(), Json::Num(id as f64));
            m.insert("cancelled".to_string(), Json::Bool(cancelled));
            let _ = write_line(out, &Json::Obj(m).to_string());
            true
        }
        "trace" => {
            // `peek: true` snapshots the span ring without consuming it
            // (PROTOCOL.md §11): dashboards poll with peek so they never
            // race a log shipper for the exactly-once drain. A peek is
            // not teed to `--trace-log` — the eventual drain still
            // delivers every span there exactly once.
            if matches!(map.get("peek"), Some(Json::Bool(true))) {
                let _ = write_line(out, &ctx.core.peek_trace().to_string());
                return true;
            }
            // Default: drain. Destructive — each span reaches exactly one
            // wire drainer — but spans are teed to the `--trace-log` sink
            // on their way out when one is configured.
            let drained = ctx.core.drain_trace();
            if let Some(sink) = &ctx.trace_sink {
                append_trace(sink, &drained);
            }
            let _ = write_line(out, &drained.to_string());
            true
        }
        "metrics" => {
            // Non-destructive registry snapshot (PROTOCOL.md §6). The
            // default reply embeds the JSON snapshot; `"format":
            // "prometheus"` returns the same snapshot rendered as
            // Prometheus text 0.0.4 in a `body` string (PROTOCOL.md §11).
            match map.get("format").map(|v| v.as_str()) {
                None => {}
                Some(Ok("json")) => {}
                Some(Ok("prometheus")) => {
                    let mut m = BTreeMap::new();
                    m.insert("op".to_string(), Json::Str("metrics".into()));
                    m.insert("format".to_string(), Json::Str("prometheus".into()));
                    m.insert(
                        "body".to_string(),
                        Json::Str(crate::obs::expo::render_prometheus(&ctx.core.metrics())),
                    );
                    let _ = write_line(out, &Json::Obj(m).to_string());
                    return true;
                }
                Some(Ok(other)) => {
                    proto_error(
                        ctx,
                        out,
                        lineno,
                        &format!("unknown metrics format '{other}' (json, prometheus)"),
                    );
                    return true;
                }
                Some(Err(_)) => {
                    proto_error(ctx, out, lineno, "metrics 'format' must be a string");
                    return true;
                }
            }
            let mut m = match ctx.core.metrics() {
                Json::Obj(m) => m,
                other => {
                    let mut m = BTreeMap::new();
                    m.insert("snapshot".to_string(), other);
                    m
                }
            };
            m.insert("op".to_string(), Json::Str("metrics".into()));
            let _ = write_line(out, &Json::Obj(m).to_string());
            true
        }
        "cache" => {
            // Result-cache introspection and reset (PROTOCOL.md §6/§8).
            // `clear` is optional; when present it must be a boolean.
            let clear = match map.get("clear") {
                None => false,
                Some(Json::Bool(b)) => *b,
                Some(_) => {
                    proto_error(ctx, out, lineno, "cache 'clear' must be a boolean");
                    return true;
                }
            };
            let _ = write_line(out, &ctx.core.cache_control(clear).to_string());
            true
        }
        "partial_fit" => {
            // Map-reduce fit, shard side (PROTOCOL.md §10). Computed
            // inline on this reader thread: the assignment pass blocks
            // only this connection, and the front drives every shard's
            // connection concurrently.
            match partial.partial_fit(&Json::Obj(map.clone())) {
                Ok(reply) => {
                    let _ = write_line(out, &reply.to_string());
                }
                Err(e) => proto_error(ctx, out, lineno, &e.to_string()),
            }
            true
        }
        "centroid_sync" => {
            match partial.centroid_sync(&Json::Obj(map.clone())) {
                Ok(reply) => {
                    let _ = write_line(out, &reply.to_string());
                }
                Err(e) => proto_error(ctx, out, lineno, &e.to_string()),
            }
            true
        }
        "bye" => false, // drain pending replies, then close this connection
        "shutdown" => {
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("shutdown-ack".into()));
            let _ = write_line(out, &Json::Obj(m).to_string());
            ctx.shutdown.store(true, Ordering::SeqCst);
            false
        }
        other => {
            proto_error(ctx, out, lineno, &format!("unknown op '{other}'"));
            true
        }
    }
}

/// Serve `GET /metrics` (Prometheus text 0.0.4, PROTOCOL.md §11) until
/// the shutdown flag flips. One short-lived connection per scrape with
/// `Connection: close` — scrapers arrive every few seconds at most, so
/// there is nothing worth keeping alive. The handler is deliberately
/// minimal HTTP/1.1: request line + headers in, one response out.
fn serve_metrics_http(listener: &TcpListener, core: &dyn FrontCore, shutdown: &AtomicBool) {
    use crate::obs::expo::{
        http_response, parse_request_line, render_prometheus, PROM_CONTENT_TYPE,
    };
    let _ = listener.set_nonblocking(true);
    while !shutdown.load(Ordering::SeqCst) {
        let mut stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                // WouldBlock (the common case) and transient accept
                // failures alike: back off one tick, re-check shutdown.
                std::thread::sleep(ACCEPT_TICK);
                continue;
            }
        };
        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
        let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
        // Read the request head (through the blank line); scrapes carry
        // no body, and anything past 8 KiB is not a scrape.
        let mut head = Vec::new();
        let mut buf = [0u8; 1024];
        loop {
            match io::Read::read(&mut stream, &mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    head.extend_from_slice(&buf[..n]);
                    if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
                        break;
                    }
                }
            }
        }
        let head = String::from_utf8_lossy(&head);
        let reply = match parse_request_line(&head) {
            Some(("GET", "/metrics")) => {
                http_response(200, "OK", PROM_CONTENT_TYPE, &render_prometheus(&core.metrics()))
            }
            Some(("GET", _)) => http_response(
                404,
                "Not Found",
                "text/plain; charset=utf-8",
                "only /metrics is served here\n",
            ),
            _ => http_response(
                405,
                "Method Not Allowed",
                "text/plain; charset=utf-8",
                "only GET /metrics is supported\n",
            ),
        };
        let _ = stream.write_all(&reply);
        let _ = stream.shutdown(std::net::Shutdown::Both);
    }
}

/// The server greeting (PROTOCOL.md §2): the first line on every
/// connection, announcing the protocol revision and core capabilities.
fn greeting(ctx: &ConnCtx) -> String {
    let mut m = BTreeMap::new();
    m.insert("kpynq".to_string(), Json::Str("serve".into()));
    m.insert("proto".to_string(), Json::Num(PROTO_VERSION as f64));
    m.insert("version".to_string(), Json::Str(env!("CARGO_PKG_VERSION").into()));
    m.insert("max_line_bytes".to_string(), Json::Num(MAX_LINE_BYTES as f64));
    ctx.core.greeting_fields(&mut m);
    Json::Obj(m).to_string()
}

/// Append a drained trace reply's spans to the `--trace-log` sink, one
/// JSON object per line (JSONL). Write failures are swallowed: a full
/// disk must not take the serving path down with it.
fn append_trace(sink: &Mutex<std::fs::File>, drained: &Json) {
    let events = match drained.get("events").and_then(|e| e.as_arr()) {
        Ok(events) if !events.is_empty() => events,
        _ => return,
    };
    let mut buf = String::new();
    for e in events {
        buf.push_str(&e.to_string());
        buf.push('\n');
    }
    let mut f = sink.lock().expect("trace sink poisoned");
    let _ = f.write_all(buf.as_bytes());
    let _ = f.flush();
}

/// Structured protocol-error reply (PROTOCOL.md §5).
fn error_reply(lineno: u64, msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("status".to_string(), Json::Str("error".into()));
    m.insert("error".to_string(), Json::Str(msg.into()));
    if lineno > 0 {
        m.insert("line".to_string(), Json::Num(lineno as f64));
    }
    Json::Obj(m).to_string()
}

fn proto_error<S: WireStream>(ctx: &ConnCtx, out: &Mutex<S>, lineno: u64, msg: &str) {
    ctx.counters.protocol_errors.fetch_add(1, Ordering::SeqCst);
    let _ = write_line(out, &error_reply(lineno, msg));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_config_validates() {
        NetConfig::default().validate().unwrap();
        assert!(NetConfig { max_conns: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn error_reply_shape_is_parseable() {
        let j = Json::parse(&error_reply(3, "malformed JSON: oops")).unwrap();
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "error");
        assert_eq!(j.get("line").unwrap().as_usize().unwrap(), 3);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("oops"));
        // Line 0 (pre-session refusals) omits the line key.
        assert!(Json::parse(&error_reply(0, "busy")).unwrap().get("line").is_err());
    }

    #[test]
    fn session_stats_fields_include_queue_depth() {
        let session = ServeSession::start(ServeConfig::default()).unwrap();
        let mut m = BTreeMap::new();
        FrontCore::stats_fields(&session, &mut m);
        assert!(m.contains_key("queue_depth"), "router least-loaded needs this");
        assert!(m.contains_key("submitted"));
        assert!(m.contains_key("peak_queue_depth"));
        assert!(m.contains_key("uptime_ms"));
        match m.get("queue_lanes") {
            Some(Json::Arr(lanes)) => {
                assert_eq!(lanes.len(), crate::serve::Priority::LEVELS)
            }
            other => panic!("queue_lanes must be a per-priority array, got {other:?}"),
        }
        match m.get("tenants") {
            Some(Json::Obj(t)) => assert!(t.is_empty(), "no tenanted traffic yet"),
            other => panic!("tenants must be an object, got {other:?}"),
        }
        let mut g = BTreeMap::new();
        FrontCore::greeting_fields(&session, &mut g);
        assert!(g.contains_key("workers"));
        assert!(g.contains_key("backends"));
        session.shutdown();
    }

    #[test]
    fn session_core_drains_trace_and_snapshots_metrics() {
        let session = ServeSession::start(ServeConfig::default()).unwrap();
        let snap = FrontCore::metrics(&session);
        assert!(snap.get("counters").is_ok());
        assert!(snap.get("histograms").is_ok());
        let drained = FrontCore::drain_trace(&session);
        assert_eq!(drained.get("op").unwrap().as_str().unwrap(), "trace");
        assert!(drained.get("events").unwrap().as_arr().unwrap().is_empty());
        session.shutdown();
    }

    #[test]
    fn trace_log_appends_drained_spans_as_jsonl() {
        use crate::obs::{SpanEvent, TraceRing};
        let dir = std::env::temp_dir().join(format!("kpynq-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let _ = std::fs::remove_file(&path);
        let sink = Mutex::new(
            std::fs::OpenOptions::new().create(true).append(true).open(&path).unwrap(),
        );
        let ring = TraceRing::default();
        ring.push(SpanEvent::new("00000000000000aa", "admit").num("ticket", 1.0));
        ring.push(SpanEvent::new("00000000000000aa", "reply").num("ticket", 1.0));
        append_trace(&sink, &ring.drain_json());
        append_trace(&sink, &ring.drain_json()); // empty drain appends nothing
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{text}");
        for line in &lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("trace_id").unwrap().as_str().unwrap(), "00000000000000aa");
        }
        let _ = std::fs::remove_file(&path);
    }
}
