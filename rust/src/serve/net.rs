//! The persistent socket front-end: `kpynq serve --listen`.
//!
//! PR 2's `kpynq serve` was a batch filter — drain stdin, answer, exit —
//! so every client paid engine construction (and, on the XLA path, AOT
//! compilation) per invocation. [`Daemon`] keeps one [`ServeSession`]
//! alive behind a listener instead: concurrent TCP (and, on Unix,
//! `unix:<path>` Unix-domain) connections all multiplex into the same
//! admission queue and the same per-worker engine banks, so warm engines
//! finally span *clients*, not just the requests of one stream.
//!
//! The wire format is the NDJSON job model `serve::job` already speaks —
//! one `FitRequest` object per line in, one response line per job out —
//! prefixed by a single server greeting line and with a handful of
//! control frames (`ping`, `stats`, `bye`, `shutdown`). The protocol is
//! specified normatively in PROTOCOL.md; this module implements it and
//! cites it rather than restating it. Connection lifecycle and
//! backpressure contracts live in DESIGN.md §2.
//!
//! Malformed lines never kill a connection, let alone the daemon: every
//! frame the server cannot accept is answered with a structured error
//! reply (PROTOCOL.md §5) and the session keeps reading. A client that
//! disconnects mid-stream forfeits its undelivered responses (counted in
//! the report) but leaves the pool untouched.
//!
//! ```no_run
//! use kpynq::serve::net::{Daemon, NetConfig};
//! use kpynq::serve::ServeConfig;
//!
//! let daemon = Daemon::bind("127.0.0.1:7071", NetConfig::default(),
//!                           ServeConfig::default()).unwrap();
//! println!("listening on {}", daemon.local_addr());
//! let report = daemon.run().unwrap(); // blocks until {"op":"shutdown"}
//! println!("{}", report.render());
//! ```

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::json::Json;

use super::job::{FitRequest, FitResponse};
use super::session::ServeSession;
use super::{ServeConfig, ServeReport};

/// Wire protocol revision this build speaks (PROTOCOL.md §1).
pub const PROTO_VERSION: u64 = 1;

/// Hard cap on one request line (PROTOCOL.md §2). Longer lines are
/// answered with a structured error and discarded up to the next newline.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Read-timeout tick: how often a blocked connection reader wakes to check
/// the shutdown flag and its idle budget.
const READ_TICK: Duration = Duration::from_millis(50);
/// Accept-poll tick for the (non-blocking) listener loop.
const ACCEPT_TICK: Duration = Duration::from_millis(20);
/// Writer-side timeout: a client that stops reading for this long has its
/// responses dropped instead of wedging a worker-fed writer thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Listener configuration (the `[serve.net]` config section).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Simultaneous-connection cap; extras are refused with an error line.
    pub max_conns: usize,
    /// Close a connection that has sent no traffic and has no pending
    /// responses for this many milliseconds. 0 disables the idle timeout.
    pub idle_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self { max_conns: 32, idle_timeout_ms: 0 }
    }
}

impl NetConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_conns == 0 {
            return Err(Error::Config("serve.net max_conns must be positive".into()));
        }
        Ok(())
    }
}

/// A bound listener: TCP (`host:port`) or, on Unix, `unix:<path>`.
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener, std::path::PathBuf),
}

/// One accept-poll outcome.
enum Accepted {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
    Pending,
}

impl Listener {
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(true),
        }
    }

    fn poll_accept(&self) -> io::Result<Accepted> {
        let accepted = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Accepted::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Accepted::Unix(s)),
        };
        match accepted {
            Ok(a) => Ok(a),
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted) =>
            {
                Ok(Accepted::Pending)
            }
            Err(e) => Err(e),
        }
    }
}

/// The minimal stream surface both TCP and Unix-domain sockets provide;
/// connection handling is generic over it.
trait WireStream: Read + Write + Send + Sized + 'static {
    fn try_clone_stream(&self) -> io::Result<Self>;
    /// Force blocking mode: whether an accepted socket inherits the
    /// listener's non-blocking flag is platform-dependent, and the read
    /// loop's timeout ticks assume a blocking socket (a non-blocking one
    /// would spin hot instead of sleeping up to `READ_TICK`).
    fn set_blocking(&self) -> io::Result<()>;
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()>;
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()>;
    fn shutdown_stream(&self);
}

impl WireStream for TcpStream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        self.try_clone()
    }
    fn set_blocking(&self) -> io::Result<()> {
        self.set_nonblocking(false)
    }
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_write_timeout(d)
    }
    fn shutdown_stream(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

#[cfg(unix)]
impl WireStream for std::os::unix::net::UnixStream {
    fn try_clone_stream(&self) -> io::Result<Self> {
        self.try_clone()
    }
    fn set_blocking(&self) -> io::Result<()> {
        self.set_nonblocking(false)
    }
    fn set_read_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }
    fn set_write_timeout_dur(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_write_timeout(d)
    }
    fn shutdown_stream(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

/// Daemon-wide connection counters, folded into the final [`ServeReport`].
#[derive(Debug, Default)]
struct NetCounters {
    accepted: AtomicU64,
    active: AtomicUsize,
    peak: AtomicUsize,
    refused: AtomicU64,
    protocol_errors: AtomicU64,
}

/// Everything a connection handler needs a handle on.
struct ConnCtx {
    session: Arc<ServeSession>,
    counters: Arc<NetCounters>,
    shutdown: Arc<AtomicBool>,
    net: NetConfig,
}

/// A bound-but-not-yet-running daemon. [`Daemon::run`] drives the accept
/// loop to completion: it returns after a graceful drain — triggered by a
/// client's `{"op":"shutdown"}` frame (PROTOCOL.md §6) or by
/// [`DaemonHandle::shutdown`] — with the session's [`ServeReport`].
pub struct Daemon {
    listener: Listener,
    net: NetConfig,
    serve: ServeConfig,
    shutdown: Arc<AtomicBool>,
}

/// A cloneable remote control for a running daemon (the embedding test /
/// bench equivalent of the on-wire `shutdown` frame).
#[derive(Clone)]
pub struct DaemonHandle {
    shutdown: Arc<AtomicBool>,
}

impl DaemonHandle {
    /// Begin a graceful drain: stop accepting, let connections finish
    /// their pending replies, then shut the session down.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

impl Daemon {
    /// Bind the listener (`host:port`, or `unix:<path>` on Unix) and
    /// validate both configs. Port 0 binds an ephemeral port — read it
    /// back with [`Daemon::local_addr`]. A stale Unix socket *file* left
    /// by a dead daemon is removed before binding; any other file type at
    /// that path makes the bind fail rather than be deleted.
    pub fn bind(addr: &str, net: NetConfig, serve: ServeConfig) -> Result<Daemon> {
        net.validate()?;
        serve.validate()?;
        let listener = match addr.strip_prefix("unix:") {
            Some(path) => bind_unix(path)?,
            None => Listener::Tcp(TcpListener::bind(addr).map_err(|e| {
                Error::Config(format!("cannot listen on '{addr}': {e}"))
            })?),
        };
        Ok(Daemon { listener, net, serve, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address, in the same notation `bind` accepts.
    pub fn local_addr(&self) -> String {
        match &self.listener {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".into()),
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }

    /// A handle that can trigger a graceful drain from another thread.
    pub fn handle(&self) -> DaemonHandle {
        DaemonHandle { shutdown: Arc::clone(&self.shutdown) }
    }

    /// The pool shape this daemon will serve with.
    pub fn serve_config(&self) -> &ServeConfig {
        &self.serve
    }

    /// Serve until shutdown: accept connections (refusing extras beyond
    /// `max_conns`), multiplex them all into one shared [`ServeSession`],
    /// and on the shutdown signal stop accepting, join every connection
    /// (each drains its pending replies first), drain the pool and return
    /// the session report with the connection counters folded in.
    pub fn run(self) -> Result<ServeReport> {
        let Daemon { listener, net, serve, shutdown } = self;
        let session = Arc::new(ServeSession::start(serve)?);
        let counters = Arc::new(NetCounters::default());
        listener.set_nonblocking()?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();

        while !shutdown.load(Ordering::SeqCst) {
            match listener.poll_accept() {
                // Transient accept failures — ECONNABORTED from a client
                // that reset mid-handshake, EMFILE under fd pressure —
                // must not kill a daemon holding live connections; back
                // off one tick and keep serving.
                Err(_) | Ok(Accepted::Pending) => std::thread::sleep(ACCEPT_TICK),
                Ok(Accepted::Tcp(stream)) => {
                    let _ = stream.set_nodelay(true);
                    if let Some(h) = spawn_conn(stream, &session, &counters, &shutdown, &net) {
                        conns.push(h);
                    }
                }
                #[cfg(unix)]
                Ok(Accepted::Unix(stream)) => {
                    if let Some(h) = spawn_conn(stream, &session, &counters, &shutdown, &net) {
                        conns.push(h);
                    }
                }
            }
            // Bound the handle list on long uptimes; finished threads are
            // already joined-equivalent (dropping a finished handle is
            // detach-after-exit).
            if conns.len() > 64 {
                conns.retain(|h| !h.is_finished());
            }
        }

        for h in conns {
            let _ = h.join();
        }
        match &listener {
            #[cfg(unix)]
            Listener::Unix(_, path) => {
                let _ = std::fs::remove_file(path);
            }
            _ => {}
        }
        drop(listener);

        let session = Arc::into_inner(session).expect("all connections joined");
        let mut report = session.shutdown();
        report.connections = counters.accepted.load(Ordering::SeqCst);
        report.peak_connections = counters.peak.load(Ordering::SeqCst);
        report.refused_connections = counters.refused.load(Ordering::SeqCst);
        report.protocol_errors = counters.protocol_errors.load(Ordering::SeqCst);
        Ok(report)
    }
}

#[cfg(unix)]
fn bind_unix(path: &str) -> Result<Listener> {
    use std::os::unix::fs::FileTypeExt;
    let path = std::path::PathBuf::from(path);
    // Remove only a stale *socket* at the target path; a regular file or
    // directory there is someone else's data and must fail the bind.
    if let Ok(meta) = std::fs::metadata(&path) {
        if meta.file_type().is_socket() {
            std::fs::remove_file(&path)?;
        }
    }
    let listener = std::os::unix::net::UnixListener::bind(&path)
        .map_err(|e| Error::Config(format!("cannot listen on 'unix:{}': {e}", path.display())))?;
    Ok(Listener::Unix(listener, path))
}

#[cfg(not(unix))]
fn bind_unix(_path: &str) -> Result<Listener> {
    Err(Error::Config("unix-domain listeners are only available on Unix platforms".into()))
}

/// Admit-or-refuse one accepted stream; on admit, spawn its handler.
fn spawn_conn<S: WireStream>(
    stream: S,
    session: &Arc<ServeSession>,
    counters: &Arc<NetCounters>,
    shutdown: &Arc<AtomicBool>,
    net: &NetConfig,
) -> Option<std::thread::JoinHandle<()>> {
    if counters.active.load(Ordering::SeqCst) >= net.max_conns {
        counters.refused.fetch_add(1, Ordering::SeqCst);
        let mut stream = stream;
        let _ = stream.set_write_timeout_dur(Some(WRITE_TIMEOUT));
        let _ = stream.write_all(
            format!(
                "{}\n",
                error_reply(0, &format!("server at max connections ({})", net.max_conns))
            )
            .as_bytes(),
        );
        stream.shutdown_stream();
        return None;
    }
    counters.accepted.fetch_add(1, Ordering::SeqCst);
    let active = counters.active.fetch_add(1, Ordering::SeqCst) + 1;
    counters.peak.fetch_max(active, Ordering::SeqCst);
    let ctx = ConnCtx {
        session: Arc::clone(session),
        counters: Arc::clone(counters),
        shutdown: Arc::clone(shutdown),
        net: net.clone(),
    };
    Some(std::thread::spawn(move || {
        handle_conn(stream, &ctx);
        ctx.counters.active.fetch_sub(1, Ordering::SeqCst);
    }))
}

/// Per-connection protocol loop. The reader (this thread) parses frames
/// and submits jobs; a paired writer thread serializes routed responses
/// back. Both write whole lines under one lock, so control replies and
/// job responses interleave without tearing. Teardown — EOF, `bye`,
/// idle timeout, read error or daemon shutdown — always drains pending
/// responses before closing (PROTOCOL.md §2).
fn handle_conn<S: WireStream>(stream: S, ctx: &ConnCtx) {
    let _ = stream.set_blocking();
    let _ = stream.set_read_timeout_dur(Some(READ_TICK));
    let writer = match stream.try_clone_stream() {
        Ok(w) => w,
        Err(_) => return,
    };
    let _ = writer.set_write_timeout_dur(Some(WRITE_TIMEOUT));
    let out = Arc::new(Mutex::new(writer));
    let pending = Arc::new(AtomicUsize::new(0));

    let _ = write_line(&out, &greeting(ctx));

    let (resp_tx, resp_rx) = mpsc::channel::<FitResponse>();
    let writer_thread = {
        let out = Arc::clone(&out);
        let pending = Arc::clone(&pending);
        std::thread::spawn(move || {
            for resp in resp_rx {
                let _ = write_line(&out, &resp.to_json().to_string());
                // Decrement even on write failure: the job is answered as
                // far as the session is concerned, and the reader's drain
                // must not wait on a dead peer.
                pending.fetch_sub(1, Ordering::SeqCst);
            }
        })
    };

    let idle_limit =
        (ctx.net.idle_timeout_ms > 0).then(|| Duration::from_millis(ctx.net.idle_timeout_ms));
    let mut reader = LineReader::new(stream);
    let mut last_activity = Instant::now();
    let mut lineno = 0u64;
    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            break; // daemon draining: stop reading, deliver what's pending
        }
        match reader.next_event() {
            LineEvent::Line(bytes) => {
                lineno += 1;
                last_activity = Instant::now();
                if !handle_frame(&bytes, lineno, ctx, &out, &resp_tx, &pending) {
                    break;
                }
            }
            LineEvent::Oversized => {
                lineno += 1;
                last_activity = Instant::now();
                proto_error(
                    ctx,
                    &out,
                    lineno,
                    &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                );
            }
            LineEvent::Tick => {
                if let Some(limit) = idle_limit {
                    if pending.load(Ordering::SeqCst) == 0 && last_activity.elapsed() >= limit {
                        let mut m = BTreeMap::new();
                        m.insert("op".to_string(), Json::Str("idle-timeout".into()));
                        m.insert("idle_ms".to_string(), Json::Num(ctx.net.idle_timeout_ms as f64));
                        let _ = write_line(&out, &Json::Obj(m).to_string());
                        break;
                    }
                }
            }
            LineEvent::Eof | LineEvent::Error(_) => break,
        }
    }

    // Drain: every submitted job produces exactly one routed response, so
    // `pending` reaches zero once the session has answered them all (the
    // writer decrements even when the peer is gone).
    while pending.load(Ordering::SeqCst) > 0 {
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(resp_tx);
    let _ = writer_thread.join();
    reader.into_inner().shutdown_stream();
}

/// Dispatch one parsed-or-not frame; returns `false` when the connection
/// should stop reading (`bye`, `shutdown`, handshake mismatch).
fn handle_frame<S: WireStream>(
    bytes: &[u8],
    lineno: u64,
    ctx: &ConnCtx,
    out: &Mutex<S>,
    resp_tx: &mpsc::Sender<FitResponse>,
    pending: &AtomicUsize,
) -> bool {
    let text = match std::str::from_utf8(bytes) {
        Ok(t) => t,
        Err(_) => {
            proto_error(ctx, out, lineno, "request line is not valid UTF-8");
            return true;
        }
    };
    let line = text.trim();
    if line.is_empty() || line.starts_with('#') {
        return true; // blank lines and comments, as in the --jobs file format
    }
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            proto_error(ctx, out, lineno, &format!("malformed JSON: {e}"));
            return true;
        }
    };
    if let Json::Obj(map) = &parsed {
        if map.contains_key("op") {
            return control_frame(map, lineno, ctx, out, pending);
        }
        if map.contains_key("proto") && !map.contains_key("id") {
            // Client handshake (PROTOCOL.md §2): optional, but if sent it
            // must name a protocol revision this server speaks.
            return match map.get("proto").map(|v| v.as_usize()) {
                Some(Ok(v)) if v as u64 == PROTO_VERSION => true,
                _ => {
                    proto_error(
                        ctx,
                        out,
                        lineno,
                        &format!("unsupported protocol revision (server speaks {PROTO_VERSION})"),
                    );
                    false
                }
            };
        }
    }
    match FitRequest::from_json(&parsed) {
        Ok(req) => {
            pending.fetch_add(1, Ordering::SeqCst);
            ctx.session.submit(req, resp_tx);
            true
        }
        Err(e) => {
            proto_error(ctx, out, lineno, &e.to_string());
            true
        }
    }
}

/// Handle a `{"op": ...}` control frame (PROTOCOL.md §6); returns `false`
/// when the connection should stop reading.
fn control_frame<S: WireStream>(
    map: &BTreeMap<String, Json>,
    lineno: u64,
    ctx: &ConnCtx,
    out: &Mutex<S>,
    pending: &AtomicUsize,
) -> bool {
    let op = match map.get("op").map(|v| v.as_str()) {
        Some(Ok(op)) => op,
        _ => {
            proto_error(ctx, out, lineno, "control frame 'op' must be a string");
            return true;
        }
    };
    match op {
        "ping" => {
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("pong".into()));
            m.insert("proto".to_string(), Json::Num(PROTO_VERSION as f64));
            let _ = write_line(out, &Json::Obj(m).to_string());
            true
        }
        "stats" => {
            let q = ctx.session.queue_stats();
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("stats".into()));
            m.insert("submitted".to_string(), Json::Num(ctx.session.submitted() as f64));
            m.insert(
                "connections".to_string(),
                Json::Num(ctx.counters.accepted.load(Ordering::SeqCst) as f64),
            );
            m.insert(
                "active_conns".to_string(),
                Json::Num(ctx.counters.active.load(Ordering::SeqCst) as f64),
            );
            m.insert("pending_here".to_string(), Json::Num(pending.load(Ordering::SeqCst) as f64));
            m.insert("shed_full".to_string(), Json::Num(q.shed_full as f64));
            m.insert("shed_deadline".to_string(), Json::Num(q.shed_deadline as f64));
            m.insert("peak_queue_depth".to_string(), Json::Num(q.peak_depth as f64));
            let _ = write_line(out, &Json::Obj(m).to_string());
            true
        }
        "bye" => false, // drain pending replies, then close this connection
        "shutdown" => {
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str("shutdown-ack".into()));
            let _ = write_line(out, &Json::Obj(m).to_string());
            ctx.shutdown.store(true, Ordering::SeqCst);
            false
        }
        other => {
            proto_error(ctx, out, lineno, &format!("unknown op '{other}'"));
            true
        }
    }
}

/// The server greeting (PROTOCOL.md §2): the first line on every
/// connection, announcing the protocol revision and pool capabilities.
fn greeting(ctx: &ConnCtx) -> String {
    let cfg = ctx.session.config();
    let mut m = BTreeMap::new();
    m.insert("kpynq".to_string(), Json::Str("serve".into()));
    m.insert("proto".to_string(), Json::Num(PROTO_VERSION as f64));
    m.insert("version".to_string(), Json::Str(env!("CARGO_PKG_VERSION").into()));
    m.insert("workers".to_string(), Json::Num(cfg.workers as f64));
    m.insert("max_batch".to_string(), Json::Num(cfg.max_batch as f64));
    m.insert("max_line_bytes".to_string(), Json::Num(MAX_LINE_BYTES as f64));
    // Only backends this *build* can actually execute (PROTOCOL.md §2):
    // without the `xla` cargo feature the engine is a stub whose
    // construction errors, so advertising it would invite guaranteed-to-
    // fail jobs.
    let mut backends = vec![Json::Str("fpga-sim".into()), Json::Str("native".into())];
    if cfg!(feature = "xla") {
        backends.push(Json::Str("xla".into()));
    }
    m.insert("backends".to_string(), Json::Arr(backends));
    Json::Obj(m).to_string()
}

/// Structured protocol-error reply (PROTOCOL.md §5).
fn error_reply(lineno: u64, msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("status".to_string(), Json::Str("error".into()));
    m.insert("error".to_string(), Json::Str(msg.into()));
    if lineno > 0 {
        m.insert("line".to_string(), Json::Num(lineno as f64));
    }
    Json::Obj(m).to_string()
}

fn proto_error<S: WireStream>(ctx: &ConnCtx, out: &Mutex<S>, lineno: u64, msg: &str) {
    ctx.counters.protocol_errors.fetch_add(1, Ordering::SeqCst);
    let _ = write_line(out, &error_reply(lineno, msg));
}

/// Write one full protocol line under the connection's writer lock.
fn write_line<S: Write>(out: &Mutex<S>, line: &str) -> io::Result<()> {
    let mut w = out.lock().expect("connection writer lock poisoned");
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// One step of the connection read loop.
enum LineEvent {
    /// A complete line (without its terminator).
    Line(Vec<u8>),
    /// A line exceeded [`MAX_LINE_BYTES`]; its bytes are being discarded
    /// up to the next newline.
    Oversized,
    /// The read timeout elapsed with no data — time to check the shutdown
    /// flag and the idle budget.
    Tick,
    Eof,
    Error(io::Error),
}

/// Incremental, bounded line reader over a timeout-ticking stream.
/// `BufReader::read_line` can neither bound a hostile line's memory nor
/// surface timeout ticks mid-line, so the accumulation is explicit here.
struct LineReader<S: Read> {
    stream: S,
    acc: Vec<u8>,
    discarding: bool,
}

impl<S: Read> LineReader<S> {
    fn new(stream: S) -> Self {
        Self { stream, acc: Vec::new(), discarding: false }
    }

    fn into_inner(self) -> S {
        self.stream
    }

    fn next_event(&mut self) -> LineEvent {
        loop {
            if let Some(i) = self.acc.iter().position(|&b| b == b'\n') {
                let rest = self.acc.split_off(i + 1);
                let mut line = std::mem::replace(&mut self.acc, rest);
                line.pop(); // the newline
                if self.discarding {
                    // Tail of an oversized line: drop it and resume normal
                    // framing from the next line.
                    self.discarding = false;
                    continue;
                }
                if line.len() > MAX_LINE_BYTES {
                    return LineEvent::Oversized; // complete, but too long
                }
                return LineEvent::Line(line);
            }
            if self.discarding {
                self.acc.clear(); // bound memory while hunting the newline
            } else if self.acc.len() > MAX_LINE_BYTES {
                self.discarding = true;
                self.acc.clear();
                return LineEvent::Oversized;
            }
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    // A final line without its terminator still counts (a
                    // `printf` without `\n` followed by EOF); discarded
                    // oversize tails do not.
                    if self.acc.is_empty() || self.discarding {
                        return LineEvent::Eof;
                    }
                    return LineEvent::Line(std::mem::take(&mut self.acc));
                }
                Ok(n) => self.acc.extend_from_slice(&buf[..n]),
                Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                    return LineEvent::Tick
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return LineEvent::Error(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted reader: each entry is either bytes to deliver or a
    /// would-block tick.
    struct Script(Vec<Option<Vec<u8>>>);

    impl Read for Script {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.0.pop() {
                None => Ok(0), // EOF
                Some(None) => Err(io::Error::new(io::ErrorKind::WouldBlock, "tick")),
                Some(Some(mut bytes)) => {
                    let n = bytes.len().min(buf.len());
                    buf[..n].copy_from_slice(&bytes[..n]);
                    if n < bytes.len() {
                        // Hand the remainder back as the next read.
                        self.0.push(Some(bytes.split_off(n)));
                    }
                    Ok(n)
                }
            }
        }
    }

    fn reader(script: Vec<Option<&[u8]>>) -> LineReader<Script> {
        LineReader::new(Script(
            script.into_iter().rev().map(|e| e.map(|b| b.to_vec())).collect(),
        ))
    }

    #[test]
    fn line_reader_splits_and_reassembles_partial_lines() {
        let mut r = reader(vec![Some(&b"{\"id\""[..]), Some(&b":1}\n{\"id\":2}\n"[..])]);
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"{\"id\":1}"));
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"{\"id\":2}"));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn line_reader_surfaces_ticks_between_chunks() {
        let mut r = reader(vec![None, Some(&b"x\n"[..]), None]);
        assert!(matches!(r.next_event(), LineEvent::Tick));
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"x"));
        assert!(matches!(r.next_event(), LineEvent::Tick));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn line_reader_discards_oversized_lines_and_recovers() {
        let big = vec![b'a'; MAX_LINE_BYTES + 4096];
        let mut r = reader(vec![Some(&big[..]), Some(&b"bbb\nok\n"[..])]);
        assert!(matches!(r.next_event(), LineEvent::Oversized));
        // The giant line's tail ("bbb\n") is swallowed; framing resumes at
        // the next line.
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"ok"));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn line_reader_yields_an_unterminated_final_line() {
        let mut r = reader(vec![Some(&b"a\nb"[..])]);
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"a"));
        assert!(matches!(r.next_event(), LineEvent::Line(l) if l == b"b"));
        assert!(matches!(r.next_event(), LineEvent::Eof));
    }

    #[test]
    fn net_config_validates() {
        NetConfig::default().validate().unwrap();
        assert!(NetConfig { max_conns: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn error_reply_shape_is_parseable() {
        let j = Json::parse(&error_reply(3, "malformed JSON: oops")).unwrap();
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "error");
        assert_eq!(j.get("line").unwrap().as_usize().unwrap(), 3);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("oops"));
        // Line 0 (pre-session refusals) omits the line key.
        assert!(Json::parse(&error_reply(0, "busy")).unwrap().get("line").is_err());
    }
}
