//! Fingerprint-keyed result cache: answer duplicate fits without
//! touching a shard.
//!
//! KPynq's work-efficiency ethos applied to traffic: a fit the system
//! has already computed is distance work the triangle inequality cannot
//! skip but the front trivially can. Requests are canonicalized into a
//! **request fingerprint** (PROTOCOL.md §8) — FNV-1a over the canonical
//! JSON of every result-determining key, with the scheduling/identity
//! keys (`id`, `priority`, `deadline_ms`, `trace_id`, `tenant`)
//! stripped, since they never change the bits of a clustering. Served
//! results are deterministic functions of that surface (generator
//! datasets are seed-addressed; fits are bit-reproducible), so a cache
//! hit replays the stored reply **bit-identically** — same assignments
//! fingerprint, inertia, iterations and work counters — marked only by
//! the `cached` key (PROTOCOL.md §4).
//!
//! File datasets (`.kpm` / `.csv` paths) are *never* cached: the bytes
//! behind a path can change between requests, and a fingerprint that
//! cannot see them must not vouch for them.
//!
//! Bounded LRU: `capacity` entries, least-recently-used evicted first,
//! `serve.cache.{hits,misses,evictions}` counters, and a
//! `{"op":"cache","clear":true}` control frame (PROTOCOL.md §6) for
//! operators who need to drop stale state. Both fronts — the daemon
//! session and the cluster front — consult one of these before
//! admission, so a duplicate fit costs neither a queue slot nor an
//! engine dispatch.

use std::collections::{HashMap, VecDeque};

use crate::obs::metrics::{names, Counter, Registry};
use crate::util::json::Json;

use super::batch::dataset_dim;
use super::job::{FitRequest, FitResponse, JobStatus};

/// FNV-1a (64-bit) over raw bytes — the same constants as the §8
/// assignment fingerprint, applied to the canonical request JSON.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Wire keys that schedule or label a job without changing its result —
/// exactly the keys stripped before fingerprinting (PROTOCOL.md §8).
pub const NON_RESULT_KEYS: &[&str] = &["id", "priority", "deadline_ms", "trace_id", "tenant"];

/// The request fingerprint (PROTOCOL.md §8): canonicalize the §3 wire
/// form (BTreeMap-ordered keys, the crate's own JSON encoder), strip
/// [`NON_RESULT_KEYS`], and FNV-1a the UTF-8 bytes. `None` marks an
/// uncacheable request — any file-path dataset, whose content the
/// fingerprint cannot observe.
pub fn fingerprint_of(req: &FitRequest) -> Option<u64> {
    dataset_dim(&req.dataset)?;
    let mut m = match req.to_json() {
        Json::Obj(m) => m,
        _ => unreachable!("FitRequest::to_json always yields an object"),
    };
    for k in NON_RESULT_KEYS {
        m.remove(*k);
    }
    Some(fnv1a(Json::Obj(m).to_string().as_bytes()))
}

/// The `{"op":"cache"}` reply body (PROTOCOL.md §6): current `size`,
/// configured `capacity`, and — after a clear — how many entries were
/// `cleared`.
pub fn cache_json(size: usize, capacity: usize, cleared: Option<usize>) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("op".to_string(), Json::Str("cache".to_string()));
    m.insert("size".to_string(), Json::Num(size as f64));
    m.insert("capacity".to_string(), Json::Num(capacity as f64));
    if let Some(n) = cleared {
        m.insert("cleared".to_string(), Json::Num(n as f64));
    }
    Json::Obj(m)
}

/// Bounded LRU of finished replies, keyed by [`fingerprint_of`].
/// Not thread-safe — callers wrap it in their session/front mutex.
#[derive(Debug)]
pub struct ResultCache {
    capacity: usize,
    entries: HashMap<u64, FitResponse>,
    /// Recency order, front = least recently used.
    order: VecDeque<u64>,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
}

impl ResultCache {
    /// `capacity` 0 disables the cache (every lookup misses silently).
    pub fn new(capacity: usize, registry: &Registry) -> Self {
        Self {
            capacity,
            entries: HashMap::new(),
            order: VecDeque::new(),
            hits: registry.counter(names::SERVE_CACHE_HITS),
            misses: registry.counter(names::SERVE_CACHE_MISSES),
            evictions: registry.counter(names::SERVE_CACHE_EVICTIONS),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Replay the stored reply for `fp`, re-identified for `req`: the
    /// caller's id / trace id / tenant are restored, timing fields are
    /// zeroed (no queue was waited on, no engine ran), and the `cached`
    /// marker is set. Every *result* field — summary, fit, report,
    /// backend, worker, batch size — is the stored run's, bit-identical.
    pub fn lookup(&mut self, fp: u64, req: &FitRequest) -> Option<FitResponse> {
        if !self.enabled() {
            return None;
        }
        let Some(stored) = self.entries.get(&fp) else {
            self.misses.inc();
            return None;
        };
        let mut resp = stored.clone();
        self.order.retain(|k| *k != fp);
        self.order.push_back(fp);
        resp.id = req.id;
        resp.trace_id = req.trace_id.clone();
        resp.tenant = req.tenant.clone();
        resp.queue_seconds = 0.0;
        resp.service_seconds = 0.0;
        resp.cached = true;
        self.hits.inc();
        Some(resp)
    }

    /// Store a finished reply under `fp`. Only completed, cold results
    /// enter (shed/failed outcomes are scheduling verdicts, and a cached
    /// reply must not re-seed itself); the first result for a
    /// fingerprint wins — duplicates are, by construction, bit-identical.
    pub fn insert(&mut self, fp: u64, resp: &FitResponse) {
        if !self.enabled() || resp.status != JobStatus::Ok || resp.cached {
            return;
        }
        if self.entries.contains_key(&fp) {
            return;
        }
        while self.entries.len() >= self.capacity {
            let Some(lru) = self.order.pop_front() else { break };
            self.entries.remove(&lru);
            self.evictions.inc();
        }
        self.entries.insert(fp, resp.clone());
        self.order.push_back(fp);
    }

    /// Drop everything; returns how many entries were dropped (the
    /// `cleared` field of the §6 `cache` control reply).
    pub fn clear(&mut self) -> usize {
        let n = self.entries.len();
        self.entries.clear();
        self.order.clear();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::job::Priority;

    fn ok_resp(id: u64) -> FitResponse {
        let req = FitRequest { id, max_points: 200, ..Default::default() };
        let ds = req.load_dataset().unwrap();
        let out = crate::coordinator::driver::run_with_engine(
            &mut crate::runtime::native::NativeEngine,
            &ds,
            &req.kmeans,
        )
        .unwrap();
        FitResponse::ok(id, "native".into(), 0, 1, 0.01, 0.2, out.fit, out.report)
    }

    #[test]
    fn fingerprint_ignores_scheduling_keys_only() {
        let base = FitRequest { id: 1, ..Default::default() };
        let fp = fingerprint_of(&base).unwrap();
        // Identity/scheduling keys do not move the fingerprint…
        let mut twin = base.clone();
        twin.id = 999;
        twin.priority = Priority::High;
        twin.deadline_ms = Some(50);
        twin.trace_id = "cafe".into();
        twin.tenant = "acme".into();
        assert_eq!(fingerprint_of(&twin).unwrap(), fp);
        // …while every result-determining key does.
        for mutate in [
            |r: &mut FitRequest| r.kmeans.seed = 123,
            |r: &mut FitRequest| r.kmeans.k += 1,
            |r: &mut FitRequest| r.dataset = "kegg".into(),
            |r: &mut FitRequest| r.data_seed += 1,
            |r: &mut FitRequest| r.max_points = 99,
            |r: &mut FitRequest| r.normalize = "zscore".into(),
            |r: &mut FitRequest| r.algorithm = "lloyd".into(),
        ] {
            let mut other = base.clone();
            mutate(&mut other);
            assert_ne!(fingerprint_of(&other).unwrap(), fp, "{other:?}");
        }
    }

    #[test]
    fn file_datasets_are_never_cacheable() {
        let mut req = FitRequest::default();
        req.dataset = "data/points.csv".into();
        assert_eq!(fingerprint_of(&req), None);
    }

    #[test]
    fn hit_replays_the_result_bits_under_the_new_identity() {
        let reg = Registry::new();
        let mut cache = ResultCache::new(4, &reg);
        let req = FitRequest { id: 1, tenant: "acme".into(), ..Default::default() };
        let fp = fingerprint_of(&req).unwrap();
        assert!(cache.lookup(fp, &req).is_none(), "cold start misses");
        let cold = ok_resp(1);
        cache.insert(fp, &cold);
        let mut dup = req.clone();
        dup.id = 42;
        dup.trace_id = "feedface".into();
        let hit = cache.lookup(fp, &dup).expect("second identical request hits");
        assert!(hit.cached);
        assert_eq!(hit.id, 42);
        assert_eq!(hit.trace_id, "feedface");
        assert_eq!(hit.tenant, "acme");
        assert_eq!(hit.queue_seconds, 0.0);
        assert_eq!(hit.service_seconds, 0.0);
        assert_eq!(hit.summary, cold.summary, "result scalars are bit-identical");
        assert_eq!(
            hit.fit.as_ref().unwrap().assignments,
            cold.fit.as_ref().unwrap().assignments
        );
        assert_eq!(
            hit.fit.as_ref().unwrap().centroids,
            cold.fit.as_ref().unwrap().centroids
        );
        assert_eq!(reg.counter(names::SERVE_CACHE_HITS).get(), 1);
        assert_eq!(reg.counter(names::SERVE_CACHE_MISSES).get(), 1);
    }

    #[test]
    fn cached_replies_do_not_reinsert_and_non_ok_never_enter() {
        let reg = Registry::new();
        let mut cache = ResultCache::new(4, &reg);
        let shed = FitResponse::shed(1, "queue full", 0.0);
        cache.insert(7, &shed);
        assert!(cache.is_empty(), "shed outcomes are not results");
        let mut replay = ok_resp(1);
        replay.cached = true;
        cache.insert(7, &replay);
        assert!(cache.is_empty(), "a cache hit must not re-seed the cache");
    }

    #[test]
    fn lru_eviction_is_bounded_and_counted() {
        let reg = Registry::new();
        let mut cache = ResultCache::new(2, &reg);
        let r = ok_resp(1);
        cache.insert(10, &r);
        cache.insert(20, &r);
        // Touch 10 so 20 becomes the LRU.
        let probe = FitRequest { id: 5, ..Default::default() };
        assert!(cache.lookup(10, &probe).is_some());
        cache.insert(30, &r);
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(20, &probe).is_none(), "LRU entry evicted");
        assert!(cache.lookup(10, &probe).is_some(), "recently used entry kept");
        assert_eq!(reg.counter(names::SERVE_CACHE_EVICTIONS).get(), 1);
    }

    #[test]
    fn clear_reports_the_drop_count_and_zero_capacity_disables() {
        let reg = Registry::new();
        let mut cache = ResultCache::new(4, &reg);
        let r = ok_resp(1);
        cache.insert(1, &r);
        cache.insert(2, &r);
        assert_eq!(cache.clear(), 2);
        assert!(cache.is_empty());
        assert_eq!(cache.clear(), 0);

        let mut off = ResultCache::new(0, &reg);
        assert!(!off.enabled());
        off.insert(1, &r);
        let probe = FitRequest::default();
        assert!(off.lookup(1, &probe).is_none());
        assert_eq!(off.len(), 0);
    }

    #[test]
    fn cache_json_shape() {
        let j = cache_json(3, 64, None);
        assert_eq!(j.get("op").unwrap().as_str().unwrap(), "cache");
        assert_eq!(j.get("size").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("capacity").unwrap().as_usize().unwrap(), 64);
        assert!(j.get("cleared").is_err());
        let c = cache_json(0, 64, Some(3));
        assert_eq!(c.get("cleared").unwrap().as_usize().unwrap(), 3);
    }
}
