//! The long-lived serving session: the shared core every front-end drives.
//!
//! PR 2's `Server::run` was batch-shaped — submit a finite job vector,
//! close, drain, report. A persistent daemon cannot work that way: jobs
//! arrive from many connections over an unbounded lifetime, and each
//! response must find its way back to the connection that submitted it.
//! [`ServeSession`] is the refactor that serves both shapes:
//!
//! * **One shared pool.** The session owns the admission queue and the
//!   sharded, engine-bank-owning worker pool for its whole lifetime, so
//!   engine construction / AOT compilation amortizes across *every*
//!   submitter — concurrent socket clients included — not just across the
//!   requests of one stdin stream (DESIGN.md §2).
//! * **Ticket-based response routing.** Client-chosen job ids are only
//!   unique per submitter (two socket clients may both send `id: 1`), so
//!   [`ServeSession::submit`] remaps each request onto a session-unique
//!   ticket, remembers `(ticket → client id, reply channel)`, and a router
//!   thread rewrites ids back as it delivers responses. Workers never see
//!   client ids.
//! * **Streaming accounting.** The router folds every response into a
//!   `report::ResponseAccumulator` as it passes through, so the session
//!   can report p50/p95 latency and per-backend utilization without
//!   retaining response history — a daemon may serve millions of jobs
//!   before [`ServeSession::shutdown`] builds the final [`ServeReport`].
//!
//! `Server::run` (batch mode) and `serve::net::Daemon` (socket mode) are
//! both thin front-ends over this type.
//!
//! ```no_run
//! use std::sync::mpsc;
//! use kpynq::serve::session::ServeSession;
//! use kpynq::serve::{FitRequest, ServeConfig};
//!
//! let session = ServeSession::start(ServeConfig::default()).unwrap();
//! let (tx, rx) = mpsc::channel();
//! session.submit(FitRequest { id: 7, max_points: 1_000, ..Default::default() }, &tx);
//! let resp = rx.recv().unwrap();
//! println!("job {} -> {}", resp.id, resp.status.name());
//! println!("{}", session.shutdown().render());
//! ```

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::driver::PartialFitState;
use crate::error::{Error, Result};
use crate::kmeans::reduce::{matrix_from_hex, matrix_to_hex, u32s_to_hex};
use crate::kmeans::Algorithm;
use crate::obs::metrics::names;
use crate::obs::profile::Phase;
use crate::obs::{mint_trace_id, Counter, Registry, SpanEvent, TraceRing};
use crate::util::json::Json;

use super::cache::{self, ResultCache};
use super::job::{FitRequest, FitResponse, JobStatus};
use super::queue::{QueueStats, SharedQueue, Submission};
use super::report::{ResponseAccumulator, ServeReport, TenantAcc, OVERFLOW_TENANT};
use super::worker::{self, WorkerStats};
use super::ServeConfig;

/// Where one in-flight job's response must be delivered.
struct Route {
    /// The id the submitter chose (restored onto the response).
    client_id: u64,
    reply: mpsc::Sender<FitResponse>,
    /// The request's tenant label (restored onto the response — workers
    /// never see tenants, exactly like client ids).
    tenant: String,
    /// The request fingerprint (PROTOCOL.md §8), when cacheable: the
    /// router stores the finished result under it.
    fingerprint: Option<u64>,
}

/// Tenants with a live `serve.queue.depth{tenant=…}` gauge, so drained
/// tenants are zeroed (not silently dropped) on the next snapshot, plus
/// whether the cardinality cap ever pushed depth into `~other`.
#[derive(Default)]
struct DepthSeries {
    tenants: std::collections::BTreeSet<String>,
    overflowed: bool,
}

/// A running serving pool: admission queue + sharded workers + response
/// router. Construct with [`ServeSession::start`], feed with
/// [`ServeSession::submit`], and finish with [`ServeSession::shutdown`]
/// (which drains queued work and returns the session [`ServeReport`]).
///
/// Dropping a session without calling `shutdown` closes the queue so the
/// worker threads exit on their own, but detaches them and loses the
/// report — front-ends should always shut down explicitly.
pub struct ServeSession {
    cfg: ServeConfig,
    queue: Arc<SharedQueue>,
    routes: Arc<Mutex<HashMap<u64, Route>>>,
    next_ticket: AtomicU64,
    /// `serve.jobs.submitted` — the session's submission count lives in
    /// the metrics registry, not a private atomic (`obs::metrics`).
    submitted: Counter,
    /// Feeds shed-at-admission responses through the router so they get
    /// the same id-restoration and accounting as worker responses.
    tx: Option<mpsc::Sender<FitResponse>>,
    workers: Vec<JoinHandle<WorkerStats>>,
    router: Option<JoinHandle<ResponseAccumulator>>,
    started: Instant,
    /// Per-session metrics registry: two daemons in one process (tests,
    /// a cluster front with an embedded shard) must not merge counters.
    registry: Arc<Registry>,
    /// Per-session trace span ring (PROTOCOL.md §11).
    ring: Arc<TraceRing>,
    /// Per-tenant accounting table, fed by the router as responses pass
    /// through (the `tenants` object of the `stats` reply, PROTOCOL.md §6).
    /// Capped at `max_tracked_tenants` distinct tenants; overflow lands
    /// in the [`OVERFLOW_TENANT`] bucket (PROTOCOL.md §3).
    tenants: Arc<Mutex<BTreeMap<String, TenantAcc>>>,
    /// Fingerprint-keyed result cache (PROTOCOL.md §8), consulted before
    /// admission and fed by the router.
    cache: Arc<Mutex<ResultCache>>,
    /// Tenants currently carrying a `serve.queue.depth{tenant=…}` gauge.
    depth_series: Mutex<DepthSeries>,
}

impl ServeSession {
    /// Validate the config, spin up the worker shards and the response
    /// router, and return the live session.
    pub fn start(cfg: ServeConfig) -> Result<ServeSession> {
        cfg.validate()?;
        let queue = Arc::new(SharedQueue::with_fair(cfg.queue_capacity, cfg.fair()));
        let routes: Arc<Mutex<HashMap<u64, Route>>> = Arc::new(Mutex::new(HashMap::new()));
        let registry = Arc::new(Registry::new());
        let cache = Arc::new(Mutex::new(ResultCache::new(cfg.cache_capacity, &registry)));
        let ring = Arc::new(TraceRing::default());
        let (tx, rx) = mpsc::channel::<FitResponse>();
        let workers = (0..cfg.workers)
            .map(|w| {
                let cfg = cfg.clone();
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let ring = Arc::clone(&ring);
                std::thread::spawn(move || worker::run_worker(w, &cfg, &queue, &tx, &ring))
            })
            .collect();
        let tenants: Arc<Mutex<BTreeMap<String, TenantAcc>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let router = {
            let routes = Arc::clone(&routes);
            let ring = Arc::clone(&ring);
            let registry = Arc::clone(&registry);
            let tenants = Arc::clone(&tenants);
            let cache = Arc::clone(&cache);
            let max_tracked = cfg.max_tracked_tenants;
            std::thread::spawn(move || {
                route_responses(rx, &routes, &ring, &registry, &tenants, &cache, max_tracked)
            })
        };
        Ok(ServeSession {
            cfg,
            queue,
            routes,
            next_ticket: AtomicU64::new(1),
            submitted: registry.counter(names::SERVE_JOBS_SUBMITTED),
            tx: Some(tx),
            workers,
            router: Some(router),
            started: Instant::now(),
            registry,
            ring,
            tenants,
            cache,
            depth_series: Mutex::new(DepthSeries::default()),
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Jobs submitted so far (admitted or shed — every one gets exactly
    /// one response).
    pub fn submitted(&self) -> u64 {
        self.submitted.get()
    }

    /// Milliseconds since the session started — the `uptime_ms` field of
    /// the `stats` control frame (PROTOCOL.md §6).
    pub fn uptime_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Per-priority-lane queue depths (high, normal, low) — the
    /// `queue_lanes` field of the `stats` control frame (PROTOCOL.md §6).
    pub fn lane_depths(&self) -> [usize; crate::serve::Priority::LEVELS] {
        self.queue.lane_depths()
    }

    /// Snapshot the session's metrics registry as JSON, after syncing the
    /// queue's mutex-guarded counters into it (the queue stays a pure
    /// deterministic structure; the registry mirrors it at read time).
    pub fn metrics(&self) -> Json {
        let stats = self.queue.stats();
        self.registry.gauge(names::SERVE_QUEUE_DEPTH).set(self.queue.depth() as i64);
        self.registry
            .gauge(names::SERVE_QUEUE_PEAK_DEPTH)
            .set_max(stats.peak_depth as i64);
        let shed_full = self.registry.counter(names::SERVE_QUEUE_SHED_FULL);
        shed_full.add(stats.shed_full.saturating_sub(shed_full.get()));
        let shed_deadline = self.registry.counter(names::SERVE_QUEUE_SHED_DEADLINE);
        shed_deadline.add(stats.shed_deadline.saturating_sub(shed_deadline.get()));
        // Per-tenant queue depth (`serve.queue.depth{tenant=…}`,
        // PROTOCOL.md §6/§11), capped like the accounting table: past
        // `max_tracked_tenants` distinct series, further tenants aggregate
        // into `~other`. Tenants that drained since the last snapshot are
        // zeroed, not dropped, so scrapes watch the queue empty out.
        {
            let depths = self.queue.tenant_depths();
            let mut series = self.depth_series.lock().expect("depth series poisoned");
            let mut overflow = 0usize;
            for (t, n) in &depths {
                if series.tenants.contains(t)
                    || series.tenants.len() < self.cfg.max_tracked_tenants
                {
                    series.tenants.insert(t.clone());
                    self.registry
                        .gauge_with(names::SERVE_QUEUE_DEPTH, &[("tenant", t)])
                        .set(*n as i64);
                } else {
                    overflow += *n;
                }
            }
            for t in &series.tenants {
                if !depths.contains_key(t) {
                    self.registry
                        .gauge_with(names::SERVE_QUEUE_DEPTH, &[("tenant", t)])
                        .set(0);
                }
            }
            if overflow > 0 {
                series.overflowed = true;
            }
            if series.overflowed {
                self.registry
                    .gauge_with(names::SERVE_QUEUE_DEPTH, &[("tenant", OVERFLOW_TENANT)])
                    .set(overflow as i64);
            }
        }
        self.registry.snapshot()
    }

    /// The session's metrics registry (tests; embedding fronts).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The session's trace ring.
    pub fn trace_ring(&self) -> &Arc<TraceRing> {
        &self.ring
    }

    /// Drain the trace ring into the `{"op":"trace"}` reply shape
    /// (PROTOCOL.md §11). Destructive — events deliver exactly once.
    pub fn drain_trace(&self) -> Json {
        self.ring.drain_json()
    }

    /// Non-destructive snapshot of the trace ring — the `{"op":"trace",
    /// "peek":true}` form (PROTOCOL.md §11). Dashboards poll with this so
    /// they never race a log shipper for the exactly-once drain.
    pub fn peek_trace(&self) -> Json {
        self.ring.peek_json()
    }

    /// Per-tenant rollups (answered / shed / p50 / p95 / queued) for the
    /// `tenants` object of the `stats` reply (PROTOCOL.md §6). Queue
    /// depths merge in live, so a tenant whose first job is still queued
    /// already shows up with `queued` > 0.
    pub fn tenants_json(&self) -> Json {
        super::report::tenants_json_with_queue(
            &self.tenants.lock().expect("tenant table poisoned"),
            &self.queue.tenant_depths(),
        )
    }

    /// Handle the `{"op":"cache"}` control frame (PROTOCOL.md §6):
    /// report the result cache's size/capacity, clearing it first when
    /// `clear` is set.
    pub fn cache_control(&self, clear: bool) -> Json {
        let mut c = self.cache.lock().expect("result cache poisoned");
        let cleared = clear.then(|| c.clear());
        cache::cache_json(c.len(), c.capacity(), cleared)
    }

    /// Live snapshot of the admission queue's counters (the `stats`
    /// control frame surfaces this on the wire — PROTOCOL.md §6).
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Jobs currently sitting in the admission queue — the `queue_depth`
    /// field of the `stats` control frame (PROTOCOL.md §6), and the load
    /// signal the cluster router's least-loaded policy reads.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Submit one job. The response — `ok`, `failed` or `shed` — arrives
    /// on `reply` with the request's own id restored. Returns the
    /// session-unique ticket the job runs under: the handle
    /// [`ServeSession::cancel`] takes (jobs shed at admission still get a
    /// ticket; their shed response is already on its way). Blocks only
    /// under `ShedPolicy::Block` with a full queue — this is the
    /// backpressure a socket connection propagates to its client
    /// (DESIGN.md §2).
    pub fn submit(&self, req: FitRequest, reply: &mpsc::Sender<FitResponse>) -> u64 {
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let client_id = req.id;
        self.submitted.inc();
        let fingerprint = cache::fingerprint_of(&req);
        self.routes.lock().expect("route map poisoned").insert(
            ticket,
            Route {
                client_id,
                reply: reply.clone(),
                tenant: req.tenant.clone(),
                fingerprint,
            },
        );
        let mut req = req;
        req.id = ticket;
        // Every admitted job runs under a trace id (PROTOCOL.md §11): the
        // client's own when supplied, else one minted here.
        if req.trace_id.is_empty() {
            req.trace_id = mint_trace_id();
        }
        self.ring.push(
            SpanEvent::new(&req.trace_id, "admit")
                .num("id", client_id as f64)
                .num("ticket", ticket as f64),
        );
        // Result cache (PROTOCOL.md §8): a hit replays the finished reply
        // without touching the queue — it still flows through the router,
        // so id restoration, accounting and tracing are identical to a
        // computed response.
        if let Some(fp) = fingerprint {
            let hit = self
                .cache
                .lock()
                .expect("result cache poisoned")
                .lookup(fp, &req);
            if let Some(resp) = hit {
                let tx = self.tx.as_ref().expect("session is live until shutdown");
                let _ = tx.send(resp);
                return ticket;
            }
        }
        if let Submission::Shed { req, reason, waited_seconds } =
            self.queue.submit(req, self.cfg.shed_policy)
        {
            // Route the shed response like any other so the submitter
            // sees its own id and the accumulator counts the shed.
            let tx = self.tx.as_ref().expect("session is live until shutdown");
            let mut resp = FitResponse::shed(req.id, reason, waited_seconds);
            resp.trace_id = req.trace_id;
            let _ = tx.send(resp);
        }
        ticket
    }

    /// Cancel a submitted job by its ticket (PROTOCOL.md §6 `cancel`):
    /// if the job is still queued it is removed — never executed — and
    /// its single response is routed as `status:"shed"`,
    /// `detail:"cancelled by client"`. Returns `false` when the ticket's
    /// job already started executing, already answered, or never existed;
    /// whatever response it owes (if any) arrives unchanged. Either way
    /// the per-job exactly-one-response invariant holds.
    pub fn cancel(&self, ticket: u64) -> bool {
        match self.queue.remove(ticket) {
            Some(p) => {
                let tx = self.tx.as_ref().expect("session is live until shutdown");
                let mut resp =
                    FitResponse::shed(ticket, "cancelled by client", p.queue_seconds());
                resp.trace_id = p.req.trace_id;
                let _ = tx.send(resp);
                true
            }
            None => false,
        }
    }

    /// Stop admitting, drain queued work, join the pool and the router,
    /// and aggregate the session's [`ServeReport`]. In-flight jobs still
    /// deliver their responses before this returns.
    pub fn shutdown(mut self) -> ServeReport {
        self.queue.close();
        let mut worker_stats = Vec::with_capacity(self.workers.len());
        for h in self.workers.drain(..) {
            worker_stats.push(h.join().expect("serve worker panicked"));
        }
        // Workers are done sending; dropping our feeder disconnects the
        // router's channel once the last queued response is delivered.
        drop(self.tx.take());
        let acc = self
            .router
            .take()
            .expect("shutdown is called at most once")
            .join()
            .expect("serve router panicked");
        acc.into_report(
            self.submitted.get(),
            &worker_stats,
            self.queue.stats(),
            self.started.elapsed().as_secs_f64(),
        )
    }
}

impl Drop for ServeSession {
    fn drop(&mut self) {
        // `shutdown` drains `workers` and takes `router`; if the session
        // is dropped without it, closing the queue lets the (now detached)
        // worker threads exit instead of blocking forever on the condvar.
        self.queue.close();
    }
}

/// Router main loop: restore client ids and tenants, deliver, accumulate.
/// Responses whose submitter has gone (a disconnected socket client) are
/// counted, not delivered — the job's engine time was already spent.
/// Every response also feeds the latency histograms (plus tenant-labeled
/// and phase-labeled series when applicable) and closes its trace with a
/// `reply` span (PROTOCOL.md §11).
fn route_responses(
    rx: mpsc::Receiver<FitResponse>,
    routes: &Mutex<HashMap<u64, Route>>,
    ring: &TraceRing,
    registry: &Registry,
    tenants: &Mutex<BTreeMap<String, TenantAcc>>,
    cache: &Mutex<ResultCache>,
    max_tracked_tenants: usize,
) -> ResponseAccumulator {
    let queue_wait_ms = registry.histogram(names::SERVE_QUEUE_WAIT_MS);
    let latency_ms = registry.histogram(names::SERVE_LATENCY_MS);
    let mut acc = ResponseAccumulator::default();
    for mut resp in rx {
        acc.observe(&resp);
        queue_wait_ms.record_ms(resp.queue_seconds * 1e3);
        latency_ms.record_ms(resp.latency_seconds() * 1e3);
        // Per-phase solver timings → `fit.phase_ms{phase=…}`. Present only
        // on runs with profiling enabled, so this path costs nothing when
        // the timers are off.
        if let Some(p) = resp.summary.as_ref().and_then(|s| s.phases) {
            for ph in Phase::ALL {
                registry
                    .histogram_with(names::FIT_PHASE_MS, &[("phase", ph.name())])
                    .record_ms(p.get(ph));
            }
        }
        let route = routes.lock().expect("route map poisoned").remove(&resp.id);
        if !resp.trace_id.is_empty() {
            ring.push(
                SpanEvent::new(&resp.trace_id, "reply")
                    .num("ticket", resp.id as f64)
                    .attr("status", Json::Str(resp.status.name().into()))
                    .num("latency_ms", resp.latency_seconds() * 1e3),
            );
        }
        match route {
            Some(Route { client_id, reply, tenant, fingerprint }) => {
                resp.id = client_id;
                resp.tenant = tenant;
                // Seed the result cache with freshly computed successes
                // (replayed hits never re-insert — `ResultCache::insert`
                // skips `cached` responses).
                if let Some(fp) = fingerprint {
                    if resp.status == JobStatus::Ok {
                        cache.lock().expect("result cache poisoned").insert(fp, &resp);
                    }
                }
                if !resp.tenant.is_empty() {
                    // Cardinality cap (PROTOCOL.md §3): once the table
                    // holds `max_tracked_tenants` distinct tenants, new
                    // ones roll up into `~other` — series and table agree.
                    let label = {
                        let table = tenants.lock().expect("tenant table poisoned");
                        if table.contains_key(&resp.tenant)
                            || table.len() < max_tracked_tenants
                        {
                            resp.tenant.clone()
                        } else {
                            OVERFLOW_TENANT.to_string()
                        }
                    };
                    let t = label.as_str();
                    registry
                        .histogram_with(names::SERVE_LATENCY_MS, &[("tenant", t)])
                        .record_ms(resp.latency_seconds() * 1e3);
                    if resp.status == JobStatus::Shed {
                        let name = if resp.detail.contains("deadline") {
                            names::SERVE_QUEUE_SHED_DEADLINE
                        } else {
                            names::SERVE_QUEUE_SHED_FULL
                        };
                        registry.counter_with(name, &[("tenant", t)]).inc();
                    }
                    tenants
                        .lock()
                        .expect("tenant table poisoned")
                        .entry(label)
                        .or_default()
                        .observe(&resp);
                }
                if reply.send(resp).is_err() {
                    acc.count_dropped_reply();
                }
            }
            // Unroutable: every submission registers its route before the
            // queue can pop it, so this indicates a front-end bug.
            None => acc.count_dropped_reply(),
        }
    }
    acc
}

/// Per-connection state for map-reduce fits (PROTOCOL.md §10): the
/// `partial_fit` / `centroid_sync` op pair, shared verbatim by the real
/// daemon (`serve::net`) and the test fake shard so conformance vectors
/// exercise one implementation. Unlike regular jobs, partial fits are
/// *not* routed through the worker pool — each `partial_fit` owns a
/// [`PartialFitState`] that lives on the connection that created it and
/// computes inline on the connection's reader thread, so a sync request
/// blocks only its own fit (the front drives every shard concurrently).
///
/// Callers wrap the `Err` of either method into a §5 error reply; the
/// connection survives, and the fit state is untouched by a rejected
/// frame (epoch-mismatch syncs in particular leave the shard replayable).
pub struct PartialSession {
    fits: HashMap<u64, PartialFitState>,
}

impl Default for PartialSession {
    fn default() -> Self {
        Self::new()
    }
}

impl PartialSession {
    pub fn new() -> PartialSession {
        PartialSession { fits: HashMap::new() }
    }

    /// Number of live partial fits on this connection.
    pub fn live(&self) -> usize {
        self.fits.len()
    }

    /// Handle a `partial_fit` frame (PROTOCOL.md §10): a §3 job
    /// description plus `algorithm` / `shard_index` / `shard_count` and an
    /// optional `history` of already-reduced centroid sets. Loads the
    /// dataset, runs assignment pass 1 over this shard's slice, replays
    /// the history (making re-dispatch after shard loss idempotent by
    /// epoch), and replies with the current `partial` frame — `init`
    /// included so the front learns `c_0` without loading the dataset.
    pub fn partial_fit(&mut self, frame: &Json) -> Result<Json> {
        let id = frame.get("id")?.as_usize()? as u64;
        if self.fits.contains_key(&id) {
            return Err(Error::Parse(format!("partial fit id {id} already live")));
        }
        let algo_name = match frame.get("algorithm") {
            Ok(v) => v.as_str()?.to_string(),
            Err(_) => "yinyang".to_string(),
        };
        let algo = Algorithm::from_name(&algo_name)?;
        let shard_index = frame.get("shard_index")?.as_usize()?;
        let shard_count = frame.get("shard_count")?.as_usize()?;
        let history = match frame.get("history") {
            Ok(v) => v.as_str()?.to_string(),
            Err(_) => String::new(),
        };
        let req = FitRequest::from_json_ignoring(
            frame,
            &["op", "algorithm", "shard_index", "shard_count", "history"],
        )?;
        let ds = req.load_dataset()?;
        let mut st = PartialFitState::new(algo, ds, req.kmeans.clone(), shard_index, shard_count)?;
        // Replay: each history entry is one reduced k×d centroid set,
        // k·d·8 hex chars, oldest first.
        let chunk = st.k() * st.d() * 8;
        if history.len() % chunk != 0 {
            return Err(Error::Parse(format!(
                "history length {} is not a multiple of one {}x{} centroid set ({chunk} hex chars)",
                history.len(),
                st.k(),
                st.d()
            )));
        }
        for entry in 0..history.len() / chunk {
            let m = matrix_from_hex(&history[entry * chunk..(entry + 1) * chunk], st.k(), st.d())?;
            st.apply_sync(&m)?;
        }
        let reply = partial_reply(id, &mut st, true);
        self.fits.insert(id, st);
        Ok(reply)
    }

    /// Handle a `centroid_sync` frame (PROTOCOL.md §10): the front's
    /// reduced centroids for the epoch the shard just reported. `done:
    /// false` advances the fit one assignment pass and replies with the
    /// next `partial`; `done: true` seals it — the shard computes its
    /// slice's exact inertia against the final centroids (no
    /// reassignment), replies `partial_done`, and forgets the fit.
    pub fn centroid_sync(&mut self, frame: &Json) -> Result<Json> {
        let id = frame.get("id")?.as_usize()? as u64;
        let epoch = frame.get("epoch")?.as_usize()?;
        let hex = frame.get("centroids")?.as_str()?;
        let done = matches!(frame.get("done"), Ok(Json::Bool(true)));
        let st = self
            .fits
            .get_mut(&id)
            .ok_or_else(|| Error::Parse(format!("unknown partial fit id {id}")))?;
        if epoch != st.epoch() {
            return Err(Error::Parse(format!(
                "centroid_sync epoch {epoch}, shard is at epoch {}",
                st.epoch()
            )));
        }
        let m = matrix_from_hex(hex, st.k(), st.d())?;
        if done {
            let (assignments, inertia) = st.finish(&m)?;
            let (lo, hi) = st.slice();
            let shard_index = st.shard_index();
            self.fits.remove(&id);
            let mut out = std::collections::BTreeMap::new();
            out.insert("op".into(), Json::Str("partial_done".into()));
            out.insert("id".into(), Json::Num(id as f64));
            out.insert("shard_index".into(), Json::Num(shard_index as f64));
            out.insert("lo".into(), Json::Num(lo as f64));
            out.insert("hi".into(), Json::Num(hi as f64));
            out.insert("assignments".into(), Json::Str(u32s_to_hex(&assignments)));
            out.insert("inertia".into(), Json::Str(inertia.to_hex()));
            Ok(Json::Obj(out))
        } else {
            st.apply_sync(&m)?;
            Ok(partial_reply(id, st, false))
        }
    }
}

/// Build a `partial` reply frame (PROTOCOL.md §10) for the fit's current
/// epoch. `include_init` is set only when answering `partial_fit`.
fn partial_reply(id: u64, st: &mut PartialFitState, include_init: bool) -> Json {
    let acc = st.partial();
    let mut m = std::collections::BTreeMap::new();
    m.insert("op".into(), Json::Str("partial".into()));
    m.insert("id".into(), Json::Num(id as f64));
    m.insert("epoch".into(), Json::Num(st.epoch() as f64));
    m.insert("shard_index".into(), Json::Num(st.shard_index() as f64));
    m.insert("d".into(), Json::Num(st.d() as f64));
    m.insert(
        "counts".into(),
        Json::Arr(acc.counts().iter().map(|&c| Json::Num(c as f64)).collect()),
    );
    m.insert("sums".into(), Json::Str(acc.sums_hex()));
    if include_init {
        m.insert("init".into(), Json::Str(matrix_to_hex(st.init_centroids())));
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::KMeansConfig;
    use crate::serve::JobStatus;

    fn job(id: u64, seed: u64) -> FitRequest {
        FitRequest {
            id,
            max_points: 400,
            kmeans: KMeansConfig { k: 3, seed, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn colliding_client_ids_route_to_their_own_submitters() {
        // Two "connections" both submit id 5 — the daemon's routing
        // problem in miniature. Each reply channel must get exactly one
        // response, with id 5 restored, carrying its own clustering.
        let session = ServeSession::start(ServeConfig { workers: 2, ..Default::default() })
            .unwrap();
        let (tx_a, rx_a) = mpsc::channel();
        let (tx_b, rx_b) = mpsc::channel();
        session.submit(job(5, 111), &tx_a);
        session.submit(job(5, 222), &tx_b);
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.id, 5);
        assert_eq!(b.id, 5);
        assert_eq!(a.status, JobStatus::Ok, "{}", a.detail);
        assert_eq!(b.status, JobStatus::Ok, "{}", b.detail);
        // Different seeds → different clusterings: proof the responses
        // were not cross-delivered.
        assert_ne!(
            a.fit.as_ref().unwrap().assignments,
            b.fit.as_ref().unwrap().assignments
        );
        assert!(rx_a.try_recv().is_err(), "exactly one response per submitter");
        let report = session.shutdown();
        assert_eq!(report.submitted, 2);
        assert_eq!(report.completed, 2);
        assert_eq!(report.dropped_replies, 0);
    }

    #[test]
    fn responses_to_departed_submitters_are_counted_not_lost() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        drop(rx); // the "connection" goes away before its job completes
        session.submit(job(1, 7), &tx);
        let report = session.shutdown();
        assert_eq!(report.completed, 1, "the job still ran");
        assert_eq!(report.dropped_replies, 1, "...but had nowhere to go");
    }

    #[test]
    fn shed_at_admission_is_routed_with_the_client_id() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut dead = job(42, 1);
        dead.deadline_ms = Some(0); // sheds at pop, inside the session
        session.submit(dead, &tx);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.status, JobStatus::Shed);
        let report = session.shutdown();
        assert_eq!(report.shed, 1);
    }

    #[test]
    fn cancel_removes_a_queued_job_and_routes_its_shed_reply() {
        // One worker, no coalescing: the first (heavy) job occupies the
        // worker while the second waits in the queue — cancellable.
        let session = ServeSession::start(ServeConfig {
            workers: 1,
            max_batch: 1,
            ..Default::default()
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut heavy = job(1, 11);
        heavy.max_points = 4_000;
        heavy.kmeans.k = 8;
        session.submit(heavy, &tx);
        let ticket2 = session.submit(job(2, 22), &tx);
        assert!(session.cancel(ticket2), "job 2 had not started executing");
        assert!(!session.cancel(ticket2), "a second cancel finds nothing");
        assert!(!session.cancel(9_999), "unknown tickets cancel nothing");
        let mut by_id = std::collections::HashMap::new();
        for _ in 0..2 {
            let r = rx.recv().unwrap();
            by_id.insert(r.id, r);
        }
        assert_eq!(by_id[&1].status, JobStatus::Ok, "{}", by_id[&1].detail);
        assert_eq!(by_id[&2].status, JobStatus::Shed);
        assert!(by_id[&2].detail.contains("cancelled"), "{}", by_id[&2].detail);
        let report = session.shutdown();
        assert_eq!(report.submitted, 2);
        assert_eq!(report.completed, 1);
        assert_eq!(report.shed, 1);
    }

    #[test]
    fn a_served_job_leaves_a_full_span_chain_and_metrics() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut traced = job(9, 5);
        traced.trace_id = "00000000deadbeef".into();
        session.submit(traced, &tx);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.status, JobStatus::Ok, "{}", resp.detail);
        assert_eq!(resp.trace_id, "00000000deadbeef", "client trace ids echo verbatim");

        // Metrics: the submission counter and both latency histograms
        // (fed by the router before it delivered our response).
        let m = session.metrics();
        let counters = m.get("counters").unwrap();
        assert_eq!(
            counters.get("serve.jobs.submitted").unwrap().as_usize().unwrap(),
            1
        );
        let hists = m.get("histograms").unwrap();
        for name in ["serve.queue_wait_ms", "serve.latency_ms"] {
            let h = hists.get(name).unwrap();
            assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1, "{name}");
        }
        assert!(m.get("gauges").unwrap().get("serve.queue.depth").is_ok());

        // Trace: one chain, in causal order, under the client's id.
        let drained = session.drain_trace();
        let events = drained.get("events").unwrap().as_arr().unwrap();
        let names: Vec<&str> = events
            .iter()
            .map(|e| e.get("event").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["admit", "queue-wait", "dispatch", "reply"]);
        for e in events {
            assert_eq!(e.get("trace_id").unwrap().as_str().unwrap(), "00000000deadbeef");
        }
        // Draining is destructive; a fresh drain is empty.
        assert!(session.drain_trace().get("events").unwrap().as_arr().unwrap().is_empty());
        session.shutdown();
    }

    #[test]
    fn tenanted_jobs_roll_up_into_labeled_series_and_the_tenant_table() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut tenanted = job(9, 5);
        tenanted.tenant = "acme".into();
        session.submit(tenanted, &tx);
        session.submit(job(10, 6), &tx); // anonymous traffic stays unlabeled
        let mut by_id = std::collections::HashMap::new();
        for _ in 0..2 {
            let r = rx.recv().unwrap();
            by_id.insert(r.id, r);
        }
        assert_eq!(by_id[&9].status, JobStatus::Ok, "{}", by_id[&9].detail);
        assert_eq!(by_id[&9].tenant, "acme", "the router restores the tenant label");
        assert!(by_id[&10].tenant.is_empty());

        let t = session.tenants_json();
        let acme = t.get("acme").unwrap();
        assert_eq!(acme.get("answered").unwrap().as_usize().unwrap(), 1);
        assert_eq!(acme.get("shed").unwrap().as_usize().unwrap(), 0);
        assert!(acme.get("p95_ms").unwrap().as_f64().unwrap() >= 0.0);

        let m = session.metrics();
        let hists = m.get("histograms").unwrap();
        let labeled = hists.get("serve.latency_ms{tenant=\"acme\"}").unwrap();
        assert_eq!(labeled.get("count").unwrap().as_usize().unwrap(), 1);
        // The unlabeled series counts ALL traffic, tenanted or not.
        let total = hists.get("serve.latency_ms").unwrap();
        assert_eq!(total.get("count").unwrap().as_usize().unwrap(), 2);
        session.shutdown();
    }

    #[test]
    fn peeking_the_trace_ring_is_not_destructive() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        session.submit(job(1, 3), &tx);
        rx.recv().unwrap();
        let peeked = session.peek_trace();
        let n = peeked.get("events").unwrap().as_arr().unwrap().len();
        assert!(n >= 2, "admit + reply at minimum, got {n}");
        // Peek again: same events still there. Then drain: ring empties.
        assert_eq!(
            session.peek_trace().get("events").unwrap().as_arr().unwrap().len(),
            n
        );
        assert_eq!(session.drain_trace().get("events").unwrap().as_arr().unwrap().len(), n);
        assert!(session.peek_trace().get("events").unwrap().as_arr().unwrap().is_empty());
        session.shutdown();
    }

    #[test]
    fn untraced_submissions_get_a_minted_trace_id() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        session.submit(job(1, 3), &tx);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.trace_id.len(), 16, "the front mints when the client doesn't");
        assert!(resp.trace_id.chars().all(|c| c.is_ascii_hexdigit()));
        session.shutdown();
    }

    #[test]
    fn cache_hit_replays_identical_bits_under_the_new_identity() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        session.submit(job(1, 5), &tx);
        let cold = rx.recv().unwrap();
        assert_eq!(cold.status, JobStatus::Ok, "{}", cold.detail);
        assert!(!cold.cached, "the first computation is not a replay");
        // Same request parameters, different id: the scheduling identity
        // is outside the fingerprint (PROTOCOL.md §8), so this hits.
        session.submit(job(2, 5), &tx);
        let hit = rx.recv().unwrap();
        assert_eq!(hit.id, 2, "replayed under the submitter's id");
        assert!(hit.cached, "the replay is marked");
        let (a, b) = (cold.fit.as_ref().unwrap(), hit.fit.as_ref().unwrap());
        assert_eq!(a.assignments, b.assignments, "bit-identical clustering");
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(
            cold.summary.as_ref().unwrap().inertia,
            hit.summary.as_ref().unwrap().inertia
        );
        let m = session.metrics();
        let counters = m.get("counters").unwrap();
        assert_eq!(counters.get("serve.cache.hits").unwrap().as_usize().unwrap(), 1);
        assert_eq!(counters.get("serve.cache.misses").unwrap().as_usize().unwrap(), 1);
        // A hit never touches the queue but still routes + accounts.
        let report = session.shutdown();
        assert_eq!(report.submitted, 2);
        assert_eq!(report.completed, 2);
    }

    #[test]
    fn cache_control_reports_and_clears() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        session.submit(job(1, 5), &tx);
        rx.recv().unwrap();
        let peek = session.cache_control(false);
        assert_eq!(peek.get("size").unwrap().as_usize().unwrap(), 1);
        assert!(peek.get("cleared").is_err(), "no cleared key without clear");
        let cleared = session.cache_control(true);
        assert_eq!(cleared.get("cleared").unwrap().as_usize().unwrap(), 1);
        assert_eq!(cleared.get("size").unwrap().as_usize().unwrap(), 0);
        // Post-clear, the same request recomputes (a miss).
        session.submit(job(2, 5), &tx);
        let resp = rx.recv().unwrap();
        assert!(!resp.cached);
        session.shutdown();
    }

    #[test]
    fn cancel_after_completion_is_a_no_op_with_one_terminal_reply() {
        // Regression (the cancel/completion race): cancelling a ticket
        // whose job already answered must return false and must NOT
        // produce a second reply.
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        let ticket = session.submit(job(1, 5), &tx);
        let first = rx.recv().unwrap();
        assert_eq!(first.status, JobStatus::Ok, "{}", first.detail);
        assert!(!session.cancel(ticket), "the job already answered");
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "exactly one terminal reply per job"
        );
        let report = session.shutdown();
        assert_eq!(report.completed, 1);
        assert_eq!(report.shed, 0);
    }

    #[test]
    fn tenant_cardinality_is_capped_into_the_overflow_bucket() {
        let session = ServeSession::start(ServeConfig {
            workers: 1,
            max_tracked_tenants: 2,
            ..Default::default()
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();
        for (i, t) in ["alpha", "beta", "gamma", "delta"].iter().enumerate() {
            let mut r = job(i as u64 + 1, i as u64 + 1);
            r.tenant = (*t).into();
            session.submit(r, &tx);
            // Serialize so the table fills deterministically (alpha, beta
            // tracked; gamma, delta overflow).
            rx.recv().unwrap();
        }
        let t = session.tenants_json();
        assert!(t.get("alpha").is_ok());
        assert!(t.get("beta").is_ok());
        assert!(t.get("gamma").is_err(), "third tenant rolls into ~other");
        let other = t.get("~other").unwrap();
        assert_eq!(other.get("answered").unwrap().as_usize().unwrap(), 2);
        session.shutdown();
    }

    #[test]
    fn tenant_queue_depth_gauges_appear_and_zero_after_drain() {
        let session = ServeSession::start(ServeConfig { workers: 1, ..Default::default() })
            .unwrap();
        let (tx, rx) = mpsc::channel();
        let mut r = job(1, 9);
        r.tenant = "acme".into();
        session.submit(r, &tx);
        rx.recv().unwrap();
        // The job drained before this snapshot; the series may simply not
        // exist yet (depth observed only at snapshot time) — but once a
        // tenant HAS been seen queued, later snapshots zero it. Force the
        // "seen" path by snapshotting while a job is queued.
        let mut slow = job(2, 10);
        slow.tenant = "acme".into();
        slow.max_points = 4_000;
        slow.kmeans.k = 8;
        session.submit(slow, &tx); // occupies the worker
        let mut queued = job(3, 11);
        queued.tenant = "acme".into();
        session.submit(queued, &tx);
        let m = session.metrics();
        let gauges = m.get("gauges").unwrap();
        if let Ok(g) = gauges.get("serve.queue.depth{tenant=\"acme\"}") {
            assert!(g.as_usize().unwrap() <= 2);
        }
        rx.recv().unwrap();
        rx.recv().unwrap();
        let m = session.metrics();
        let gauges = m.get("gauges").unwrap();
        if let Ok(g) = gauges.get("serve.queue.depth{tenant=\"acme\"}") {
            assert_eq!(g.as_usize().unwrap(), 0, "drained tenants zero, not vanish");
        }
        session.shutdown();
    }

    #[test]
    fn idle_session_reports_cleanly() {
        let session = ServeSession::start(ServeConfig::default()).unwrap();
        let report = session.shutdown();
        assert_eq!(report.submitted, 0);
        assert_eq!(report.completed, 0);
        assert_eq!(report.p50_latency_ms, 0.0, "idle window must not leak NaN");
        assert_eq!(report.workers, 2);
    }
}
