//! The KPynq system layer: what the PS-side host does.
//!
//! In the paper a Python program on the ARM PS "is responsible for invoking
//! the PL part hardware accelerator and initiate the DMA data transfer".
//! Here the host is Rust, and it drives one of three backends:
//!
//! * [`Backend::SimulatedFpga`] — the cycle-approximate Zynq accelerator
//!   (`hw::Accelerator`): the paper's system, timing and all.
//! * [`Backend::Native`] — filtering on the host + dense survivor tiles on
//!   the in-process Rust engine. This is the measured (wall-clock) hot
//!   path that the §Perf pass optimises.
//! * [`Backend::Xla`] — same scheduling, but tiles execute on the
//!   AOT-compiled Pallas kernel through PJRT (`runtime::xla`) — the
//!   TPU-adaptation path of DESIGN.md §Hardware-Adaptation, Python-free
//!   at run time.
//!
//! All three produce identical clusterings for the same seed (asserted by
//! the `coordinator_equivalence` integration tests): filters are
//! conservative and distances tie-break identically everywhere.

pub mod buffer;
pub mod driver;
pub mod scheduler;
pub mod telemetry;

use std::path::PathBuf;

use crate::data::Dataset;
use crate::error::Result;
use crate::hw::AccelConfig;
use crate::kmeans::{FitResult, KMeansConfig};

pub use telemetry::RunReport;

/// Which execution backend the system drives.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Cycle-approximate Zynq accelerator simulation.
    SimulatedFpga(Box<AccelConfig>),
    /// Host filtering + native Rust tile engine (measured wall-clock).
    Native,
    /// Host filtering + AOT Pallas/XLA tile engine (measured wall-clock).
    /// Needs the `xla` cargo feature and built artifacts (`make
    /// artifacts`); without the feature, selecting this backend fails with
    /// a descriptive `Error::Xla` at engine construction.
    Xla { artifact_dir: PathBuf },
}

impl Default for Backend {
    fn default() -> Self {
        Backend::SimulatedFpga(Box::new(AccelConfig::default()))
    }
}

/// System-level configuration.
#[derive(Clone, Debug, Default)]
pub struct SystemConfig {
    pub backend: Backend,
    /// Verify the final clustering against a direct Lloyd run (slow; used
    /// by examples and tests, not benchmarks).
    pub verify: bool,
}

/// A fit plus the system-level report.
#[derive(Clone, Debug)]
pub struct SystemOutput {
    pub fit: FitResult,
    pub report: RunReport,
}

/// The KPynq system.
pub struct KpynqSystem {
    cfg: SystemConfig,
}

impl KpynqSystem {
    pub fn new(cfg: SystemConfig) -> Result<Self> {
        Ok(Self { cfg })
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Cluster a dataset. Initialisation is deterministic in
    /// `kcfg.seed`, so any backend (and the pure-software algorithms)
    /// started from the same config agree exactly.
    pub fn cluster(&self, ds: &Dataset, kcfg: &KMeansConfig) -> Result<SystemOutput> {
        let out = driver::run(&self.cfg, ds, kcfg)?;
        if self.cfg.verify {
            let direct = crate::kmeans::fit(crate::kmeans::Algorithm::Lloyd, ds, kcfg)?;
            if direct.assignments != out.fit.assignments {
                return Err(crate::error::Error::Config(format!(
                    "verification failed: backend disagrees with Lloyd on {} points",
                    direct
                        .assignments
                        .iter()
                        .zip(&out.fit.assignments)
                        .filter(|(a, b)| a != b)
                        .count()
                )));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn default_system_clusters_blobs() {
        let ds = synth::blobs(1200, 8, 4, 3);
        let sys = KpynqSystem::new(SystemConfig::default()).unwrap();
        let kcfg = KMeansConfig { k: 4, seed: 11, ..Default::default() };
        let out = sys.cluster(&ds, &kcfg).unwrap();
        assert!(out.fit.converged);
        assert_eq!(out.fit.assignments.len(), 1200);
        assert!(out.report.total_cycles > 0);
    }

    #[test]
    fn verify_mode_accepts_exact_backend() {
        let ds = synth::blobs(600, 6, 3, 7);
        let sys = KpynqSystem::new(SystemConfig {
            backend: Backend::Native,
            verify: true,
        })
        .unwrap();
        let kcfg = KMeansConfig { k: 3, seed: 5, ..Default::default() };
        let out = sys.cluster(&ds, &kcfg).unwrap();
        assert!(out.fit.converged);
    }
}
