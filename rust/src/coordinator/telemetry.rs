//! Run-level telemetry emitted by the coordinator.

use crate::hw::CycleBreakdown;
use crate::kmeans::metrics::WorkEfficiency;
use crate::obs::profile::PhaseTotals;

/// What a run cost, in whichever currencies the backend produces.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Backend identifier ("fpga-sim", "native", "xla-pjrt").
    pub backend: String,
    /// Simulated PL cycles (FPGA backend; 0 otherwise).
    pub total_cycles: u64,
    /// Simulated seconds at the PL clock (FPGA backend; 0 otherwise).
    pub sim_seconds: f64,
    /// Measured host wall-clock of the whole fit.
    pub wall_seconds: f64,
    /// Per-iteration cycle breakdowns (FPGA backend).
    pub iter_cycles: Vec<CycleBreakdown>,
    /// Pipeline busy fraction (FPGA backend) — drives dynamic power.
    pub pipeline_utilization: f64,
    /// Total DMA traffic in bytes (FPGA backend).
    pub dma_bytes: u64,
    /// Tiles dispatched to the engine (engine backends).
    pub tiles_dispatched: u64,
    /// Points that survived filtering and were re-scanned, summed over
    /// iterations (engine backends; equals n × iters with filters off).
    pub points_rescanned: u64,
    /// Whole-run triangle-inequality savings (all backends that track
    /// per-iteration stats; all-zero otherwise — `kmeans::metrics`).
    pub work: WorkEfficiency,
    /// Per-phase wall-time split from `obs::profile` — `Some` only when
    /// profiling was enabled for the run. The timers are provably
    /// non-perturbing (DESIGN.md §2): the fit is bit-identical on or off.
    pub phases: Option<PhaseTotals>,
}

impl RunReport {
    /// Simulated-or-measured seconds, preferring the simulation when the
    /// backend produced one (engine backends report wall-clock).
    pub fn seconds(&self) -> f64 {
        if self.sim_seconds > 0.0 {
            self.sim_seconds
        } else {
            self.wall_seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_prefers_simulation() {
        let mut r = RunReport { sim_seconds: 2.0, wall_seconds: 0.5, ..Default::default() };
        assert_eq!(r.seconds(), 2.0);
        r.sim_seconds = 0.0;
        assert_eq!(r.seconds(), 0.5);
    }
}
