//! Tile scheduling: partitioning and survivor compaction.
//!
//! The accelerator (and the PJRT kernel) consume fixed-size dense tiles.
//! The scheduler produces two plans:
//!
//! * [`partition`] — split `0..n` into contiguous tiles for full-scan
//!   iterations (iteration 1, or filters disabled);
//! * [`compact`] — pack a sparse survivor set into dense tiles, the
//!   batch-level-sparsity trick of DESIGN.md §Hardware-Adaptation: the
//!   filter eliminates points on the host, the engine only ever sees dense
//!   work.
//!
//! Invariants (property-tested in `rust/tests/proptest_coordinator.rs`):
//! every index appears in exactly one tile, order within a tile is
//! ascending, and no tile exceeds the configured size.

/// A tile of point indices (dense, ascending).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tile {
    pub indices: Vec<usize>,
}

/// Contiguous partition of `0..n` into tiles of at most `tile_size`.
pub fn partition(n: usize, tile_size: usize) -> Vec<Tile> {
    assert!(tile_size > 0, "tile_size must be positive");
    let mut out = Vec::with_capacity(n.div_ceil(tile_size));
    let mut start = 0;
    while start < n {
        let end = (start + tile_size).min(n);
        out.push(Tile { indices: (start..end).collect() });
        start = end;
    }
    out
}

/// Pack survivor indices (any order, no duplicates) into dense tiles.
/// Indices are sorted so downstream gathers are cache-friendly and results
/// are deterministic regardless of how the filter enumerated survivors.
pub fn compact(mut survivors: Vec<usize>, tile_size: usize) -> Vec<Tile> {
    assert!(tile_size > 0, "tile_size must be positive");
    survivors.sort_unstable();
    survivors
        .chunks(tile_size)
        .map(|c| Tile { indices: c.to_vec() })
        .collect()
}

/// Occupancy of the last tile (padding waste diagnostic): 1.0 when full.
pub fn tail_occupancy(tiles: &[Tile], tile_size: usize) -> f64 {
    match tiles.last() {
        None => 1.0,
        Some(t) => t.indices.len() as f64 / tile_size as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly() {
        let tiles = partition(1000, 256);
        assert_eq!(tiles.len(), 4);
        let total: usize = tiles.iter().map(|t| t.indices.len()).sum();
        assert_eq!(total, 1000);
        assert_eq!(tiles[3].indices.len(), 232);
        assert_eq!(tiles[0].indices[0], 0);
        assert_eq!(tiles[3].indices[231], 999);
    }

    #[test]
    fn partition_empty_and_exact() {
        assert!(partition(0, 64).is_empty());
        let t = partition(128, 64);
        assert_eq!(t.len(), 2);
        assert!(t.iter().all(|t| t.indices.len() == 64));
    }

    #[test]
    fn compact_sorts_and_chunks() {
        let tiles = compact(vec![9, 3, 7, 1, 5], 2);
        assert_eq!(tiles.len(), 3);
        assert_eq!(tiles[0].indices, vec![1, 3]);
        assert_eq!(tiles[1].indices, vec![5, 7]);
        assert_eq!(tiles[2].indices, vec![9]);
    }

    #[test]
    fn tail_occupancy_reports_waste() {
        let tiles = compact((0..100).collect(), 64);
        assert!((tail_occupancy(&tiles, 64) - 36.0 / 64.0).abs() < 1e-12);
        assert_eq!(tail_occupancy(&[], 64), 1.0);
    }
}
