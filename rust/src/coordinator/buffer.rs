//! Host-side double buffering.
//!
//! On the board, DMA ping-pongs between two BRAM tile buffers so transfer
//! overlaps compute (provisioned in `hw::resource`, timed in
//! `hw::accelerator`). On the host the same pattern overlaps tile *prep*
//! (gather + padding — memory-bound) with tile *execution* (engine call —
//! compute-bound): [`pipelined`] runs the producer on a worker thread and
//! the consumer on the caller's thread, connected by a capacity-1 channel,
//! which is exactly a two-slot ping-pong.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Timing of a pipelined run: how long each side spent blocked on the
/// other (a balanced pipeline has both near zero).
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineTiming {
    pub producer_blocked: Duration,
    pub consumer_blocked: Duration,
    pub total: Duration,
}

/// Stream `items` through `produce` (worker thread) and `consume` (caller
/// thread) with double buffering. Returns consumer outputs in order.
///
/// `produce` here is infallible; for producers that can fail (file reads,
/// DMA-style transfers) use [`try_pipelined`], which gives the failure an
/// explicit poisoned-stream error path instead of a silent truncation.
pub fn pipelined<I, T, R, P, C>(
    items: Vec<I>,
    produce: P,
    mut consume: C,
) -> (Vec<R>, PipelineTiming)
where
    I: Send,
    T: Send,
    P: Fn(I) -> T + Send,
    C: FnMut(T) -> R,
{
    let started = Instant::now();
    let mut timing = PipelineTiming::default();
    // Capacity 1: one tile in flight + one being consumed = two buffers.
    let (tx, rx) = mpsc::sync_channel::<T>(1);
    let mut results = Vec::with_capacity(items.len());

    std::thread::scope(|scope| {
        let producer_blocked = scope.spawn(move || {
            let mut blocked = Duration::ZERO;
            for item in items {
                let value = produce(item);
                let t0 = Instant::now();
                if tx.send(value).is_err() {
                    break; // consumer dropped — shutting down
                }
                blocked += t0.elapsed();
            }
            blocked
        });

        loop {
            let t0 = Instant::now();
            match rx.recv() {
                Ok(v) => {
                    timing.consumer_blocked += t0.elapsed();
                    results.push(consume(v));
                }
                Err(_) => break, // producer finished
            }
        }
        timing.producer_blocked = producer_blocked.join().unwrap_or(Duration::ZERO);
    });

    timing.total = started.elapsed();
    (results, timing)
}

/// Fallible-producer variant of [`pipelined`]: the first `produce` error
/// **poisons the stream** — production stops, the consumer drains what was
/// already in flight (so side effects stay prefix-consistent), and the
/// error comes back to the caller in place of the results.
///
/// This is the DMA-fault contract on the board made explicit in the types:
/// a shut-down stream ends with `Ok` (every produced item consumed), a
/// faulted stream — including a *panicking* producer — ends with `Err`
/// (nothing partial returned), so callers can distinguish "clean
/// shutdown" from "transfer fault" without sentinel values.
pub fn try_pipelined<I, T, R, P, C>(
    items: Vec<I>,
    produce: P,
    mut consume: C,
) -> (crate::error::Result<Vec<R>>, PipelineTiming)
where
    I: Send,
    T: Send,
    P: Fn(I) -> crate::error::Result<T> + Send,
    C: FnMut(T) -> R,
{
    let started = Instant::now();
    let mut timing = PipelineTiming::default();
    // Capacity 1: one tile in flight + one being consumed = two buffers.
    let (tx, rx) = mpsc::sync_channel::<T>(1);
    let mut results = Vec::with_capacity(items.len());

    let poison = std::thread::scope(|scope| {
        let producer = scope.spawn(move || {
            let mut blocked = Duration::ZERO;
            for item in items {
                let value = match produce(item) {
                    Ok(v) => v,
                    Err(e) => return (blocked, Some(e)), // poison: stop producing
                };
                let t0 = Instant::now();
                if tx.send(value).is_err() {
                    break; // consumer dropped — shutting down
                }
                blocked += t0.elapsed();
            }
            (blocked, None)
        });

        loop {
            let t0 = Instant::now();
            match rx.recv() {
                Ok(v) => {
                    timing.consumer_blocked += t0.elapsed();
                    results.push(consume(v));
                }
                Err(_) => break, // producer finished or poisoned
            }
        }
        let (blocked, poison) = match producer.join() {
            Ok(result) => result,
            // A panicking producer is a fault, not a clean shutdown — do
            // not let a truncated prefix masquerade as a complete stream.
            Err(_) => (
                Duration::ZERO,
                Some(crate::error::Error::Data("pipeline producer panicked".into())),
            ),
        };
        timing.producer_blocked = blocked;
        poison
    });

    timing.total = started.elapsed();
    match poison {
        Some(e) => (Err(e), timing),
        None => (Ok(results), timing),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn processes_all_items_in_order() {
        let (out, _t) = pipelined(
            (0..100).collect::<Vec<i32>>(),
            |x| x * 2,
            |x| x + 1,
        );
        assert_eq!(out, (0..100).map(|x| x * 2 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_is_fine() {
        let (out, _t) = pipelined(Vec::<i32>::new(), |x| x, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn overlap_beats_serial_for_balanced_stages() {
        // Producer and consumer each sleep ~2 ms per item; pipelined total
        // must be well under the 4 ms/item serial cost.
        let items: Vec<u32> = (0..12).collect();
        let serial_estimate = Duration::from_millis(4 * 12);
        let (_out, t) = pipelined(
            items,
            |x| {
                std::thread::sleep(Duration::from_millis(2));
                x
            },
            |x| {
                std::thread::sleep(Duration::from_millis(2));
                x
            },
        );
        assert!(
            t.total < serial_estimate.mul_f64(0.8),
            "no overlap: {:?} vs serial {:?}",
            t.total,
            serial_estimate
        );
    }

    #[test]
    fn try_pipelined_ok_path_matches_pipelined() {
        let (out, _t) = try_pipelined(
            (0..100).collect::<Vec<i32>>(),
            |x| Ok(x * 2),
            |x| x + 1,
        );
        assert_eq!(out.unwrap(), (0..100).map(|x| x * 2 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn try_pipelined_producer_fault_poisons_the_stream() {
        let mut consumed = 0usize;
        let (out, t) = try_pipelined(
            (0..100).collect::<Vec<i32>>(),
            |x| {
                if x == 5 {
                    Err(crate::error::Error::Data("simulated DMA fault".into()))
                } else {
                    Ok(x)
                }
            },
            |x| {
                consumed += 1;
                x
            },
        );
        let err = out.unwrap_err();
        assert!(err.to_string().contains("simulated DMA fault"), "{err}");
        // The consumer drained only what was produced before the fault —
        // a prefix, never items past the poison point.
        assert!(consumed <= 5, "consumed {consumed} items past the fault");
        assert!(t.total > Duration::ZERO);
    }

    #[test]
    fn try_pipelined_empty_input_is_ok() {
        let (out, _t) = try_pipelined(Vec::<i32>::new(), Ok, |x| x);
        assert!(out.unwrap().is_empty());
    }

    #[test]
    fn try_pipelined_producer_panic_is_a_fault_not_a_shutdown() {
        let (out, _t) = try_pipelined(
            (0..10).collect::<Vec<i32>>(),
            |x| {
                if x == 3 {
                    panic!("producer bug");
                }
                Ok(x)
            },
            |x| x,
        );
        let err = out.unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
    }
}
