//! The coordinator's run loop.
//!
//! For the FPGA backend the whole iteration structure lives inside
//! `hw::Accelerator::run_fit`; this module wraps it into a [`RunReport`].
//!
//! For the engine backends (native / XLA) the coordinator itself plays the
//! role the PS + filter unit share on the board, with the filter moved into
//! the scheduler per DESIGN.md §Hardware-Adaptation:
//!
//! 1. iteration 1 — every tile is dispatched densely; bounds are seeded
//!    from the engine's (best, second) results (Hamerly-style: one upper,
//!    one lower bound per point — the point-level filter);
//! 2. every later iteration — drifts are applied to the bounds on the
//!    host, the global triangle-inequality test eliminates settled points
//!    *without any distance work*, and only the survivors are compacted
//!    into dense tiles for the engine, which rescans them fully and
//!    refreshes their bounds exactly.
//!
//! Exactness argument: a filtered point provably keeps its assignment (the
//! bound test is conservative, `bounds::filter_safe`); a surviving point
//! gets the same full scan Lloyd would do. Centroid recomputation is the
//! shared `kmeans::recompute_centroids`. Hence assignments equal Lloyd's
//! at every iteration — the `coordinator_equivalence` integration test.

use std::time::Instant;

use crate::data::Dataset;
use crate::error::Result;
use crate::hw::{AccelConfig, Accelerator};
use crate::kmeans::bounds::{deflate_lb, filter_safe, inflate_ub};
use crate::kmeans::hamerly::half_nearest_other;
use crate::kmeans::metrics::IterStats;
use crate::kmeans::{
    centroid_drifts, compute_inertia, init, recompute_centroids, FitResult, KMeansConfig,
    RunStats,
};
use crate::runtime::{native::NativeEngine, xla::XlaEngine, Engine};

use super::scheduler;
use super::telemetry::RunReport;
use super::{Backend, SystemConfig, SystemOutput};

/// Default tile size for engine dispatch — matches the AOT tile so the XLA
/// engine never splits a scheduler tile.
pub const ENGINE_TILE: usize = 256;

/// Run one clustering job on the configured backend.
///
/// `Backend::Xla` constructs the PJRT engine first and therefore fails
/// fast (with a descriptive error) when the `xla` feature is off or the
/// artifacts are missing — before any clustering work starts.
pub fn run(sys: &SystemConfig, ds: &Dataset, kcfg: &KMeansConfig) -> Result<SystemOutput> {
    match &sys.backend {
        Backend::SimulatedFpga(acfg) => run_fpga(acfg, ds, kcfg),
        Backend::Native => run_engine(&mut NativeEngine, "native", ds, kcfg),
        Backend::Xla { artifact_dir } => {
            let mut eng = XlaEngine::new(artifact_dir)?;
            run_engine(&mut eng, "xla-pjrt", ds, kcfg)
        }
    }
}

fn run_fpga(acfg: &AccelConfig, ds: &Dataset, kcfg: &KMeansConfig) -> Result<SystemOutput> {
    let t0 = Instant::now();
    let init_c = init::initialize(ds, kcfg)?;
    let acc = Accelerator::new(acfg.clone());
    let run = acc.run_fit(ds, kcfg, init_c)?;
    let report = RunReport {
        backend: "fpga-sim".into(),
        total_cycles: run.total_cycles,
        sim_seconds: run.seconds,
        wall_seconds: t0.elapsed().as_secs_f64(),
        iter_cycles: run.iters.clone(),
        pipeline_utilization: run.pipeline_utilization,
        dma_bytes: run.dma_bytes,
        tiles_dispatched: 0,
        points_rescanned: run.fit.stats.iters.iter().map(|i| i.survivors).sum(),
    };
    Ok(SystemOutput { fit: run.fit, report })
}

/// The engine-backed coordinator loop (host filtering + dense tiles).
fn run_engine(
    engine: &mut dyn Engine,
    backend_name: &str,
    ds: &Dataset,
    kcfg: &KMeansConfig,
) -> Result<SystemOutput> {
    kcfg.validate(ds.n())?;
    ds.validate()?;
    let t0 = Instant::now();
    let n = ds.n();
    let k = kcfg.k;
    let mut centroids = init::initialize(ds, kcfg)?;

    let mut assignments = vec![0u32; n];
    let mut ub = vec![0.0f32; n];
    let mut lb = vec![0.0f32; n];
    let mut stats = RunStats::default();
    let mut tiles_dispatched = 0u64;
    let mut points_rescanned = 0u64;
    let mut converged = false;
    let mut iterations = 0usize;

    // ---- Iteration 1: dense dispatch of the whole dataset ----
    // One engine call: the engine splits into kernel tiles internally, so
    // per-call setup (centroid padding + literal upload on the XLA path)
    // is paid once per iteration, not once per tile (§Perf).
    {
        iterations += 1;
        let mut it = IterStats::default();
        let out = engine.assign_tile(&ds.points, &centroids)?;
        tiles_dispatched += n.div_ceil(ENGINE_TILE) as u64;
        for i in 0..n {
            assignments[i] = out.idx[i];
            ub[i] = out.best[i].max(0.0).sqrt();
            lb[i] = if out.second[i].is_finite() {
                out.second[i].max(0.0).sqrt()
            } else {
                f32::INFINITY
            };
        }
        points_rescanned += n as u64;
        it.dist_comps = (n as u64) * (k as u64);
        it.survivors = n as u64;
        it.reassigned = n as u64;
        let (new_c, _) = recompute_centroids(ds, &assignments, &centroids);
        let (drifts, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);
        if (max_drift as f64) <= kcfg.tol {
            converged = true;
        } else {
            for i in 0..n {
                ub[i] = inflate_ub(ub[i], drifts[assignments[i] as usize]);
                lb[i] = deflate_lb(lb[i], max_drift);
            }
        }
    }

    // ---- Filtered iterations: compacted survivor tiles ----
    while !converged && iterations < kcfg.max_iters {
        iterations += 1;
        let mut it = IterStats::default();

        // Inter-centroid guard (k² on the host — cheap next to n·k).
        let (s_half, pair_comps) = half_nearest_other(&centroids);
        it.dist_comps += pair_comps;

        let mut survivors = Vec::new();
        for i in 0..n {
            let guard = lb[i].max(s_half[assignments[i] as usize]);
            if filter_safe(guard, ub[i]) {
                it.filtered_global += 1;
            } else {
                survivors.push(i);
            }
        }
        it.survivors = survivors.len() as u64;
        points_rescanned += survivors.len() as u64;

        // Compact all survivors into one dense matrix and dispatch once;
        // scheduler::compact documents the tiling invariants the engines
        // rely on (ascending order ⇒ cache-friendly gather).
        let tiles = scheduler::compact(survivors, ENGINE_TILE);
        if !tiles.is_empty() {
            let order: Vec<usize> =
                tiles.iter().flat_map(|t| t.indices.iter().copied()).collect();
            let pts = ds.points.gather_rows(&order);
            let out = engine.assign_tile(&pts, &centroids)?;
            tiles_dispatched += tiles.len() as u64;
            it.dist_comps += (order.len() * k) as u64;
            for (j, &i) in order.iter().enumerate() {
                if assignments[i] != out.idx[j] {
                    it.reassigned += 1;
                    assignments[i] = out.idx[j];
                }
                ub[i] = out.best[j].max(0.0).sqrt();
                lb[i] = if out.second[j].is_finite() {
                    out.second[j].max(0.0).sqrt()
                } else {
                    f32::INFINITY
                };
            }
        }

        let (new_c, _) = recompute_centroids(ds, &assignments, &centroids);
        let (drifts, max_drift) = centroid_drifts(&centroids, &new_c);
        centroids = new_c;
        it.max_drift = max_drift;
        stats.push(it);

        if (max_drift as f64) <= kcfg.tol {
            converged = true;
        } else {
            for i in 0..n {
                ub[i] = inflate_ub(ub[i], drifts[assignments[i] as usize]);
                lb[i] = deflate_lb(lb[i], max_drift);
            }
        }
    }

    let inertia = compute_inertia(ds, &centroids, &assignments);
    let fit = FitResult { centroids, assignments, inertia, iterations, converged, stats };
    let report = RunReport {
        backend: backend_name.into(),
        wall_seconds: t0.elapsed().as_secs_f64(),
        tiles_dispatched,
        points_rescanned,
        ..Default::default()
    };
    Ok(SystemOutput { fit, report })
}

/// Convenience for tests/benches: run the engine loop with an explicit
/// engine instance (bypasses `SystemConfig`).
pub fn run_with_engine(
    engine: &mut dyn Engine,
    ds: &Dataset,
    kcfg: &KMeansConfig,
) -> Result<SystemOutput> {
    let name = engine.name();
    run_engine(engine, name, ds, kcfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, Algorithm};

    #[test]
    fn native_engine_loop_matches_lloyd() {
        let ds = synth::blobs(700, 9, 4, 3);
        let kcfg = KMeansConfig { k: 4, seed: 13, ..Default::default() };
        let direct = kmeans::fit(Algorithm::Lloyd, &ds, &kcfg).unwrap();
        let out = run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap();
        assert_eq!(direct.assignments, out.fit.assignments);
        assert_eq!(direct.centroids, out.fit.centroids);
        assert_eq!(direct.iterations, out.fit.iterations);
        assert!(out.report.tiles_dispatched > 0);
    }

    #[test]
    fn filtering_reduces_rescans() {
        let ds = synth::blobs(4000, 8, 6, 9);
        let kcfg = KMeansConfig { k: 6, seed: 3, max_iters: 50, ..Default::default() };
        let out = run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap();
        let dense = (ds.n() * out.fit.iterations) as u64;
        assert!(
            out.report.points_rescanned < dense,
            "rescans {} should be under dense {}",
            out.report.points_rescanned,
            dense
        );
    }

    #[test]
    fn engine_tile_matches_aot_tile() {
        // The scheduler tile must equal the AOT kernel tile so the XLA
        // engine never pads mid-run (checked against the python constant).
        assert_eq!(ENGINE_TILE, 256);
    }
}
