//! The coordinator's run loop.
//!
//! For the FPGA backend the whole iteration structure lives inside
//! `hw::Accelerator::run_fit`; this module wraps it into a [`RunReport`].
//!
//! For the engine backends (native / XLA) the coordinator itself plays the
//! role the PS + filter unit share on the board, with the filter moved into
//! the scheduler per DESIGN.md §Hardware-Adaptation:
//!
//! 1. iteration 1 — every tile is dispatched densely; bounds are seeded
//!    from the engine's (best, second) results (Hamerly-style: one upper,
//!    one lower bound per point — the point-level filter);
//! 2. every later iteration — drifts are applied to the bounds on the
//!    host, the global triangle-inequality test eliminates settled points
//!    *without any distance work*, and only the survivors are compacted
//!    into dense tiles for the engine, which rescans them fully and
//!    refreshes their bounds exactly.
//!
//! Exactness argument: a filtered point provably keeps its assignment (the
//! bound test is conservative, `bounds::filter_safe`); a surviving point
//! gets the same full scan Lloyd would do. Centroid recomputation is the
//! shared `kmeans::recompute_centroids`. Hence assignments equal Lloyd's
//! at every iteration — the `coordinator_equivalence` integration test.
//!
//! The loop is factored as a resumable state machine ([`FitState`]): each
//! iteration is a `begin_iteration` (host-side filtering, survivor
//! compaction — produces a [`Dispatch`]) followed by a
//! `complete_iteration` (absorb engine results, recompute centroids,
//! update bounds). `run_engine` drives one state to completion;
//! `serve::batch` drives several states in lockstep so compatible
//! requests share one engine dispatch per iteration (`Engine::assign_batch`)
//! while every state's trajectory stays bit-identical to a solo run.

use std::time::Instant;

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::hw::{AccelConfig, Accelerator};
use crate::kmeans::bounds::{deflate_lb, filter_safe, group_max_drifts, inflate_ub};
use crate::kmeans::hamerly::half_nearest_other;
use crate::kmeans::kernel::{self, scan_all};
use crate::kmeans::metrics::IterStats;
use crate::kmeans::reduce::{ExactSum, PartialAccumulator};
use crate::kmeans::{
    centroid_drifts, compute_inertia, init, recompute_centroids, yinyang, Algorithm, FitResult,
    KMeansConfig, RunStats,
};
use crate::obs::profile::{Phase, PhaseTimer};
use crate::runtime::{native::NativeEngine, xla::XlaEngine, AssignOut, Engine};
use crate::util::matrix::Matrix;

use super::scheduler;
use super::telemetry::RunReport;
use super::{Backend, SystemConfig, SystemOutput};

/// Default tile size for engine dispatch — matches the AOT tile so the XLA
/// engine never splits a scheduler tile.
pub const ENGINE_TILE: usize = 256;

/// Run one clustering job on the configured backend.
///
/// `Backend::Xla` constructs the PJRT engine first and therefore fails
/// fast (with a descriptive error) when the `xla` feature is off or the
/// artifacts are missing — before any clustering work starts.
pub fn run(sys: &SystemConfig, ds: &Dataset, kcfg: &KMeansConfig) -> Result<SystemOutput> {
    match &sys.backend {
        Backend::SimulatedFpga(acfg) => run_fpga(acfg, ds, kcfg),
        Backend::Native => run_engine(&mut NativeEngine, "native", ds, kcfg),
        Backend::Xla { artifact_dir } => {
            let mut eng = XlaEngine::new(artifact_dir)?;
            run_engine(&mut eng, "xla-pjrt", ds, kcfg)
        }
    }
}

fn run_fpga(acfg: &AccelConfig, ds: &Dataset, kcfg: &KMeansConfig) -> Result<SystemOutput> {
    let t0 = Instant::now();
    let init_c = init::initialize(ds, kcfg)?;
    let acc = Accelerator::new(acfg.clone());
    let run = acc.run_fit(ds, kcfg, init_c)?;
    let report = RunReport {
        backend: "fpga-sim".into(),
        total_cycles: run.total_cycles,
        sim_seconds: run.seconds,
        wall_seconds: t0.elapsed().as_secs_f64(),
        iter_cycles: run.iters.clone(),
        pipeline_utilization: run.pipeline_utilization,
        dma_bytes: run.dma_bytes,
        tiles_dispatched: 0,
        points_rescanned: run.fit.stats.iters.iter().map(|i| i.survivors).sum(),
        work: run.fit.stats.work_efficiency(ds.n(), kcfg.k),
        phases: run.fit.stats.phases,
    };
    Ok(SystemOutput { fit: run.fit, report })
}

/// What [`FitState::begin_iteration`] wants executed on the engine.
#[derive(Debug)]
pub enum Dispatch {
    /// Iteration 1: scan the whole dataset densely (use
    /// [`FitState::points`] as the tile source — no gather copy).
    Dense,
    /// Filtered iteration: the survivors, already compacted into dense
    /// ascending tiles and gathered into one matrix.
    Survivors(Matrix),
    /// Every point was filtered this iteration — no engine work at all.
    Skip,
}

/// Bookkeeping carried between `begin_iteration` and `complete_iteration`.
struct PendingIter {
    it: IterStats,
    /// Original point index per dispatched row; `None` marks the dense
    /// iteration-1 dispatch (identity order over the whole dataset).
    order: Option<Vec<usize>>,
}

/// The engine-backed coordinator loop as a resumable state machine.
///
/// Invariant: the sequence `begin_iteration` → engine dispatch →
/// `complete_iteration`, repeated until [`done`](FitState::done), performs
/// exactly the operations of a monolithic run — same floats, same order —
/// so interleaving several states (as `serve::batch::fit_lockstep` does)
/// cannot change any individual result.
pub struct FitState<'a> {
    ds: &'a Dataset,
    kcfg: &'a KMeansConfig,
    centroids: Matrix,
    assignments: Vec<u32>,
    ub: Vec<f32>,
    lb: Vec<f32>,
    stats: RunStats,
    tiles_dispatched: u64,
    points_rescanned: u64,
    converged: bool,
    iterations: usize,
    started: Instant,
    pending: Option<PendingIter>,
    /// obs::profile phase clock — pure annotation, bit-identical on/off.
    /// The Assign phase opened by `begin_iteration` stays open across the
    /// engine dispatch so the scan itself is attributed to Assign.
    timer: PhaseTimer,
}

impl<'a> FitState<'a> {
    /// Validate the job and run the (deterministic, seed-driven)
    /// initialisation. The wall-clock in the final report starts here.
    pub fn new(ds: &'a Dataset, kcfg: &'a KMeansConfig) -> Result<Self> {
        kcfg.validate(ds.n())?;
        ds.validate()?;
        let started = Instant::now();
        let n = ds.n();
        let mut timer = PhaseTimer::new();
        timer.enter(Phase::Init);
        let centroids = init::initialize(ds, kcfg)?;
        timer.exit();
        Ok(Self {
            ds,
            kcfg,
            centroids,
            assignments: vec![0u32; n],
            ub: vec![0.0f32; n],
            lb: vec![0.0f32; n],
            stats: RunStats::default(),
            tiles_dispatched: 0,
            points_rescanned: 0,
            converged: false,
            iterations: 0,
            started,
            pending: None,
            timer,
        })
    }

    /// True once the fit converged or hit the iteration cap.
    pub fn done(&self) -> bool {
        self.converged || self.iterations >= self.kcfg.max_iters
    }

    /// The dataset's point matrix (the tile source for [`Dispatch::Dense`]).
    pub fn points(&self) -> &Matrix {
        &self.ds.points
    }

    /// Current centroids — the second argument of the engine dispatch.
    pub fn centroids(&self) -> &Matrix {
        &self.centroids
    }

    /// Iterations completed (plus the one in flight, if any).
    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Start the next iteration: apply the global triangle-inequality
    /// filter on the host and compact the survivors. The caller must
    /// execute the returned [`Dispatch`] against
    /// [`centroids`](FitState::centroids) and feed the output to
    /// [`complete_iteration`](FitState::complete_iteration).
    ///
    /// Panics when called on a finished fit or with an iteration pending.
    pub fn begin_iteration(&mut self) -> Dispatch {
        assert!(self.pending.is_none(), "iteration already in flight");
        assert!(!self.done(), "begin_iteration on a finished fit");
        self.timer.enter(Phase::Assign);
        self.iterations += 1;
        let n = self.ds.n();
        let k = self.kcfg.k;
        let mut it = IterStats::default();

        // ---- Iteration 1: dense dispatch of the whole dataset ----
        // One engine call: the engine splits into kernel tiles internally,
        // so per-call setup (centroid padding + literal upload on the XLA
        // path) is paid once per iteration, not once per tile (§Perf).
        if self.iterations == 1 {
            self.tiles_dispatched += n.div_ceil(ENGINE_TILE) as u64;
            self.points_rescanned += n as u64;
            it.dist_comps = (n as u64) * (k as u64);
            it.survivors = n as u64;
            it.reassigned = n as u64;
            self.pending = Some(PendingIter { it, order: None });
            return Dispatch::Dense;
        }

        // ---- Filtered iteration: compacted survivor tiles ----
        // Inter-centroid guard (k² on the host — cheap next to n·k).
        let (s_half, pair_comps) = half_nearest_other(&self.centroids);
        it.dist_comps += pair_comps;

        let mut survivors = Vec::new();
        for i in 0..n {
            let guard = self.lb[i].max(s_half[self.assignments[i] as usize]);
            if filter_safe(guard, self.ub[i]) {
                it.filtered_global += 1;
            } else {
                survivors.push(i);
            }
        }
        it.survivors = survivors.len() as u64;
        self.points_rescanned += survivors.len() as u64;

        // Compact all survivors into one dense matrix to dispatch once;
        // scheduler::compact documents the tiling invariants the engines
        // rely on (ascending order ⇒ cache-friendly gather).
        let tiles = scheduler::compact(survivors, ENGINE_TILE);
        if tiles.is_empty() {
            self.pending = Some(PendingIter { it, order: Some(Vec::new()) });
            return Dispatch::Skip;
        }
        let order: Vec<usize> =
            tiles.iter().flat_map(|t| t.indices.iter().copied()).collect();
        let pts = self.ds.points.gather_rows(&order);
        self.tiles_dispatched += tiles.len() as u64;
        it.dist_comps += (order.len() * k) as u64;
        self.pending = Some(PendingIter { it, order: Some(order) });
        Dispatch::Survivors(pts)
    }

    /// Absorb the engine output for the in-flight iteration, recompute
    /// centroids and update the bounds. Pass `None` if (and only if) the
    /// dispatch was [`Dispatch::Skip`].
    pub fn complete_iteration(&mut self, out: Option<&AssignOut>) -> Result<()> {
        let PendingIter { mut it, order } = self
            .pending
            .take()
            .ok_or_else(|| Error::Config("complete_iteration without begin_iteration".into()))?;

        match &order {
            // Dense iteration 1: seed assignments and both bounds.
            None => {
                let out = out.ok_or_else(|| {
                    Error::Config("dense dispatch requires an engine output".into())
                })?;
                let n = self.ds.n();
                if out.idx.len() != n {
                    return Err(Error::Config(format!(
                        "engine returned {} results for {} points",
                        out.idx.len(),
                        n
                    )));
                }
                for i in 0..n {
                    self.assignments[i] = out.idx[i];
                    self.ub[i] = out.best[i].max(0.0).sqrt();
                    self.lb[i] = if out.second[i].is_finite() {
                        out.second[i].max(0.0).sqrt()
                    } else {
                        f32::INFINITY
                    };
                }
            }
            // Filtered iteration with no survivors: nothing to absorb.
            Some(order) if order.is_empty() => {
                if out.is_some() {
                    return Err(Error::Config(
                        "unexpected engine output for a skipped dispatch".into(),
                    ));
                }
            }
            // Filtered iteration: survivors rescanned, bounds refreshed.
            Some(order) => {
                let out = out.ok_or_else(|| {
                    Error::Config("survivor dispatch requires an engine output".into())
                })?;
                if out.idx.len() != order.len() {
                    return Err(Error::Config(format!(
                        "engine returned {} results for {} survivors",
                        out.idx.len(),
                        order.len()
                    )));
                }
                for (j, &i) in order.iter().enumerate() {
                    if self.assignments[i] != out.idx[j] {
                        it.reassigned += 1;
                        self.assignments[i] = out.idx[j];
                    }
                    self.ub[i] = out.best[j].max(0.0).sqrt();
                    self.lb[i] = if out.second[j].is_finite() {
                        out.second[j].max(0.0).sqrt()
                    } else {
                        f32::INFINITY
                    };
                }
            }
        }

        self.timer.enter(Phase::Update);
        let (new_c, _) = recompute_centroids(self.ds, &self.assignments, &self.centroids);
        let (drifts, max_drift) = centroid_drifts(&self.centroids, &new_c);
        self.centroids = new_c;
        it.max_drift = max_drift;
        self.stats.push(it);

        if (max_drift as f64) <= self.kcfg.tol {
            self.converged = true;
        } else {
            self.timer.enter(Phase::Bounds);
            for i in 0..self.ds.n() {
                self.ub[i] = inflate_ub(self.ub[i], drifts[self.assignments[i] as usize]);
                self.lb[i] = deflate_lb(self.lb[i], max_drift);
            }
        }
        self.timer.exit();
        Ok(())
    }

    /// Seal the fit into a [`SystemOutput`] with the final inertia and the
    /// wall-clock measured since [`new`](FitState::new).
    pub fn finish(mut self, backend_name: &str) -> SystemOutput {
        debug_assert!(self.pending.is_none(), "finish with an iteration in flight");
        let phases = self.timer.totals();
        self.stats.phases = phases;
        let inertia = compute_inertia(self.ds, &self.centroids, &self.assignments);
        let work = self.stats.work_efficiency(self.ds.n(), self.kcfg.k);
        let fit = FitResult {
            centroids: self.centroids,
            assignments: self.assignments,
            inertia,
            iterations: self.iterations,
            converged: self.converged,
            stats: self.stats,
        };
        let report = RunReport {
            backend: backend_name.into(),
            wall_seconds: self.started.elapsed().as_secs_f64(),
            tiles_dispatched: self.tiles_dispatched,
            points_rescanned: self.points_rescanned,
            work,
            phases,
            ..Default::default()
        };
        SystemOutput { fit, report }
    }
}

/// The engine-backed coordinator loop (host filtering + dense tiles).
fn run_engine(
    engine: &mut dyn Engine,
    backend_name: &str,
    ds: &Dataset,
    kcfg: &KMeansConfig,
) -> Result<SystemOutput> {
    let mut st = FitState::new(ds, kcfg)?;
    while !st.done() {
        let out = match st.begin_iteration() {
            Dispatch::Dense => Some(engine.assign_tile(st.points(), st.centroids())?),
            Dispatch::Survivors(pts) => Some(engine.assign_tile(&pts, st.centroids())?),
            Dispatch::Skip => None,
        };
        st.complete_iteration(out.as_ref())?;
    }
    Ok(st.finish(backend_name))
}

/// Convenience for tests/benches: run the engine loop with an explicit
/// engine instance (bypasses `SystemConfig`).
pub fn run_with_engine(
    engine: &mut dyn Engine,
    ds: &Dataset,
    kcfg: &KMeansConfig,
) -> Result<SystemOutput> {
    let name = engine.name();
    run_engine(engine, name, ds, kcfg)
}

/// Run one pinned kernel variant host-side — the serve layer's
/// explicit-`algorithm` path (PROTOCOL.md §3). No engine loop, no tiling:
/// the named algorithm's own iteration structure runs exactly as
/// `kmeans::fit` defines it, so the full multi-level filter stats
/// (group/point level included, for yinyang) flow into the report's
/// work-efficiency rollup.
pub fn run_algorithm(
    algo: Algorithm,
    backend_name: &str,
    ds: &Dataset,
    kcfg: &KMeansConfig,
) -> Result<SystemOutput> {
    let t0 = Instant::now();
    let fit = crate::kmeans::fit(algo, ds, kcfg)?;
    let report = RunReport {
        backend: backend_name.into(),
        wall_seconds: t0.elapsed().as_secs_f64(),
        points_rescanned: fit.stats.iters.iter().map(|i| i.survivors).sum(),
        work: fit.stats.work_efficiency(ds.n(), kcfg.k),
        phases: fit.stats.phases,
        ..Default::default()
    };
    Ok(SystemOutput { fit, report })
}

/// Per-algorithm shard-local bound state for a [`PartialFitState`].
///
/// Each variant mirrors the corresponding solo fit's per-point state, and
/// the assignment passes below transcribe the solo inner loops verbatim
/// (stats aside). Every per-point decision in all four algorithms is a
/// pure function of (point row, that point's bounds, the shared centroid
/// geometry), so running the identical loop over a slice produces the
/// identical assignments the solo loop would produce for those points —
/// the keystone of the map-reduce bit-identity contract (PROTOCOL.md §10).
enum SliceBounds {
    Lloyd,
    /// One upper + one lower bound per slice point.
    Hamerly { ub: Vec<f32>, lb: Vec<f32> },
    /// Upper bound + per-centroid lower bounds (`slice_n × k`).
    Elkan { ub: Vec<f32>, lb: Vec<f32> },
    /// The multi-level filter state over a gathered copy of the slice.
    Yinyang {
        sub: Dataset,
        grouping: yinyang::Grouping,
        st: yinyang::FilterState,
    },
}

/// One shard's half of a map-reduce fit (PROTOCOL.md §10): per-iteration
/// assignments plus per-cluster partial sums/counts over the contiguous
/// slice `[lo, hi)` of the dataset, with triangle-inequality bounds kept
/// entirely shard-local. The counterpart of [`FitState`]'s begin/dispatch/
/// complete seam, split at the reduction instead of the engine dispatch:
///
/// 1. [`PartialFitState::new`] loads nothing over the wire — every shard
///    derives the same initial centroids from the same deterministic
///    seed — and runs assignment pass 1 over its slice (`epoch` = 1).
/// 2. [`PartialFitState::partial`] packages the slice's sums/counts for
///    the front to merge ([`PartialAccumulator`] is exact, so merge order
///    cannot matter).
/// 3. [`PartialFitState::apply_sync`] accepts the reduced centroids,
///    applies drift updates to the local bounds exactly as the solo fit
///    would, and runs the next assignment pass (`epoch` += 1).
/// 4. [`PartialFitState::finish`] seals the slice: final assignments and
///    the slice's exact inertia contribution against the final centroids.
///
/// Epochs count completed assignment passes; a re-dispatched shard can be
/// replayed to any epoch by feeding the reduced-centroid history through
/// `apply_sync`, which makes recovery idempotent.
pub struct PartialFitState {
    ds: Dataset,
    kcfg: KMeansConfig,
    shard_index: usize,
    shard_count: usize,
    lo: usize,
    hi: usize,
    /// The deterministic initial centroids (`c_0`), kept for the front
    /// (which never loads the dataset itself).
    init: Matrix,
    /// The centroids the current assignments were computed against.
    centroids: Matrix,
    /// Completed assignment passes.
    epoch: usize,
    /// Slice-local assignments (`hi - lo` entries).
    assignments: Vec<u32>,
    bounds: SliceBounds,
    /// obs::profile phase clock — pure annotation, bit-identical on/off.
    /// Reduce covers packaging partial sums (`partial`) and sealing the
    /// slice (`finish`); the assignment passes land in Init/Assign/Bounds.
    timer: PhaseTimer,
}

impl PartialFitState {
    /// Validate, initialise centroids deterministically and run assignment
    /// pass 1 over this shard's slice. `ds` must be the *full* dataset —
    /// the slice boundaries are derived from the global `n`, so every
    /// shard agrees on who owns which points. A slice may be empty (more
    /// shards than points); it then contributes zero sums/counts.
    pub fn new(
        algo: Algorithm,
        ds: Dataset,
        kcfg: KMeansConfig,
        shard_index: usize,
        shard_count: usize,
    ) -> Result<PartialFitState> {
        if shard_count == 0 {
            return Err(Error::Config("partial fit shard_count must be positive".into()));
        }
        if shard_index >= shard_count {
            return Err(Error::Config(format!(
                "partial fit shard_index {shard_index} out of range for {shard_count} shards"
            )));
        }
        kcfg.validate(ds.n())?;
        ds.validate()?;
        let mut timer = PhaseTimer::new();
        timer.enter(Phase::Init);
        let n = ds.n();
        let k = kcfg.k;
        let (lo, hi) = (shard_index * n / shard_count, (shard_index + 1) * n / shard_count);
        let centroids = init::initialize(&ds, &kcfg)?;
        let slice_n = hi - lo;
        let mut assignments = vec![0u32; slice_n];
        let bounds = match algo {
            Algorithm::Lloyd => {
                let mut best = vec![0.0f32; slice_n];
                let mut second = vec![0.0f32; slice_n];
                kernel::nearest_into(
                    &ds.points, lo, hi, &centroids, &mut assignments, &mut best, &mut second,
                );
                SliceBounds::Lloyd
            }
            Algorithm::Hamerly => {
                let mut ub = vec![0.0f32; slice_n];
                let mut lb = vec![0.0f32; slice_n];
                let mut best = vec![0.0f32; slice_n];
                let mut second = vec![0.0f32; slice_n];
                kernel::nearest_into(
                    &ds.points, lo, hi, &centroids, &mut assignments, &mut best, &mut second,
                );
                for j in 0..slice_n {
                    ub[j] = best[j].sqrt();
                    lb[j] = second[j].sqrt();
                }
                SliceBounds::Hamerly { ub, lb }
            }
            Algorithm::Elkan => {
                // Elkan compares in sqrt space: convert each kernel tile
                // entry to a distance before the argmin compare, exactly
                // as the solo fit's bound initialisation does.
                let mut ub = vec![0.0f32; slice_n];
                let mut lb = vec![0.0f32; slice_n * k];
                let mut tile = vec![0.0f32; kernel::TILE_POINTS * k];
                let mut j0 = 0usize;
                while j0 < slice_n {
                    let j1 = (j0 + kernel::TILE_POINTS).min(slice_n);
                    kernel::sq_dist_block(
                        &ds.points, lo + j0, lo + j1, &centroids, &mut tile[..(j1 - j0) * k],
                    );
                    for j in j0..j1 {
                        let lbrow = &mut lb[j * k..(j + 1) * k];
                        let mut best = f32::INFINITY;
                        let mut arg = 0usize;
                        for (c, slot) in lbrow.iter_mut().enumerate() {
                            let d = tile[(j - j0) * k + c].sqrt();
                            *slot = d;
                            if d < best {
                                best = d;
                                arg = c;
                            }
                        }
                        assignments[j] = arg as u32;
                        ub[j] = best;
                    }
                    j0 = j1;
                }
                SliceBounds::Elkan { ub, lb }
            }
            Algorithm::Yinyang => {
                let n_groups = kcfg.effective_groups().clamp(1, k);
                let grouping = yinyang::group_centroids(&centroids, n_groups, kcfg.seed);
                let idx: Vec<usize> = (lo..hi).collect();
                let sub = Dataset {
                    name: ds.name.clone(),
                    points: ds.points.gather_rows(&idx),
                    labels: None,
                };
                let (st, _) = yinyang::FilterState::init_full_scan(&sub, &centroids, &grouping);
                assignments.copy_from_slice(&st.assignments);
                SliceBounds::Yinyang { sub, grouping, st }
            }
        };
        timer.exit();
        Ok(PartialFitState {
            ds,
            kcfg,
            shard_index,
            shard_count,
            lo,
            hi,
            init: centroids.clone(),
            centroids,
            epoch: 1,
            assignments,
            bounds,
            timer,
        })
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn shard_index(&self) -> usize {
        self.shard_index
    }

    pub fn shard_count(&self) -> usize {
        self.shard_count
    }

    /// Slice boundaries `[lo, hi)` in global point indices.
    pub fn slice(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    pub fn k(&self) -> usize {
        self.kcfg.k
    }

    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// The deterministic initial centroids every shard agrees on.
    pub fn init_centroids(&self) -> &Matrix {
        &self.init
    }

    /// This slice's per-cluster partial sums + counts for the current
    /// epoch's assignments — the shard's contribution to the front's
    /// reduction. Empty slices return an all-zero accumulator.
    pub fn partial(&mut self) -> PartialAccumulator {
        self.timer.enter(Phase::Reduce);
        let mut acc = PartialAccumulator::new(self.kcfg.k, self.ds.d());
        for (j, &a) in self.assignments.iter().enumerate() {
            acc.add_point(self.ds.points.row(self.lo + j), a as usize);
        }
        self.timer.exit();
        acc
    }

    /// Per-phase totals accumulated so far (`None` when profiling is off).
    pub fn phase_totals(&mut self) -> Option<crate::obs::profile::PhaseTotals> {
        self.timer.totals()
    }

    /// Accept the reduced centroids for the just-completed epoch, apply
    /// the same drift-based bound updates the solo fit applies when it is
    /// not converged, and run the next assignment pass over the slice.
    pub fn apply_sync(&mut self, new_c: &Matrix) -> Result<()> {
        let (k, d) = (self.kcfg.k, self.ds.d());
        if new_c.rows() != k || new_c.cols() != d {
            return Err(Error::Config(format!(
                "centroid sync is {}x{}, expected {}x{}",
                new_c.rows(),
                new_c.cols(),
                k,
                d
            )));
        }
        let (drifts, max_drift) = centroid_drifts(&self.centroids, new_c);
        let (lo, slice_n) = (self.lo, self.hi - self.lo);
        match &mut self.bounds {
            SliceBounds::Lloyd => {
                self.timer.enter(Phase::Assign);
                let mut best = vec![0.0f32; slice_n];
                let mut second = vec![0.0f32; slice_n];
                kernel::nearest_into(
                    &self.ds.points,
                    lo,
                    lo + slice_n,
                    new_c,
                    &mut self.assignments,
                    &mut best,
                    &mut second,
                );
            }
            SliceBounds::Hamerly { ub, lb } => {
                self.timer.enter(Phase::Bounds);
                for j in 0..slice_n {
                    ub[j] = inflate_ub(ub[j], drifts[self.assignments[j] as usize]);
                    lb[j] = deflate_lb(lb[j], max_drift);
                }
                self.timer.enter(Phase::Assign);
                let (s_half, _) = half_nearest_other(new_c);
                for j in 0..slice_n {
                    let row = self.ds.points.row(lo + j);
                    let a = self.assignments[j] as usize;
                    let m = lb[j].max(s_half[a]);
                    if filter_safe(m, ub[j]) {
                        continue;
                    }
                    let exact = kernel::dist_pair(row, new_c.row(a));
                    ub[j] = exact;
                    if filter_safe(m, ub[j]) {
                        continue;
                    }
                    let (arg, best, second) = scan_all(row, new_c);
                    self.assignments[j] = arg as u32;
                    ub[j] = best.sqrt();
                    lb[j] = second.sqrt();
                }
            }
            SliceBounds::Elkan { ub, lb } => {
                self.timer.enter(Phase::Bounds);
                for j in 0..slice_n {
                    ub[j] = inflate_ub(ub[j], drifts[self.assignments[j] as usize]);
                    let lbrow = &mut lb[j * k..(j + 1) * k];
                    for c in 0..k {
                        lbrow[c] = deflate_lb(lbrow[c], drifts[c]);
                    }
                }
                self.timer.enter(Phase::Assign);
                let (s_half, _) = half_nearest_other(new_c);
                for j in 0..slice_n {
                    let row = self.ds.points.row(lo + j);
                    let mut a = self.assignments[j] as usize;
                    if filter_safe(s_half[a], ub[j]) {
                        continue;
                    }
                    let lbrow = &mut lb[j * k..(j + 1) * k];
                    let mut ub_i = ub[j];
                    let mut tight = false;
                    for c in 0..k {
                        if c == a {
                            continue;
                        }
                        if filter_safe(lbrow[c], ub_i) {
                            continue;
                        }
                        if !tight {
                            ub_i = kernel::dist_pair(row, new_c.row(a));
                            lbrow[a] = ub_i;
                            tight = true;
                            if filter_safe(lbrow[c], ub_i) {
                                continue;
                            }
                        }
                        let dc = kernel::dist_pair(row, new_c.row(c));
                        lbrow[c] = dc;
                        if dc < ub_i {
                            a = c;
                            ub_i = dc;
                        }
                    }
                    ub[j] = ub_i;
                    self.assignments[j] = a as u32;
                }
            }
            SliceBounds::Yinyang { sub, grouping, st } => {
                self.timer.enter(Phase::Bounds);
                let group_drifts = group_max_drifts(&drifts, &grouping.group_of, grouping.n_groups());
                st.apply_drifts(&drifts, &group_drifts);
                self.timer.enter(Phase::Assign);
                for (j, row) in sub.points.rows_iter().enumerate() {
                    yinyang::step_point(row, new_c, grouping, &drifts, &group_drifts, j, st);
                }
                self.assignments.copy_from_slice(&st.assignments);
            }
        }
        self.timer.exit();
        self.centroids = new_c.clone();
        self.epoch += 1;
        Ok(())
    }

    /// Seal the slice against the final centroids: the slice's assignment
    /// vector (to be concatenated in shard order) and its exact inertia
    /// contribution (to be merged across shards). No reassignment happens
    /// here — exactly like the solo fits, the final assignments are the
    /// ones from the last completed pass.
    pub fn finish(&mut self, final_c: &Matrix) -> Result<(Vec<u32>, ExactSum)> {
        if final_c.rows() != self.kcfg.k || final_c.cols() != self.ds.d() {
            return Err(Error::Config(format!(
                "final centroids are {}x{}, expected {}x{}",
                final_c.rows(),
                final_c.cols(),
                self.kcfg.k,
                self.ds.d()
            )));
        }
        self.timer.enter(Phase::Reduce);
        let mut inertia = ExactSum::new();
        for (j, &a) in self.assignments.iter().enumerate() {
            inertia.add(kernel::sq_dist_pair(self.ds.points.row(self.lo + j), final_c.row(a as usize)));
        }
        self.timer.exit();
        Ok((self.assignments.clone(), inertia))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, Algorithm};

    #[test]
    fn native_engine_loop_matches_lloyd() {
        let ds = synth::blobs(700, 9, 4, 3);
        let kcfg = KMeansConfig { k: 4, seed: 13, ..Default::default() };
        let direct = kmeans::fit(Algorithm::Lloyd, &ds, &kcfg).unwrap();
        let out = run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap();
        assert_eq!(direct.assignments, out.fit.assignments);
        assert_eq!(direct.centroids, out.fit.centroids);
        assert_eq!(direct.iterations, out.fit.iterations);
        assert!(out.report.tiles_dispatched > 0);
    }

    #[test]
    fn filtering_reduces_rescans() {
        let ds = synth::blobs(4000, 8, 6, 9);
        let kcfg = KMeansConfig { k: 6, seed: 3, max_iters: 50, ..Default::default() };
        let out = run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap();
        let dense = (ds.n() * out.fit.iterations) as u64;
        assert!(
            out.report.points_rescanned < dense,
            "rescans {} should be under dense {}",
            out.report.points_rescanned,
            dense
        );
    }

    #[test]
    fn engine_tile_matches_aot_tile() {
        // The scheduler tile must equal the AOT kernel tile so the XLA
        // engine never pads mid-run (checked against the python constant).
        assert_eq!(ENGINE_TILE, 256);
    }

    #[test]
    fn stepwise_state_matches_monolithic_run() {
        // Driving FitState by hand must reproduce run_with_engine exactly
        // — the contract serve's lockstep batch executor relies on.
        let ds = synth::blobs(900, 7, 5, 21);
        let kcfg = KMeansConfig { k: 5, seed: 2, ..Default::default() };
        let reference = run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap();

        let mut eng = NativeEngine;
        let mut st = FitState::new(&ds, &kcfg).unwrap();
        while !st.done() {
            let out = match st.begin_iteration() {
                Dispatch::Dense => Some(eng.assign_tile(st.points(), st.centroids()).unwrap()),
                Dispatch::Survivors(pts) => {
                    Some(eng.assign_tile(&pts, st.centroids()).unwrap())
                }
                Dispatch::Skip => None,
            };
            st.complete_iteration(out.as_ref()).unwrap();
        }
        let stepped = st.finish("native");
        assert_eq!(reference.fit.assignments, stepped.fit.assignments);
        assert_eq!(reference.fit.centroids, stepped.fit.centroids);
        assert_eq!(reference.fit.iterations, stepped.fit.iterations);
        assert_eq!(reference.report.tiles_dispatched, stepped.report.tiles_dispatched);
        assert_eq!(reference.report.points_rescanned, stepped.report.points_rescanned);
    }

    #[test]
    fn pinned_kernels_report_their_filter_savings() {
        // The acceptance contrast in miniature: yinyang prunes points via
        // its global filter, lloyd (by construction) never does — and the
        // report's work rollup must show exactly that.
        let ds = synth::blobs(2000, 8, 5, 4);
        let kcfg = KMeansConfig { k: 5, seed: 6, max_iters: 40, ..Default::default() };
        let yy = run_algorithm(Algorithm::Yinyang, "native", &ds, &kcfg).unwrap();
        let ll = run_algorithm(Algorithm::Lloyd, "native", &ds, &kcfg).unwrap();
        assert!(yy.report.work.points_pruned > 0, "yinyang must prune");
        assert!(yy.report.work.dist_comps_avoided > 0);
        assert_eq!(ll.report.work.points_pruned, 0, "lloyd filters nothing");
        assert_eq!(ll.report.work.dist_comps_avoided, 0);
        // Same clustering either way — pinning changes work, not results.
        assert_eq!(yy.fit.assignments, ll.fit.assignments);
    }

    #[test]
    fn complete_without_begin_is_an_error() {
        let ds = synth::blobs(50, 4, 2, 1);
        let kcfg = KMeansConfig { k: 2, seed: 1, ..Default::default() };
        let mut st = FitState::new(&ds, &kcfg).unwrap();
        assert!(st.complete_iteration(None).is_err());
    }

    #[test]
    fn dense_dispatch_requires_output() {
        let ds = synth::blobs(50, 4, 2, 1);
        let kcfg = KMeansConfig { k: 2, seed: 1, ..Default::default() };
        let mut st = FitState::new(&ds, &kcfg).unwrap();
        assert!(matches!(st.begin_iteration(), Dispatch::Dense));
        assert!(st.complete_iteration(None).is_err());
    }
}
