//! `kpynq` — the KPynq launcher.
//!
//! Subcommands (hand-rolled parsing; `clap` is not in the offline crate
//! universe):
//!
//! ```text
//! kpynq run [--config FILE] [--dataset NAME] [--k K] [--backend B] [--software]
//! kpynq serve [--jobs FILE] [--workers N] [--batch N]   NDJSON fit jobs → pool
//! kpynq serve --listen ADDR [--max-conns N]             persistent daemon (PROTOCOL.md)
//! kpynq cluster --shards N --listen ADDR                N shard daemons, one endpoint
//! kpynq cluster --remote A,B --listen ADDR              multi-host: attach to remote daemons
//! kpynq datasets                      list the built-in dataset generators
//! kpynq resources [--d D] [--k K]     lane-count frontier on both parts
//! kpynq init-config                   print an example config file
//! kpynq info                          artifact / environment summary
//! ```

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use kpynq::config::{RunConfig, EXAMPLE};
use kpynq::coordinator::{KpynqSystem, SystemConfig};
use kpynq::data::synth;
use kpynq::hw::filter_unit::FilterUnitConfig;
use kpynq::hw::resource::{self, ProblemShape};
use kpynq::hw::ZynqPart;
use kpynq::kmeans;
use kpynq::obs;
use kpynq::runtime::manifest::Manifest;
use kpynq::util::bench::Table;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "cluster" => cmd_cluster(rest),
        "datasets" => cmd_datasets(),
        "resources" => cmd_resources(rest),
        "init-config" => {
            print!("{EXAMPLE}");
            Ok(())
        }
        "info" => cmd_info(rest),
        "table" => cmd_table(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    eprintln!(
        "kpynq — work-efficient triangle-inequality K-means (KPynq reproduction)\n\
         \n\
         usage: kpynq <command> [options]\n\
         \n\
         commands:\n\
         \x20 run          cluster a dataset (simulated FPGA, native or XLA backend)\n\
         \x20 serve        serve line-delimited JSON fit jobs on a sharded worker pool\n\
         \x20 cluster      one endpoint over N shard daemons (spawned + supervised)\n\
         \x20 datasets     list built-in dataset generators\n\
         \x20 resources    print the lane-count frontier for the supported parts\n\
         \x20 init-config  print an example TOML config\n\
         \x20 info         artifact/environment summary\n\
         \x20 table        run the T1/T2 evaluation (options: --points N, --json FILE)\n\
         \n\
         run options:\n\
         \x20 --config FILE    load a TOML config (see `kpynq init-config`)\n\
         \x20 --dataset NAME   override dataset (gassensor|kegg|roadnetwork|uscensus|covtype|mnist|blobs|uniform|file)\n\
         \x20 --k K            override cluster count\n\
         \x20 --max-points N   subsample cap\n\
         \x20 --backend B      fpga-sim | native | xla (xla needs the `xla` cargo feature + `make artifacts`)\n\
         \x20 --software       run the software algorithm (config [kmeans].algorithm) instead of a backend\n\
         \x20 --verify         cross-check the result against a direct Lloyd run\n\
         \x20 --profile        per-phase solver timers (init/assign/bounds/update/reduce);\n\
         \x20                  provably non-perturbing — results stay bit-identical\n\
         \n\
         serve options (jobs: one JSON object per line, `#` comments allowed;\n\
         e.g. {{\"id\":1,\"dataset\":\"kegg\",\"k\":16,\"backend\":\"native\",\"priority\":\"high\"}}):\n\
         \x20 --jobs FILE      read NDJSON jobs from FILE (default: stdin)\n\
         \x20 --config FILE    load the [serve] pool shape from a TOML config\n\
         \x20 --workers N      worker shards (default 2)\n\
         \x20 --queue N        admission queue capacity (default 64)\n\
         \x20 --batch N        micro-batch cap, 1 disables coalescing (default 8)\n\
         \x20 --shed POLICY    block | shed (full-queue policy, default block)\n\
         \x20 --tenant-weights L  weighted-fair scheduling, e.g. acme=3,free=1\n\
         \x20                  (unlisted tenants get [serve] default_tenant_weight)\n\
         \x20 --tenant-cap N   max queued jobs per tenant (default 0 = no quota)\n\
         \x20 --cache N        result-cache entries (default 64; 0 disables)\n\
         \x20 --out FILE       write NDJSON responses to FILE (default: stdout)\n\
         \x20                  the ServeReport summary always goes to stderr\n\
         \n\
         serve daemon options (persistent socket front-end, wire format in PROTOCOL.md;\n\
         drain with {{\"op\":\"shutdown\"}} on any connection):\n\
         \x20 --listen ADDR         host:port (0 = ephemeral) or unix:/path.sock\n\
         \x20 --max-conns N         simultaneous client connections (default 32)\n\
         \x20 --idle-timeout-ms N   close idle connections after N ms (default 0 = never)\n\
         \x20 --trace-log FILE      append drained trace spans to FILE as JSONL\n\
         \x20                       (PROTOCOL.md \u{a7}11; spans also drain via {{\"op\":\"trace\"}})\n\
         \x20 --metrics-listen ADDR serve GET /metrics (Prometheus text 0.0.4) on host:port\n\
         \x20                       (own listener — scrapers never consume a job slot)\n\
         \x20 --profile             per-phase solver timers; replies gain phase_*_ms keys\n\
         \n\
         cluster options (cross-process shards behind one endpoint; same wire\n\
         protocol as the daemon — external clients cannot tell the difference):\n\
         \x20 --listen ADDR         the front door (required; host:port or unix:/path.sock)\n\
         \x20 --shards N            shard daemon processes (default 2; [cluster] in config)\n\
         \x20 --remote A,B,…        remote mode: attach to already-running daemons at these\n\
         \x20                       addresses (host:port or unix:/path.sock) instead of\n\
         \x20                       spawning local shards; lost links reconnect under the\n\
         \x20                       [cluster] reconnect_* policy, dead ones are routed around\n\
         \x20 --socket-dir DIR      shard unix-socket directory (default: temp dir)\n\
         \x20 --max-restarts N      respawns (local) / reconnects (remote) per shard\n\
         \x20                       before abandoning it\n\
         \x20 --mode MODE           request (default: route each job whole to one shard) or\n\
         \x20                       map-reduce (slice each job's points across all shards;\n\
         \x20                       one fit scales with shard count, results bit-identical)\n\
         \x20 plus the serve pool flags (--workers/--queue/--batch/--shed/\n\
         \x20 --tenant-weights/--tenant-cap, per shard; --cache at the front)\n\
         \x20 and the daemon flags (--max-conns/--idle-timeout-ms/--trace-log/\n\
         \x20 --metrics-listen/--profile, at the front; a front scrape merges every\n\
         \x20 shard's registry, labeled by shard)\n\
         \n\
         environment:\n\
         \x20 KPYNQ_LOG=error|warn|info|debug   stderr log threshold (default info)"
    );
}

/// Pull `--flag value` out of an argument list.
fn take_opt(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn cmd_run(args: &[String]) -> kpynq::Result<()> {
    let mut cfg = match take_opt(args, "--config") {
        Some(path) => RunConfig::from_file(Path::new(&path))?,
        None => RunConfig::default(),
    };
    if let Some(ds) = take_opt(args, "--dataset") {
        cfg.dataset = ds;
    }
    if let Some(k) = take_opt(args, "--k") {
        cfg.kmeans.k = k
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --k '{k}'")))?;
    }
    if let Some(mp) = take_opt(args, "--max-points") {
        cfg.max_points = mp
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --max-points '{mp}'")))?;
    }
    if let Some(b) = take_opt(args, "--backend") {
        cfg.backend_name = b;
        cfg.validate()?;
    }
    if has_flag(args, "--profile") || cfg.profile {
        obs::profile::set_enabled(true);
    }

    let ds = cfg.load_dataset()?;
    println!(
        "dataset {} — {} points × {} dims, k={}, seed={}",
        ds.name,
        ds.n(),
        ds.d(),
        cfg.kmeans.k,
        cfg.kmeans.seed
    );

    if has_flag(args, "--software") {
        let t0 = std::time::Instant::now();
        let fit = kmeans::fit(cfg.algorithm, &ds, &cfg.kmeans)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "software {}: inertia {:.4}, {} iters ({}), {:.3}s wall, {} distance comps \
             ({:.1}% of lloyd)",
            cfg.algorithm.name(),
            fit.inertia,
            fit.iterations,
            if fit.converged { "converged" } else { "max-iters" },
            dt,
            fit.stats.total_dist_comps(),
            fit.stats.work_ratio(ds.n(), cfg.kmeans.k) * 100.0
        );
        if let Some(p) = &fit.stats.phases {
            println!("{}", render_phases(p));
        }
        return Ok(());
    }

    let sys = KpynqSystem::new(SystemConfig {
        backend: cfg.backend(),
        verify: has_flag(args, "--verify"),
    })?;
    let out = sys.cluster(&ds, &cfg.kmeans)?;
    println!(
        "backend {}: inertia {:.4}, {} iters ({})",
        out.report.backend,
        out.fit.inertia,
        out.fit.iterations,
        if out.fit.converged { "converged" } else { "max-iters" },
    );
    if out.report.total_cycles > 0 {
        println!(
            "simulated: {} PL cycles = {:.4}s at 100 MHz | pipeline busy {:.1}% | DMA {:.1} MB",
            out.report.total_cycles,
            out.report.sim_seconds,
            out.report.pipeline_utilization * 100.0,
            out.report.dma_bytes as f64 / 1e6
        );
    } else {
        println!(
            "measured: {:.3}s wall | {} tiles dispatched | {} points rescanned",
            out.report.wall_seconds, out.report.tiles_dispatched, out.report.points_rescanned
        );
    }
    if let Some(p) = &out.report.phases {
        println!("{}", render_phases(p));
    }
    Ok(())
}

/// One-line per-phase wall-time split for `--profile` runs.
fn render_phases(p: &kpynq::obs::profile::PhaseTotals) -> String {
    use kpynq::obs::profile::Phase;
    let mut s = String::from("phases:");
    for ph in Phase::ALL {
        s.push_str(&format!(" {} {:.3}ms", ph.name(), p.get(ph)));
    }
    s.push_str(&format!(" (total {:.3}ms)", p.total_ms()));
    s
}

/// Scheduling/caching knobs shared by `serve` and `cluster`:
/// `--tenant-weights acme=3,free=1`, `--tenant-cap N`, `--cache N`.
fn apply_qos_flags(args: &[String], scfg: &mut kpynq::serve::ServeConfig) -> kpynq::Result<()> {
    if let Some(list) = take_opt(args, "--tenant-weights") {
        let entries: Vec<String> = list
            .split(',')
            .map(|e| e.trim().to_string())
            .filter(|e| !e.is_empty())
            .collect();
        scfg.tenant_weights = kpynq::serve::ServeConfig::parse_tenant_weights(&entries)?;
    }
    if let Some(c) = take_opt(args, "--tenant-cap") {
        scfg.tenant_queue_cap = c
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --tenant-cap '{c}'")))?;
    }
    if let Some(c) = take_opt(args, "--cache") {
        scfg.cache_capacity = c
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --cache '{c}'")))?;
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> kpynq::Result<()> {
    use kpynq::serve::{FitRequest, Server, ShedPolicy};

    let cfg = match take_opt(args, "--config") {
        Some(path) => RunConfig::from_file(Path::new(&path))?,
        None => RunConfig::default(),
    };
    let mut scfg = cfg.serve_config()?;
    if let Some(w) = take_opt(args, "--workers") {
        scfg.workers = w
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --workers '{w}'")))?;
    }
    if let Some(q) = take_opt(args, "--queue") {
        scfg.queue_capacity = q
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --queue '{q}'")))?;
    }
    if let Some(b) = take_opt(args, "--batch") {
        scfg.max_batch = b
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --batch '{b}'")))?;
    }
    if let Some(s) = take_opt(args, "--shed") {
        scfg.shed_policy = ShedPolicy::from_name(&s)?;
    }
    apply_qos_flags(args, &mut scfg)?;
    scfg.validate()?;
    if has_flag(args, "--profile") || cfg.profile {
        obs::profile::set_enabled(true);
    }

    // Daemon mode: `--listen` (or a `[serve.net] listen` config entry)
    // turns the one-shot filter into the persistent socket front-end.
    let listen = take_opt(args, "--listen")
        .or_else(|| (!cfg.serve_listen.is_empty()).then(|| cfg.serve_listen.clone()));
    if let Some(addr) = listen {
        // One-shot-only flags must fail loudly here, not be silently
        // ignored — a daemon reads jobs from its socket, not from files.
        for flag in ["--jobs", "--out"] {
            if has_flag(args, flag) {
                return Err(kpynq::Error::Config(format!(
                    "{flag} is a one-shot serve option; the daemon (--listen {addr}) \
                     exchanges NDJSON over the socket (see PROTOCOL.md)"
                )));
            }
        }
        return cmd_serve_daemon(args, &cfg, scfg, &addr);
    }

    // Fail fast on an unwritable --out: a bad path must surface before the
    // serving session runs, not after it — results would be lost.
    let out_path = take_opt(args, "--out");
    if let Some(path) = &out_path {
        std::fs::write(path, "")?;
    }

    let text = match take_opt(args, "--jobs") {
        Some(path) => std::fs::read_to_string(&path)?,
        None => {
            use std::io::Read;
            obs::log::info("serve", "reading NDJSON jobs from stdin (one object per line, EOF ends)...");
            let mut s = String::new();
            std::io::stdin().read_to_string(&mut s)?;
            s
        }
    };
    let mut jobs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let req = FitRequest::from_json_line(line)
            .map_err(|e| kpynq::Error::Parse(format!("jobs line {}: {e}", lineno + 1)))?;
        jobs.push(req);
    }
    obs::log::info(
        "serve",
        &format!(
            "serving {} jobs on {} workers (queue {}, batch {}, {} policy)",
            jobs.len(),
            scfg.workers,
            scfg.queue_capacity,
            scfg.max_batch,
            scfg.shed_policy.name()
        ),
    );

    let outcome = Server::new(scfg)?.run(jobs)?;

    // Responses as NDJSON (stdout or --out) — the report goes to stderr so
    // stdout stays machine-parseable.
    let mut ndjson = String::new();
    for resp in &outcome.responses {
        ndjson.push_str(&resp.to_json().to_string());
        ndjson.push('\n');
    }
    match &out_path {
        Some(path) => {
            std::fs::write(path, &ndjson)?;
            obs::log::info("serve", &format!("wrote {} responses to {path}", outcome.responses.len()));
        }
        None => print!("{ndjson}"),
    }
    eprint!("{}", outcome.report.render());
    Ok(())
}

/// `kpynq serve --listen`: run the persistent daemon until a client sends
/// `{"op":"shutdown"}` (PROTOCOL.md §6), then print the session report.
fn cmd_serve_daemon(
    args: &[String],
    cfg: &RunConfig,
    scfg: kpynq::serve::ServeConfig,
    addr: &str,
) -> kpynq::Result<()> {
    use kpynq::serve::net::{Daemon, PROTO_VERSION};

    let mut net = cfg.net_config()?;
    if let Some(n) = take_opt(args, "--max-conns") {
        net.max_conns = n
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --max-conns '{n}'")))?;
    }
    if let Some(t) = take_opt(args, "--idle-timeout-ms") {
        net.idle_timeout_ms = t
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --idle-timeout-ms '{t}'")))?;
    }
    if let Some(p) = take_opt(args, "--trace-log") {
        net.trace_log = Some(p);
    }
    if let Some(m) = take_opt(args, "--metrics-listen") {
        net.metrics_listen = Some(m);
    }
    net.validate()?;

    let daemon = Daemon::bind(addr, net, scfg)?;
    obs::log::info(
        "serve",
        &format!(
            "kpynq serve: listening on {} (proto {PROTO_VERSION}, {} workers, batch {}, {} policy; \
             NDJSON jobs per PROTOCOL.md, drain with {{\"op\":\"shutdown\"}})",
            daemon.local_addr(),
            daemon.serve_config().workers,
            daemon.serve_config().max_batch,
            daemon.serve_config().shed_policy.name(),
        ),
    );
    if let Some(maddr) = daemon.metrics_addr() {
        obs::log::info("serve", &format!("metrics: GET http://{maddr}/metrics (Prometheus text 0.0.4)"));
    }
    let report = daemon.run()?;
    eprint!("{}", report.render());
    Ok(())
}

/// `kpynq cluster`: spawn and supervise N shard daemons behind one
/// listener (wire surface identical to `kpynq serve --listen`; the
/// fan-out/fan-in and crash-recovery contracts are in DESIGN.md §2).
fn cmd_cluster(args: &[String]) -> kpynq::Result<()> {
    use kpynq::cluster::Cluster;
    use kpynq::serve::net::PROTO_VERSION;
    use kpynq::serve::ShedPolicy;

    let cfg = match take_opt(args, "--config") {
        Some(path) => RunConfig::from_file(Path::new(&path))?,
        None => RunConfig::default(),
    };
    // Per-shard pool shape: [serve] section + the serve pool flags.
    let mut scfg = cfg.serve_config()?;
    if let Some(w) = take_opt(args, "--workers") {
        scfg.workers = w
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --workers '{w}'")))?;
    }
    if let Some(q) = take_opt(args, "--queue") {
        scfg.queue_capacity = q
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --queue '{q}'")))?;
    }
    if let Some(b) = take_opt(args, "--batch") {
        scfg.max_batch = b
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --batch '{b}'")))?;
    }
    if let Some(s) = take_opt(args, "--shed") {
        scfg.shed_policy = ShedPolicy::from_name(&s)?;
    }
    apply_qos_flags(args, &mut scfg)?;

    // The flag-overridden pool shape replaces cluster_config()'s copy;
    // the single ccfg.validate() below covers both it and the cluster
    // fields (no separate scfg.validate() needed).
    let mut ccfg = cfg.cluster_config()?;
    ccfg.serve = scfg;
    if let Some(n) = take_opt(args, "--shards") {
        ccfg.shards = n
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --shards '{n}'")))?;
    }
    if let Some(d) = take_opt(args, "--socket-dir") {
        ccfg.socket_dir = PathBuf::from(d);
    }
    if let Some(r) = take_opt(args, "--max-restarts") {
        ccfg.max_restarts = r
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --max-restarts '{r}'")))?;
    }
    if let Some(m) = take_opt(args, "--mode") {
        ccfg.fit_mode = kpynq::cluster::FitMode::from_name(&m)?;
    }
    if let Some(list) = take_opt(args, "--remote") {
        let addrs: Vec<String> = list
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        if addrs.is_empty() {
            return Err(kpynq::Error::Config(
                "--remote needs a comma-separated address list (host:port or unix:/path.sock)"
                    .into(),
            ));
        }
        ccfg.remote_shards = addrs;
    }
    ccfg.validate()?;

    let listen = take_opt(args, "--listen")
        .or_else(|| (!cfg.serve_listen.is_empty()).then(|| cfg.serve_listen.clone()))
        .ok_or_else(|| {
            kpynq::Error::Config(
                "kpynq cluster needs --listen ADDR (or [serve.net] listen in the config)".into(),
            )
        })?;
    let mut net = cfg.net_config()?;
    if let Some(n) = take_opt(args, "--max-conns") {
        net.max_conns = n
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --max-conns '{n}'")))?;
    }
    if let Some(t) = take_opt(args, "--idle-timeout-ms") {
        net.idle_timeout_ms = t
            .parse()
            .map_err(|_| kpynq::Error::Config(format!("bad --idle-timeout-ms '{t}'")))?;
    }
    if let Some(p) = take_opt(args, "--trace-log") {
        net.trace_log = Some(p);
    }
    if let Some(m) = take_opt(args, "--metrics-listen") {
        net.metrics_listen = Some(m);
    }
    net.validate()?;
    // Enables the front's own timers; spawned local shards inherit the
    // flag through their command line only if the operator passes it to
    // the shard binary via config — the front still merges whatever
    // phase series the shards report.
    if has_flag(args, "--profile") || cfg.profile {
        obs::profile::set_enabled(true);
    }

    let shards = ccfg.shard_count();
    let workers = ccfg.serve.workers;
    let fit_mode = ccfg.fit_mode.name();
    let mode = if ccfg.remote_shards.is_empty() {
        "local".to_string()
    } else {
        format!("remote: {}", ccfg.remote_shards.join(", "))
    };
    let cluster = Cluster::start(&listen, net, ccfg)?;
    obs::log::info(
        "cluster",
        &format!(
            "kpynq cluster: {} shards ({}) x {} workers behind {}, {} fits (proto \
             {PROTO_VERSION}; NDJSON jobs per PROTOCOL.md, drain with {{\"op\":\"shutdown\"}})",
            shards,
            mode,
            workers,
            cluster.local_addr(),
            fit_mode,
        ),
    );
    let report = cluster.run()?;
    eprint!("{}", report.render());
    Ok(())
}

fn cmd_datasets() -> kpynq::Result<()> {
    let mut t = Table::new(&["name", "n", "d", "modes", "character"]);
    for s in synth::uci_specs() {
        t.row(vec![
            s.name.to_string(),
            s.n.to_string(),
            s.d.to_string(),
            s.modes.to_string(),
            format!(
                "imbalance {:.1}, noise {:.2}, active dims {:.0}%",
                s.imbalance,
                s.noise_frac,
                s.active_dims_frac * 100.0
            ),
        ]);
    }
    t.print();
    println!("plus: blobs (easy synthetic), uniform (adversarial), .kpm/.csv files");
    Ok(())
}

fn cmd_resources(args: &[String]) -> kpynq::Result<()> {
    let d: usize = take_opt(args, "--d").and_then(|v| v.parse().ok()).unwrap_or(64);
    let k: usize = take_opt(args, "--k").and_then(|v| v.parse().ok()).unwrap_or(16);
    let g = (k + 9) / 10;
    let shape = ProblemShape::new(k, d, g.max(1), 256);
    let filt = FilterUnitConfig::default();
    for part in [ZynqPart::xc7z020(), ZynqPart::zu7ev()] {
        println!("part {} (d={d}, k={k}):", part.name);
        let mut t = Table::new(&["lanes", "mac_width", "DSP", "BRAM_18K", "LUT", "fits"]);
        for &w in &[4u64, 8] {
            for &lanes in &[1u64, 2, 4, 8, 16, 32] {
                let pipe = kpynq::hw::pipeline::PipelineConfig { lanes, mac_width: w };
                let est = resource::estimate(&pipe, &filt, &shape);
                t.row(vec![
                    lanes.to_string(),
                    w.to_string(),
                    format!("{}/{}", est.dsp, part.dsp),
                    format!("{}/{}", est.bram_18k, part.bram_18k),
                    format!("{}/{}", est.luts, part.luts),
                    if est.fits(&part) { "yes".into() } else { "NO".into() },
                ]);
            }
        }
        t.print();
    }
    Ok(())
}

fn cmd_table(args: &[String]) -> kpynq::Result<()> {
    use kpynq::harness;
    let points: usize = take_opt(args, "--points")
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let k: usize = take_opt(args, "--k").and_then(|v| v.parse().ok()).unwrap_or(16);
    let suite = harness::bench_suite(2019, points);
    let kcfg = kpynq::kmeans::KMeansConfig { k, seed: 7, max_iters: 100, ..Default::default() };
    let acfg = kpynq::hw::AccelConfig::default();
    let cpu = harness::default_cpu();
    let mut rows = Vec::new();
    for ds in &suite {
        rows.push(harness::speedup_energy_row(ds, &kcfg, &acfg, &cpu)?);
    }
    print!("{}", harness::render_speedup_table(&rows));
    if let Some(path) = take_opt(args, "--json") {
        std::fs::write(&path, harness::speedup_rows_to_json(&rows).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> kpynq::Result<()> {
    let dir = take_opt(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    println!("kpynq {} — three-layer KPynq reproduction", env!("CARGO_PKG_VERSION"));
    match Manifest::load(&PathBuf::from(&dir)) {
        Ok(m) => {
            println!("artifacts: {} modules in {dir} (tile_n = {})", m.artifacts.len(), m.tile_n);
            let mut t = Table::new(&["name", "entry", "d", "k", "g"]);
            for a in &m.artifacts {
                t.row(vec![
                    a.name.clone(),
                    a.entry.clone(),
                    a.d.to_string(),
                    a.k.to_string(),
                    a.g.to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
