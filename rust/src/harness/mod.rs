//! Experiment harness: regenerates the paper's reported results.
//!
//! Each bench target (`rust/benches/`) and the end-to-end example call
//! into this module so table logic lives in one tested place:
//!
//! * [`speedup_energy_row`] — one row of T1 (speedup) + T2 (energy):
//!   CPU-model baseline vs. simulated KPynq on one dataset.
//! * [`filter_ablation_row`] — F2: distance-computation work ratios for
//!   {none, point-level, multi-level} filter configurations.
//! * [`parallelism_point`] — F3: cycles + resource fit across lane counts.
//! * [`dma_breakdown_row`] — F5: where the cycles go.
//!
//! Aggregates use the geometric mean, the standard way to average ratios
//! across benchmarks.

use crate::data::Dataset;
use crate::error::Result;
use crate::hw::cpu_model::CpuModel;
use crate::hw::energy::PowerModel;
use crate::hw::filter_unit::FilterUnitConfig;
use crate::hw::pipeline::PipelineConfig;
use crate::hw::resource::{self, ProblemShape};
use crate::hw::{AccelConfig, Accelerator, ZynqPart};
use crate::kmeans::{self, init, Algorithm, KMeansConfig};
use crate::util::bench::Table;

/// One dataset's speedup + energy numbers.
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    pub dataset: String,
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub iterations: usize,
    /// CPU baseline (standard K-means) time from the machine model.
    pub cpu_seconds: f64,
    /// Simulated KPynq time.
    pub fpga_seconds: f64,
    pub speedup: f64,
    /// Fraction of Lloyd's distance work the filter actually performed.
    pub work_ratio: f64,
    pub cpu_joules: f64,
    pub fpga_joules: f64,
    pub energy_efficiency: f64,
}

/// Run the T1/T2 comparison on one dataset.
///
/// Both sides run to the *same* trajectory (exact algorithms, same init),
/// so the iteration count is shared and the comparison isolates the
/// architecture, exactly as in the paper.
pub fn speedup_energy_row(
    ds: &Dataset,
    kcfg: &KMeansConfig,
    acfg: &AccelConfig,
    cpu: &CpuModel,
) -> Result<SpeedupRow> {
    let init_c = init::initialize(ds, kcfg)?;
    let acc = Accelerator::new(acfg.clone());
    let run = acc.run_fit(ds, kcfg, init_c)?;
    let iterations = run.fit.iterations;

    let cpu_seconds = cpu.run_seconds(ds.n(), kcfg.k, ds.d(), iterations);
    let energy = acfg.power.compare(run.seconds, run.pipeline_utilization, cpu_seconds);

    Ok(SpeedupRow {
        dataset: ds.name.clone(),
        n: ds.n(),
        d: ds.d(),
        k: kcfg.k,
        iterations,
        cpu_seconds,
        fpga_seconds: run.seconds,
        speedup: cpu_seconds / run.seconds,
        work_ratio: run.fit.stats.work_ratio(ds.n(), kcfg.k),
        cpu_joules: energy.cpu_joules,
        fpga_joules: energy.fpga_joules,
        energy_efficiency: energy.efficiency_ratio,
    })
}

/// Render T1/T2 rows as the paper-style table.
pub fn render_speedup_table(rows: &[SpeedupRow]) -> String {
    let mut t = Table::new(&[
        "dataset", "n", "d", "k", "iters", "cpu (s)", "kpynq (s)", "speedup",
        "work", "energy-eff",
    ]);
    for r in rows {
        t.row(vec![
            r.dataset.clone(),
            r.n.to_string(),
            r.d.to_string(),
            r.k.to_string(),
            r.iterations.to_string(),
            format!("{:.4}", r.cpu_seconds),
            format!("{:.4}", r.fpga_seconds),
            format!("{:.2}x", r.speedup),
            format!("{:.1}%", r.work_ratio * 100.0),
            format!("{:.1}x", r.energy_efficiency),
        ]);
    }
    let speedups: Vec<f64> = rows.iter().map(|r| r.speedup).collect();
    let effs: Vec<f64> = rows.iter().map(|r| r.energy_efficiency).collect();
    let mut s = t.render();
    s.push_str(&format!(
        "geomean speedup {:.2}x (max {:.2}x) | geomean energy-eff {:.1}x (max {:.1}x)\n",
        crate::util::stats::geomean(&speedups),
        speedups.iter().cloned().fold(0.0, f64::max),
        crate::util::stats::geomean(&effs),
        effs.iter().cloned().fold(0.0, f64::max),
    ));
    s
}

/// One dataset's filter-ablation numbers (F2).
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub dataset: String,
    /// Work ratios (fraction of n·k·iters distance computations).
    pub lloyd: f64,
    pub point_level: f64,  // Hamerly: global/point filter only
    pub multi_level: f64,  // Yinyang: group + point filters
    pub elkan: f64,        // software upper bound on filtering
    /// Simulated cycle counts with filters off / on.
    pub cycles_off: u64,
    pub cycles_on: u64,
}

/// Run the F2 ablation on one dataset.
pub fn filter_ablation_row(
    ds: &Dataset,
    kcfg: &KMeansConfig,
    acfg: &AccelConfig,
) -> Result<AblationRow> {
    let init_c = init::initialize(ds, kcfg)?;
    let lloyd = kmeans::fit_from(Algorithm::Lloyd, ds, kcfg, init_c.clone())?;
    let hamerly = kmeans::fit_from(Algorithm::Hamerly, ds, kcfg, init_c.clone())?;
    let elkan = kmeans::fit_from(Algorithm::Elkan, ds, kcfg, init_c.clone())?;
    let yinyang = kmeans::fit_from(Algorithm::Yinyang, ds, kcfg, init_c.clone())?;

    let on = Accelerator::new(AccelConfig { enable_filters: true, ..acfg.clone() })
        .run_fit(ds, kcfg, init_c.clone())?;
    let off = Accelerator::new(AccelConfig { enable_filters: false, ..acfg.clone() })
        .run_fit(ds, kcfg, init_c)?;

    let wr = |f: &kmeans::FitResult| f.stats.work_ratio(ds.n(), kcfg.k);
    Ok(AblationRow {
        dataset: ds.name.clone(),
        lloyd: wr(&lloyd),
        point_level: wr(&hamerly),
        multi_level: wr(&yinyang),
        elkan: wr(&elkan),
        cycles_off: off.total_cycles,
        cycles_on: on.total_cycles,
    })
}

/// One lane-count point of the F3 parallelism sweep.
#[derive(Clone, Debug)]
pub struct ParallelismPoint {
    pub lanes: u64,
    pub fits: bool,
    pub dsp: u64,
    pub bram: u64,
    pub cycles: Option<u64>,
    pub seconds: Option<f64>,
}

/// Evaluate one lane count on one dataset (F3).
pub fn parallelism_point(
    ds: &Dataset,
    kcfg: &KMeansConfig,
    lanes: u64,
    mac_width: u64,
    part: &ZynqPart,
) -> Result<ParallelismPoint> {
    let pipe = PipelineConfig { lanes, mac_width };
    let g = kcfg.effective_groups().clamp(1, kcfg.k);
    let shape = ProblemShape::new(kcfg.k, ds.d(), g, 256);
    let est = resource::estimate(&pipe, &FilterUnitConfig::default(), &shape);
    let fits = est.fits(part);
    let (cycles, seconds) = if fits {
        let acfg = AccelConfig { pipeline: pipe, part: part.clone(), ..Default::default() };
        let init_c = init::initialize(ds, kcfg)?;
        let run = Accelerator::new(acfg).run_fit(ds, kcfg, init_c)?;
        (Some(run.total_cycles), Some(run.seconds))
    } else {
        (None, None)
    };
    Ok(ParallelismPoint { lanes, fits, dsp: est.dsp, bram: est.bram_18k, cycles, seconds })
}

/// F5: cycle breakdown shares for one run.
#[derive(Clone, Debug)]
pub struct DmaBreakdownRow {
    pub dataset: String,
    pub dma_in_frac: f64,
    pub filter_frac: f64,
    pub pipeline_frac: f64,
    pub ps_update_frac: f64,
    /// Overlap efficiency: serial-sum / makespan (≥ 1; higher = better
    /// double buffering).
    pub overlap_gain: f64,
}

/// Compute the F5 row for one dataset.
pub fn dma_breakdown_row(
    ds: &Dataset,
    kcfg: &KMeansConfig,
    acfg: &AccelConfig,
) -> Result<DmaBreakdownRow> {
    let init_c = init::initialize(ds, kcfg)?;
    let run = Accelerator::new(acfg.clone()).run_fit(ds, kcfg, init_c)?;
    let mut dma_in = 0u64;
    let mut filter = 0u64;
    let mut pipe = 0u64;
    let mut ps = 0u64;
    let mut serial = 0u64;
    let mut makespan = 0u64;
    for it in &run.iters {
        dma_in += it.dma_in;
        filter += it.filter;
        pipe += it.pipeline;
        ps += it.ps_update;
        serial += it.serial_sum();
        makespan += it.total;
    }
    let total = (dma_in + filter + pipe + ps).max(1) as f64;
    Ok(DmaBreakdownRow {
        dataset: ds.name.clone(),
        dma_in_frac: dma_in as f64 / total,
        filter_frac: filter as f64 / total,
        pipeline_frac: pipe as f64 / total,
        ps_update_frac: ps as f64 / total,
        overlap_gain: serial as f64 / makespan.max(1) as f64,
    })
}

/// The benchmark-scale dataset suite: the six UCI-equivalents, subsampled
/// to keep full-suite bench runs tractable while preserving geometry
/// (`cap = 0` disables subsampling for the end-to-end example).
pub fn bench_suite(seed: u64, cap: usize) -> Vec<Dataset> {
    crate::data::synth::uci_all(seed)
        .into_iter()
        .map(|ds| {
            let mut out = if cap > 0 { ds.subsample(cap, seed) } else { ds };
            // Normalised features, as the fixed-point datapath requires.
            crate::data::normalize::min_max(&mut out);
            // Benchmarks never consult ground truth; drop it so the suite's
            // memory footprint is just the points.
            out.labels = None;
            out
        })
        .collect()
}

/// Default power model shared by benches (kept here so T1/T2 agree).
pub fn default_power() -> PowerModel {
    PowerModel::default()
}

/// Serialise T1/T2 rows as JSON (machine-readable experiment record; the
/// CLI's `table` command writes these next to the human tables).
pub fn speedup_rows_to_json(rows: &[SpeedupRow]) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let arr = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("dataset".into(), Json::Str(r.dataset.clone()));
            m.insert("n".into(), Json::Num(r.n as f64));
            m.insert("d".into(), Json::Num(r.d as f64));
            m.insert("k".into(), Json::Num(r.k as f64));
            m.insert("iterations".into(), Json::Num(r.iterations as f64));
            m.insert("cpu_seconds".into(), Json::Num(r.cpu_seconds));
            m.insert("fpga_seconds".into(), Json::Num(r.fpga_seconds));
            m.insert("speedup".into(), Json::Num(r.speedup));
            m.insert("work_ratio".into(), Json::Num(r.work_ratio));
            m.insert("energy_efficiency".into(), Json::Num(r.energy_efficiency));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("experiment".into(), Json::Str("t1_t2_speedup_energy".into()));
    top.insert(
        "geomean_speedup".into(),
        Json::Num(crate::util::stats::geomean(
            &rows.iter().map(|r| r.speedup).collect::<Vec<_>>(),
        )),
    );
    top.insert("rows".into(), Json::Arr(arr));
    Json::Obj(top)
}

/// Default CPU baseline model shared by benches.
pub fn default_cpu() -> CpuModel {
    CpuModel::default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn small_cfg() -> KMeansConfig {
        KMeansConfig { k: 8, seed: 42, max_iters: 30, ..Default::default() }
    }

    #[test]
    fn speedup_row_is_self_consistent() {
        let ds = synth::blobs(3000, 32, 8, 5);
        let row = speedup_energy_row(
            &ds,
            &small_cfg(),
            &AccelConfig::default(),
            &CpuModel::default(),
        )
        .unwrap();
        assert!((row.speedup - row.cpu_seconds / row.fpga_seconds).abs() < 1e-9);
        assert!((row.energy_efficiency - row.cpu_joules / row.fpga_joules).abs() < 1e-9);
        assert!(row.work_ratio > 0.0 && row.work_ratio <= 1.01);
        assert!(row.iterations > 1);
    }

    #[test]
    fn ablation_orders_filters_correctly() {
        let ds = synth::blobs(4000, 16, 8, 7);
        let row = filter_ablation_row(&ds, &small_cfg(), &AccelConfig::default()).unwrap();
        assert!((row.lloyd - 1.0).abs() < 1e-9, "lloyd is the 100% yardstick");
        assert!(row.point_level < row.lloyd);
        assert!(row.multi_level <= row.point_level * 1.05);
        assert!(row.elkan <= row.multi_level * 1.5);
        assert!(row.cycles_on < row.cycles_off);
    }

    #[test]
    fn parallelism_sweep_has_a_frontier() {
        let ds = synth::blobs(2000, 32, 8, 9);
        let part = ZynqPart::xc7z020();
        let mut prev_cycles = u64::MAX;
        let mut saw_unfit = false;
        for lanes in [1u64, 2, 4, 8, 16, 32, 64] {
            let p = parallelism_point(&ds, &small_cfg(), lanes, 4, &part).unwrap();
            if let Some(c) = p.cycles {
                assert!(c <= prev_cycles, "more lanes should not be slower");
                prev_cycles = c;
            } else {
                saw_unfit = true;
            }
        }
        assert!(saw_unfit, "the sweep must eventually exceed the 7020");
    }

    #[test]
    fn breakdown_fracs_sum_to_one() {
        let ds = synth::blobs(2000, 16, 4, 11);
        let row = dma_breakdown_row(&ds, &small_cfg(), &AccelConfig::default()).unwrap();
        let sum = row.dma_in_frac + row.filter_frac + row.pipeline_frac + row.ps_update_frac;
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(row.overlap_gain >= 1.0);
    }

    #[test]
    fn bench_suite_is_capped_and_normalized() {
        let suite = bench_suite(1, 2000);
        assert_eq!(suite.len(), 6);
        for ds in &suite {
            assert!(ds.n() <= 2000);
            assert!(ds.points.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }
}
