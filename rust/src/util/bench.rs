//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bencher`]: warmup, then repeated timed runs
//! until both a minimum iteration count and a minimum wall-clock budget are
//! met, reporting median / mean / min over per-iteration times. A
//! [`black_box`] re-export prevents the optimiser from deleting measured
//! work. The output format is stable and table-like so bench logs are
//! directly pasteable into EXPERIMENTS.md.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats;

/// Re-exported optimisation barrier.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with a wall-clock budget.
pub struct Bencher {
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Minimum total time spent in timed iterations.
    pub min_time: Duration,
    /// Hard cap on iterations (slow end-to-end benches).
    pub max_iters: usize,
    /// Warmup iterations (untimed).
    pub warmup_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000_000,
            warmup_iters: 2,
        }
    }
}

impl Bencher {
    /// A bencher sized for expensive end-to-end runs (seconds each).
    pub fn end_to_end() -> Self {
        Self {
            min_iters: 3,
            min_time: Duration::from_millis(200),
            max_iters: 10,
            warmup_iters: 1,
        }
    }

    /// Time `f`, printing and returning the measurement.
    pub fn bench<F, R>(&self, name: &str, mut f: F) -> Measurement
    where
        F: FnMut() -> R,
    {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let budget_start = Instant::now();
        while (times.len() < self.min_iters
            || budget_start.elapsed() < self.min_time)
            && times.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let med = stats::median(&times);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        let m = Measurement {
            name: name.to_string(),
            iters: times.len(),
            median: Duration::from_secs_f64(med),
            mean: Duration::from_secs_f64(mean),
            min: Duration::from_secs_f64(min),
            max: Duration::from_secs_f64(max),
        };
        println!(
            "bench {:<44} iters {:>5}  median {:>12}  mean {:>12}  min {:>12}",
            m.name,
            m.iters,
            fmt_duration(m.median),
            fmt_duration(m.mean),
            fmt_duration(m.min),
        );
        m
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            min_iters: 5,
            min_time: Duration::from_millis(1),
            max_iters: 50,
            warmup_iters: 1,
        };
        let m = b.bench("noop-sum", || (0..100u64).sum::<u64>());
        assert!(m.iters >= 5);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["dataset", "speedup"]);
        t.row(vec!["kegg".into(), "3.10x".into()]);
        t.row(vec!["roadnetwork".into(), "1.95x".into()]);
        let r = t.render();
        assert!(r.contains("| roadnetwork |"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
