//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bencher`]: warmup, then repeated timed runs
//! until both a minimum iteration count and a minimum wall-clock budget are
//! met, reporting median / mean / min over per-iteration times. A
//! [`black_box`] re-export prevents the optimiser from deleting measured
//! work. The output format is stable and table-like so bench logs are
//! directly pasteable into EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::hint::black_box as std_black_box;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// Re-exported optimisation barrier.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// The measurement as a JSON object (seconds as floats), in the same
    /// hand-rolled encoding the obs snapshot uses.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert("iters".to_string(), Json::Num(self.iters as f64));
        o.insert("median_s".to_string(), Json::Num(self.median.as_secs_f64()));
        o.insert("mean_s".to_string(), Json::Num(self.mean.as_secs_f64()));
        o.insert("min_s".to_string(), Json::Num(self.min.as_secs_f64()));
        o.insert("max_s".to_string(), Json::Num(self.max.as_secs_f64()));
        Json::Obj(o)
    }
}

/// Everything the benches of this process have produced so far:
/// measurements from every [`Bencher`] plus tables registered with
/// [`record_table`]. Drained by [`write_bench_json`].
static RECORDED_MEASUREMENTS: Mutex<Vec<Measurement>> = Mutex::new(Vec::new());
static RECORDED_TABLES: Mutex<Vec<(String, Json)>> = Mutex::new(Vec::new());

/// Register a finished result table for the bench's JSON artifact.
pub fn record_table(name: &str, t: &Table) {
    RECORDED_TABLES.lock().unwrap().push((name.to_string(), t.to_json()));
}

/// Drain everything recorded so far and write it as `BENCH_<name>.json`
/// (in the working directory — the repo root under `cargo bench`),
/// serialized with the same encoder as the obs metrics snapshot, which is
/// embedded under `"metrics"` so kernel work-efficiency counters that
/// accumulated during the bench ride along. Returns the path written.
pub fn write_bench_json(name: &str) -> std::io::Result<String> {
    let measurements: Vec<Measurement> =
        std::mem::take(&mut *RECORDED_MEASUREMENTS.lock().unwrap());
    let tables: Vec<(String, Json)> = std::mem::take(&mut *RECORDED_TABLES.lock().unwrap());
    let mut o = BTreeMap::new();
    o.insert("bench".to_string(), Json::Str(name.to_string()));
    o.insert(
        "measurements".to_string(),
        Json::Arr(measurements.iter().map(Measurement::to_json).collect()),
    );
    o.insert("tables".to_string(), Json::Obj(tables.into_iter().collect()));
    o.insert("metrics".to_string(), crate::obs::global().snapshot());
    let path = format!("BENCH_{name}.json");
    std::fs::write(&path, format!("{}\n", Json::Obj(o)))?;
    Ok(path)
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with a wall-clock budget.
pub struct Bencher {
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Minimum total time spent in timed iterations.
    pub min_time: Duration,
    /// Hard cap on iterations (slow end-to-end benches).
    pub max_iters: usize,
    /// Warmup iterations (untimed).
    pub warmup_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000_000,
            warmup_iters: 2,
        }
    }
}

impl Bencher {
    /// A bencher sized for expensive end-to-end runs (seconds each).
    pub fn end_to_end() -> Self {
        Self {
            min_iters: 3,
            min_time: Duration::from_millis(200),
            max_iters: 10,
            warmup_iters: 1,
        }
    }

    /// Time `f`, printing and returning the measurement.
    pub fn bench<F, R>(&self, name: &str, mut f: F) -> Measurement
    where
        F: FnMut() -> R,
    {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut times = Vec::new();
        let budget_start = Instant::now();
        while (times.len() < self.min_iters
            || budget_start.elapsed() < self.min_time)
            && times.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let med = stats::median(&times);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        let m = Measurement {
            name: name.to_string(),
            iters: times.len(),
            median: Duration::from_secs_f64(med),
            mean: Duration::from_secs_f64(mean),
            min: Duration::from_secs_f64(min),
            max: Duration::from_secs_f64(max),
        };
        RECORDED_MEASUREMENTS.lock().unwrap().push(m.clone());
        println!(
            "bench {:<44} iters {:>5}  median {:>12}  mean {:>12}  min {:>12}",
            m.name,
            m.iters,
            fmt_duration(m.median),
            fmt_duration(m.mean),
            fmt_duration(m.min),
        );
        m
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// The table as a JSON array of `{header: cell}` objects (all cells
    /// stay strings — they are already formatted for display).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|row| {
                    Json::Obj(
                        self.headers
                            .iter()
                            .cloned()
                            .zip(row.iter().map(|c| Json::Str(c.clone())))
                            .collect(),
                    )
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            min_iters: 5,
            min_time: Duration::from_millis(1),
            max_iters: 50,
            warmup_iters: 1,
        };
        let m = b.bench("noop-sum", || (0..100u64).sum::<u64>());
        assert!(m.iters >= 5);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["dataset", "speedup"]);
        t.row(vec!["kegg".into(), "3.10x".into()]);
        t.row(vec!["roadnetwork".into(), "1.95x".into()]);
        let r = t.render();
        assert!(r.contains("| roadnetwork |"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn table_and_measurement_encode_as_json() {
        let mut t = Table::new(&["dataset", "speedup"]);
        t.row(vec!["kegg".into(), "3.10x".into()]);
        let j = t.to_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("dataset").unwrap().as_str().unwrap(), "kegg");
        assert_eq!(rows[0].get("speedup").unwrap().as_str().unwrap(), "3.10x");

        let m = Measurement {
            name: "noop".into(),
            iters: 3,
            median: Duration::from_millis(2),
            mean: Duration::from_millis(2),
            min: Duration::from_millis(1),
            max: Duration::from_millis(3),
        };
        let mj = m.to_json();
        assert_eq!(mj.get("iters").unwrap().as_usize().unwrap(), 3);
        assert!((mj.get("median_s").unwrap().as_f64().unwrap() - 0.002).abs() < 1e-9);
    }
}
