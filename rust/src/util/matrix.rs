//! Dense row-major `f32` matrix — the storage type for points and centroids.
//!
//! Deliberately minimal: K-means needs contiguous row access, squared
//! distances and a handful of row-wise updates. Everything hot lives in
//! `kmeans::*` as free functions over `&[f32]` slices so the compiler can
//! vectorise without abstraction in the way.

use crate::error::{Error, Result};

/// Row-major matrix of `rows × cols` f32 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Wrap an existing buffer; fails if the length is not `rows * cols`.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Data(format!(
                "buffer of {} values cannot be a {}x{} matrix",
                data.len(), rows, cols
            )));
        }
        Ok(Self { data, rows, cols })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Copy a set of rows into a new matrix (used by tile compaction).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Iterate over rows.
    pub fn rows_iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols)
    }
}

/// Squared Euclidean distance between two equal-length slices.
///
/// Eight independent accumulator lanes over `chunks_exact(8)`: the fixed-
/// size chunk arrays eliminate bounds checks and give LLVM a clean 8-wide
/// reduction to vectorise without `-ffast-math` reassociation permission —
/// the same shape as the FPGA's MAC tree (DESIGN.md §Perf, L3 hot path).
/// Deliberately `d * d + acc`, NOT `f32::mul_add`: without `-C
/// target-feature=+fma` the latter lowers to a libm `fmaf` call and is ~6×
/// slower (measured in the hotpath bench).
///
/// Edge semantics (pinned by the table-driven tests below; the tiled
/// kernel `kmeans::kernel` inherits them verbatim since every tile entry
/// is this reduction):
///
/// * length 0 ⇒ `+0.0`; all-equal finite inputs ⇒ `+0.0` (never `-0.0`,
///   even when coordinates mix `±0.0` — IEEE-754 `(-0.0)+(+0.0) = +0.0`
///   and squares are non-negative).
/// * any `NaN` coordinate ⇒ `NaN`; `∞` coordinate opposite a finite one
///   ⇒ `+∞`; `∞` opposite `∞` (same sign) ⇒ `NaN` (`∞ − ∞`). NaN/∞ are
///   *propagated, not filtered* — callers wanting validation do it at
///   ingest (`Dataset::validate`), not per distance.
/// * subnormal differences underflow to `+0.0` when `d·d` rounds below
///   the smallest subnormal — two distinct points can legally be at
///   squared distance zero. Bound logic must therefore never divide by a
///   squared distance without checking it.
/// * identical behavior in the 8-lane body and the `len % 8` remainder
///   tail: the tests sweep a special value through every position of a
///   length-9 slice (lanes and tail) and every length 0..=17.
///
/// Note the result is *not* guaranteed bit-equal to a naive sequential
/// `Σ(aᵢ-bᵢ)²` for arbitrary finite inputs — the 8-lane pairwise
/// reduction associates differently. The normative reference for the
/// kernel equivalence battery is this function itself, applied per pair.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        // Fixed-size views: no bounds checks inside the loop body.
        let xa: &[f32; 8] = xa.try_into().unwrap();
        let xb: &[f32; 8] = xb.try_into().unwrap();
        for l in 0..8 {
            let d = xa[l] - xb[l];
            lanes[l] += d * d;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        tail += d * d;
    }
    let s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    s + tail
}

/// Euclidean distance. Inherits `sq_dist`'s edge semantics; additionally
/// `sqrt` maps `NaN` to `NaN` and never produces a negative zero.
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f32 {
    sq_dist(a, b).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_access() {
        let mut m = Matrix::zeros(3, 4);
        m.row_mut(1)[2] = 5.0;
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
        assert_eq!(m.as_slice()[6], 5.0);
    }

    #[test]
    fn from_vec_validates_shape() {
        assert!(Matrix::from_vec(vec![1.0; 6], 2, 3).is_ok());
        assert!(Matrix::from_vec(vec![1.0; 5], 2, 3).is_err());
    }

    #[test]
    fn gather_rows_copies() {
        let m = Matrix::from_vec((0..12).map(|x| x as f32).collect(), 4, 3).unwrap();
        let g = m.gather_rows(&[3, 0]);
        assert_eq!(g.row(0), &[9.0, 10.0, 11.0]);
        assert_eq!(g.row(1), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn sq_dist_matches_naive_for_all_lengths() {
        for n in 0..33 {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..n).map(|i| (n - i) as f32 * 0.25).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let got = sq_dist(&a, &b);
            assert!((got - naive).abs() <= 1e-4 * naive.max(1.0), "n={n}");
        }
    }

    #[test]
    fn dist_is_sqrt_of_sq_dist() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 6.0, 3.0];
        assert!((dist(&a, &b) - 5.0).abs() < 1e-6);
    }

    /// What the doc contract calls "edge semantics": table-driven pins for
    /// NaN, ±0.0, infinities and subnormal underflow, exercised in both an
    /// 8-lane body position (index 3 of a length-9 slice) and the
    /// remainder tail (index 8).
    #[test]
    fn sq_dist_edge_semantics_table() {
        #[derive(Clone, Copy)]
        enum Expect {
            /// Exact bit pattern (covers the +0.0-not--0.0 pins).
            Bits(f32),
            IsNan,
            IsPosInf,
        }
        let tiny = f32::from_bits(1); // smallest positive subnormal
        let sub = 1.0e-40f32; // subnormal; sub*sub underflows to 0
        // (name, position, a-value, b-value, expectation); position is
        // patched into otherwise-zero length-9 slices.
        let table: &[(&str, usize, f32, f32, Expect)] = &[
            ("signed zeros in lane", 3, -0.0, 0.0, Expect::Bits(0.0)),
            ("signed zeros in tail", 8, -0.0, 0.0, Expect::Bits(0.0)),
            ("nan in lane", 3, f32::NAN, 0.0, Expect::IsNan),
            ("nan in tail", 8, f32::NAN, 0.0, Expect::IsNan),
            ("nan on rhs", 3, 0.0, f32::NAN, Expect::IsNan),
            ("inf in lane", 3, f32::INFINITY, 0.0, Expect::IsPosInf),
            ("inf in tail", 8, f32::INFINITY, 0.0, Expect::IsPosInf),
            ("neg inf", 3, f32::NEG_INFINITY, 1.0, Expect::IsPosInf),
            ("inf minus inf in lane", 3, f32::INFINITY, f32::INFINITY, Expect::IsNan),
            ("inf minus inf in tail", 8, f32::INFINITY, f32::INFINITY, Expect::IsNan),
            ("min subnormal underflows (lane)", 3, tiny, 0.0, Expect::Bits(0.0)),
            ("min subnormal underflows (tail)", 8, tiny, 0.0, Expect::Bits(0.0)),
            ("1e-40 diff underflows", 3, sub, 0.0, Expect::Bits(0.0)),
            ("equal subnormals cancel", 3, sub, sub, Expect::Bits(0.0)),
        ];
        for &(name, pos, av, bv, want) in table {
            let mut a = [0.0f32; 9];
            let mut b = [0.0f32; 9];
            a[pos] = av;
            b[pos] = bv;
            let got = sq_dist(&a, &b);
            match want {
                Expect::Bits(w) => {
                    assert_eq!(got.to_bits(), w.to_bits(), "{name}: got {got}");
                }
                Expect::IsNan => assert!(got.is_nan(), "{name}: got {got}"),
                Expect::IsPosInf => {
                    assert!(got.is_infinite() && got > 0.0, "{name}: got {got}")
                }
            }
        }
    }

    #[test]
    fn sq_dist_zero_length_is_positive_zero() {
        let got = sq_dist(&[], &[]);
        assert_eq!(got.to_bits(), 0.0f32.to_bits());
    }

    /// A single nonzero difference has exactly one nonzero term, so the
    /// reduction order cannot matter: the result must be bit-equal to
    /// `diff²` wherever the difference sits — lane body or remainder tail
    /// — for every length 0..=17 (two full chunks plus every tail size).
    #[test]
    fn sq_dist_remainder_path_every_length_and_position() {
        for len in 1..=17usize {
            for pos in 0..len {
                let mut a = vec![0.0f32; len];
                let b = vec![0.0f32; len];
                a[pos] = 3.0;
                let got = sq_dist(&a, &b);
                assert_eq!(got.to_bits(), 9.0f32.to_bits(), "len={len} pos={pos}");
            }
        }
    }

    #[test]
    fn dist_propagates_nan_and_never_negative() {
        let mut a = [0.0f32; 9];
        a[4] = f32::NAN;
        assert!(dist(&a, &[0.0; 9]).is_nan());
        let d = dist(&[-0.0, 0.0], &[0.0, -0.0]);
        assert_eq!(d.to_bits(), 0.0f32.to_bits());
    }
}
