//! Small self-contained utilities.
//!
//! The default build has **zero external dependencies** (the optional `xla`
//! feature is the one exception, and it is off unless the PJRT crate is
//! vendored — see `Cargo.toml`), so several things that would normally be
//! external crates live here instead: a deterministic RNG ([`rng`]), a JSON
//! reader / writer ([`json`]), a TOML-subset reader ([`toml`]), a benchmark
//! timer ([`bench`]) and a property-test driver ([`proptest`]).

pub mod bench;
pub mod json;
pub mod matrix;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod toml;
