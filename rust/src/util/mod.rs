//! Small self-contained utilities.
//!
//! The offline crate universe for this build contains only the `xla`
//! dependency closure, so several things that would normally be external
//! crates live here instead: a deterministic RNG ([`rng`]), a JSON reader /
//! writer ([`json`]), a TOML-subset reader ([`toml`]), a benchmark timer
//! ([`bench`]) and a property-test driver ([`proptest`]).

pub mod bench;
pub mod json;
pub mod matrix;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod toml;
