//! Tiny property-test driver (the `proptest` crate is unavailable offline).
//!
//! [`run_cases`] feeds a closure `CASES` independent deterministic RNG
//! streams; the closure generates its own random instance and asserts its
//! invariant, returning `Err(description)` on violation. On failure the
//! driver reports the failing case index and seed so the case can be
//! replayed exactly — no shrinking, but instances are kept small by
//! construction so raw counterexamples stay readable.

use super::rng::Rng;

/// Number of random cases per property (tuned so the whole L3 property
/// suite stays under a few seconds in `cargo test`).
pub const CASES: usize = 100;

/// Run `cases` random trials of `prop`, panicking with context on failure.
pub fn run_cases_n<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' violated on case {case}/{cases} \
                 (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// [`run_cases_n`] with the default case count.
pub fn run_cases<F>(name: &str, seed: u64, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    run_cases_n(name, seed, CASES, prop)
}

/// Helper: random small clustering instance (points, n, d, k) for
/// algorithm-equivalence properties.
pub fn small_instance(rng: &mut Rng) -> (Vec<f32>, usize, usize, usize) {
    let n = 8 + rng.next_below(120);
    let d = 1 + rng.next_below(12);
    let k = 1 + rng.next_below(8.min(n));
    // A mixture of a few loose blobs — representative geometry, and with
    // enough spread that near-ties are rare but possible.
    let centers: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 3.0)).collect();
    let mut pts = Vec::with_capacity(n * d);
    for _ in 0..n {
        let c = rng.next_below(k);
        for j in 0..d {
            pts.push(centers[c * d + j] + rng.normal_f32(0.0, 0.7));
        }
    }
    (pts, n, d, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_cases_n("counts", 1, 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' violated")]
    fn failing_property_panics_with_context() {
        run_cases_n("always-fails", 2, 10, |_| Err("boom".into()));
    }

    #[test]
    fn small_instance_is_well_formed() {
        run_cases("instance-shape", 3, |rng| {
            let (pts, n, d, k) = small_instance(rng);
            if pts.len() != n * d {
                return Err(format!("len {} != {}*{}", pts.len(), n, d));
            }
            if k == 0 || k > n {
                return Err(format!("bad k={k} for n={n}"));
            }
            if !pts.iter().all(|x| x.is_finite()) {
                return Err("non-finite point".into());
            }
            Ok(())
        });
    }
}
