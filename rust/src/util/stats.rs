//! Streaming statistics helpers shared by the harness and the benches.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Geometric mean of a slice (used for "average speedup across datasets",
/// matching how hardware papers aggregate ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Nearest-rank percentile (`p` in 0..=100; copies + sorts — fine for
/// report-sized inputs). Nearest-rank returns an element of `xs`, so for
/// even-length input `percentile(xs, 50.0)` is the lower-middle element,
/// not [`median`]'s interpolated value. Serving latency reports use
/// p50/p95.
///
/// Edge behavior, pinned by `percentile_window_edges` (these windows are
/// routine for an idle `serve::net` daemon, not corner cases):
///
/// * **empty input** → NaN — there is no latency to report; aggregators
///   like `serve::ServeReport` must guard and substitute their zero
///   default rather than propagate NaN onto a wire surface;
/// * **single sample** → that sample, for every `p` (including 0 and 100);
/// * `p` outside 0..=100 clamps to the extreme elements;
/// * NaN *elements* sort last (`f64::total_cmp`) instead of panicking —
///   a poisoned sample can skew a tail percentile but never abort a
///   report build mid-session.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Median (copies + sorts; fine for report-sized inputs). Empty input →
/// NaN; NaN elements sort last rather than panicking (see [`percentile`]).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_window_edges() {
        // Empty window (an idle daemon reporting period): NaN, for every p.
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert!(percentile(&[], p).is_nan());
        }
        // Single-sample window: that sample, for every p.
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&[3.25], p), 3.25);
        }
        // Two samples: p50 is the lower element (nearest rank, not the
        // interpolated median), p95 the upper.
        assert_eq!(percentile(&[10.0, 20.0], 50.0), 10.0);
        assert_eq!(percentile(&[10.0, 20.0], 95.0), 20.0);
        assert_eq!(median(&[10.0, 20.0]), 15.0);
        // Out-of-range p clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[1.0, 2.0, 3.0], -10.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0], 150.0), 3.0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_sort() {
        // total_cmp sorts NaN after every number: the finite percentiles
        // stay sane and nothing aborts mid-report.
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
        assert_eq!(median(&[1.0, f64::NAN, 2.0]), 2.0);
    }
}
