//! Deterministic random number generation.
//!
//! Xoshiro256++ seeded through SplitMix64 — the standard pairing from the
//! xoshiro reference implementations. Every dataset generator and every
//! randomized test in the crate derives from one of these, so all results
//! are exactly reproducible from a single `u64` seed across platforms.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box–Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits / 2^53 — the canonical double construction.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) with rejection to remove modulo bias.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "next_below(0)");
        let bound = bound as u64;
        // Lemire-style threshold rejection.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let m = (r as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= threshold {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln() finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.next_normal() as f32) * std + mean
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    /// Falls back to uniform if all weights are zero.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return self.next_below(weights.len());
        }
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent child stream (for per-thread / per-tile use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut r = Rng::new(13);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
