//! Minimal JSON reader/writer.
//!
//! The offline crate universe has no `serde_json`, so the crate carries its
//! own reader for the two JSON surfaces it touches: the AOT manifest
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) and the
//! experiment reports emitted by the harness. Full JSON grammar, no
//! extensions; numbers are parsed as `f64` (the manifest only contains
//! integers small enough to round-trip exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors (all return Parse errors with a path-ish message) --

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Parse(format!("missing key '{key}'"))),
            _ => Err(Error::Parse(format!("expected object looking up '{key}'"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > (1u64 << 53) as f64 {
            return Err(Error::Parse(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(Error::Parse(format!("expected array, got {other:?}"))),
        }
    }

    /// Serialise back to compact JSON (used by report writers).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (wanted {lit})")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        // Surrogate pairs are not needed by our producers;
                        // map lone surrogates to the replacement char.
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
          "version": 1,
          "tile_n": 256,
          "artifacts": [
            {"name": "assign_n256_d4_k16", "file": "a.hlo.txt",
             "inputs": [{"shape": [256, 4], "dtype": "f32"}],
             "outputs": [], "d": 4, "k": 16, "g": 8}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "assign_n256_d4_k16");
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 256);
    }

    #[test]
    fn scalar_values() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated", ""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(j, Json::Str("héllo é".into()));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
