//! TOML-subset reader for run configuration files.
//!
//! Supports the subset the KPynq config surface needs: `[section]` headers,
//! `key = value` pairs with string / integer / float / boolean / homogeneous
//! array values, `#` comments and blank lines. No nested tables-in-arrays,
//! no multi-line strings, no datetimes — the config schema (`config.rs`)
//! never produces them.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => Err(Error::Parse(format!("expected integer, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| Error::Parse(format!("expected usize, got {i}")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => Err(Error::Parse(format!("expected float, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(Error::Parse(format!("expected bool, got {other:?}"))),
        }
    }
}

/// `section -> key -> value`. Top-level keys live in the `""` section.
pub type Document = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc: Document = BTreeMap::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut section = String::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| Error::Parse(format!("line {}: unterminated section", lineno + 1)))?
                .trim();
            if name.is_empty() {
                return Err(Error::Parse(format!("line {}: empty section name", lineno + 1)));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Parse(format!("line {}: expected key = value", lineno + 1)))?;
        let key = line[..eq].trim();
        let val_text = line[eq + 1..].trim();
        if key.is_empty() || val_text.is_empty() {
            return Err(Error::Parse(format!("line {}: empty key or value", lineno + 1)));
        }
        let value = parse_value(val_text)
            .map_err(|e| Error::Parse(format!("line {}: {e}", lineno + 1)))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<Value, String> {
    let text = text.trim();
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: std::result::Result<Vec<Value>, String> =
            inner.split(',').map(|s| parse_value(s.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    // Numbers: integer if it parses as i64 and has no '.', 'e' markers.
    let looks_float = text.contains('.') || text.contains('e') || text.contains('E');
    if !looks_float {
        if let Ok(i) = text.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{text}'"))
}

/// Convenience: look up `section.key`, with a default.
pub fn get<'d>(doc: &'d Document, section: &str, key: &str) -> Option<&'d Value> {
    doc.get(section).and_then(|s| s.get(key))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let text = r#"
# KPynq run config
name = "demo"

[algorithm]
k = 16
groups = 8          # yinyang groups
tolerance = 1e-4
use_filters = true

[hardware]
lanes = 8
clock_mhz = 100.0
sweep = [1, 2, 4, 8]
"#;
        let doc = parse(text).unwrap();
        assert_eq!(get(&doc, "", "name").unwrap().as_str().unwrap(), "demo");
        assert_eq!(get(&doc, "algorithm", "k").unwrap().as_usize().unwrap(), 16);
        assert_eq!(get(&doc, "algorithm", "tolerance").unwrap().as_f64().unwrap(), 1e-4);
        assert!(get(&doc, "algorithm", "use_filters").unwrap().as_bool().unwrap());
        assert_eq!(get(&doc, "hardware", "clock_mhz").unwrap().as_f64().unwrap(), 100.0);
        let arr = match get(&doc, "hardware", "sweep").unwrap() {
            Value::Arr(v) => v,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 4);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse("s = \"a # b\"").unwrap();
        assert_eq!(get(&doc, "", "s").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("keyonly").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("x = \"open").is_err());
        assert!(parse("x = [1, 2").is_err());
    }

    #[test]
    fn integer_vs_float() {
        let doc = parse("a = 5\nb = 5.0\nc = 1_000").unwrap();
        assert_eq!(get(&doc, "", "a").unwrap(), &Value::Int(5));
        assert_eq!(get(&doc, "", "b").unwrap(), &Value::Float(5.0));
        assert_eq!(get(&doc, "", "c").unwrap(), &Value::Int(1000));
    }
}
