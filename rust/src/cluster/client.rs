//! The client side of PROTOCOL.md: a blocking connection that speaks to a
//! `kpynq serve --listen` daemon as a peer.
//!
//! Until now every implementation of the wire protocol lived on the
//! server side; [`ClientConn`] is the first *client*, and the cluster
//! front is built out of it — but it is equally usable on its own as a
//! typed alternative to hand-rolled `nc`/python one-liners. It shares the
//! framing implementation with the daemon (`serve::codec`), so there is
//! exactly one reading of PROTOCOL.md §2 in the tree.
//!
//! What it does beyond moving lines:
//!
//! * **Handshake** — reads the greeting, checks `kpynq == "serve"` and
//!   the protocol revision, and asserts `{"proto":1}` back (PROTOCOL.md
//!   §2), so version skew fails at connect time, not mid-stream.
//! * **Id remapping** — [`ClientConn::submit`] rewrites every outgoing
//!   request onto a connection-unique wire id and restores the caller's
//!   id on the way back. Callers can therefore forward requests from
//!   many tenants whose ids collide — exactly what the cluster front
//!   does — without bookkeeping of their own.
//! * **Control frames** — typed `ping` / `stats` / `cancel` round-trips
//!   (job responses arriving in between are buffered, not lost).
//! * **Reconnect with backoff** — [`ClientConn::connect_with_backoff`]
//!   runs the doubling retry loop under a [`ReconnectPolicy`]: the
//!   supervisor leans on it while a freshly spawned shard binds its
//!   socket, and the remote-shards front ([`crate::cluster::remote`])
//!   leans on it to re-establish a lost link to a daemon on another
//!   host.
//!
//! ```no_run
//! use kpynq::cluster::client::ClientConn;
//! use kpynq::serve::FitRequest;
//!
//! let mut c = ClientConn::connect("127.0.0.1:7071").unwrap();
//! c.submit(&FitRequest { id: 1, max_points: 1_000, ..Default::default() }).unwrap();
//! let resp = c.recv_response().unwrap();
//! println!("job {} -> {}", resp.id, resp.status.name());
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::codec::{write_line, LineEvent, LineReader, Stream, WireStream};
use crate::serve::job::{FitRequest, FitResponse};
use crate::serve::net::PROTO_VERSION;
use crate::util::json::Json;

/// The bounded-backoff shape every (re)connect to a protocol peer shares:
/// the supervisor's readiness wait for a freshly spawned local shard and
/// the remote fleet's link re-establishment are the *same* loop with
/// different budgets, so the knobs live here once instead of riding along
/// as loose arguments (they used to — four positional `Duration`/`u32`
/// parameters on `connect_with_backoff`, duplicated at each call site).
///
/// **Total-wait bound.** Retry delays double from [`base_delay`] up to
/// [`max_delay`], and the *sum of backoff sleeps* is additionally capped
/// by [`total_wait`]: each sleep is clamped to the remaining budget, and
/// once the budget is spent the loop stops retrying even if `attempts`
/// remain. The bound is therefore hard for the waiting the policy itself
/// inserts; the connect attempts' own latency (normally instant on a
/// refused loopback port, but up to the OS connect timeout for a
/// black-holed remote host) rides on top and cannot be bounded from
/// here. `rust/src/cluster/client.rs` unit-pins the sleep bound.
///
/// [`base_delay`]: ReconnectPolicy::base_delay
/// [`max_delay`]: ReconnectPolicy::max_delay
/// [`total_wait`]: ReconnectPolicy::total_wait
#[derive(Clone, Debug, PartialEq)]
pub struct ReconnectPolicy {
    /// Connection attempts before giving up (at least 1 is always made).
    pub attempts: u32,
    /// First retry delay; doubles after every failed attempt.
    pub base_delay: Duration,
    /// Cap on the doubled delay.
    pub max_delay: Duration,
    /// Hard bound on the total time spent sleeping between attempts.
    pub total_wait: Duration,
}

impl Default for ReconnectPolicy {
    /// The shard-readiness shape the supervisor has always used: doubling
    /// backoff from 20 ms capped at 250 ms, 45 attempts, ≈ 10 s total —
    /// deliberately bounded, because a respawn runs this inline on the
    /// cluster's monitor thread, which is stalled for the duration.
    fn default() -> Self {
        Self {
            attempts: 45,
            base_delay: Duration::from_millis(20),
            max_delay: Duration::from_millis(250),
            total_wait: Duration::from_secs(10),
        }
    }
}

impl ReconnectPolicy {
    pub fn validate(&self) -> Result<()> {
        if self.attempts == 0 {
            return Err(Error::Config("reconnect attempts must be positive".into()));
        }
        if self.base_delay.is_zero() || self.max_delay < self.base_delay {
            return Err(Error::Config(
                "reconnect base delay must be positive and no larger than the cap".into(),
            ));
        }
        if self.total_wait.is_zero() {
            return Err(Error::Config("reconnect total wait must be positive".into()));
        }
        Ok(())
    }
}

/// A detached handle that can force a connection's socket closed from any
/// thread: both halves of a split [`ClientConn`] then observe EOF/EPIPE
/// and wind down through their normal error paths. This is the remote
/// fleet's analogue of the supervisor's SIGKILL — the only way to
/// "crash" a peer the cluster does not own a process handle for (the
/// hung-link watchdog and the chaos hook both use it). The handle holds
/// its own clone of the socket, deliberately *outside* the writer lock:
/// a force-close must land even when the writer half is wedged
/// mid-`write` on a peer that stopped reading — which is precisely the
/// condition the watchdog fires on.
#[derive(Clone)]
pub struct LinkShutdown {
    stream: Arc<Stream>,
}

impl LinkShutdown {
    /// Shut the socket down in both directions (idempotent).
    pub fn shutdown(&self) {
        self.stream.shutdown_stream();
    }
}

/// Parsed `{"op":"stats"}` reply (PROTOCOL.md §6) — the per-shard load
/// snapshot the cluster router's least-loaded policy reads.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShardStats {
    /// Jobs the shard session has accepted over its lifetime.
    pub submitted: u64,
    /// Jobs sitting in the shard's admission queue right now.
    pub queue_depth: usize,
    pub shed_full: u64,
    pub shed_deadline: u64,
    pub peak_queue_depth: usize,
    pub active_conns: usize,
    /// Milliseconds the shard session has been up (PROTOCOL.md §6;
    /// 0 from servers predating the key).
    pub uptime_ms: u64,
    /// Queued jobs per priority lane, `[high, normal, low]` (PROTOCOL.md
    /// §6; all-zero from servers predating the key).
    pub queue_lanes: [usize; crate::serve::Priority::LEVELS],
}

impl ShardStats {
    fn from_json(j: &Json) -> Result<ShardStats> {
        let num = |key: &str| -> Result<u64> {
            match j.get(key) {
                Ok(v) => Ok(v.as_usize()? as u64),
                Err(_) => Ok(0), // tolerate absent keys (older servers)
            }
        };
        let mut queue_lanes = [0usize; crate::serve::Priority::LEVELS];
        if let Ok(arr) = j.get("queue_lanes").and_then(|v| v.as_arr()) {
            for (slot, v) in queue_lanes.iter_mut().zip(arr.iter()) {
                *slot = v.as_usize().unwrap_or(0);
            }
        }
        Ok(ShardStats {
            submitted: num("submitted")?,
            queue_depth: num("queue_depth")? as usize,
            shed_full: num("shed_full")?,
            shed_deadline: num("shed_deadline")?,
            peak_queue_depth: num("peak_queue_depth")? as usize,
            active_conns: num("active_conns")? as usize,
            uptime_ms: num("uptime_ms")?,
            queue_lanes,
        })
    }
}

/// One frame from the server, classified (PROTOCOL.md §4–§6). Job ids are
/// already restored to the submitter's ids.
#[derive(Debug)]
pub enum ClientEvent {
    /// A job reply (`ok` / `shed` / `failed`).
    Response(FitResponse),
    /// `{"op":"pong"}` — the server's revision rides along.
    Pong { proto: u64 },
    /// `{"op":"stats"}` reply.
    Stats(ShardStats),
    /// `{"op":"cancelled"}` ack; `id` is the submitter's id.
    Cancelled { id: u64, cancelled: bool },
    /// A §5 protocol-error reply (carries no job id).
    ProtocolError(Json),
    /// Any other server notice (`idle-timeout`, `shutdown-ack`, …).
    Notice(Json),
    /// The read timeout elapsed (only with [`ClientConn::set_read_timeout`]).
    Tick,
    /// Server closed the connection.
    Eof,
}

/// The shared half of a connection: locked writer + the wire-id remap
/// table. Cloneable so a split sender and receiver stay consistent.
#[derive(Clone)]
struct Shared {
    writer: Arc<Mutex<Stream>>,
    /// A lock-free socket clone for [`LinkShutdown`] (see there for why
    /// it must not share the writer lock).
    killer: Arc<Stream>,
    /// wire id → the submitter's id, removed as responses arrive.
    inflight: Arc<Mutex<HashMap<u64, u64>>>,
    /// wire id → submitter's id for sent cancels. Kept separately from
    /// `inflight` because the job's own reply may overtake the
    /// `cancelled` ack and remove the inflight entry first — the ack
    /// must still restore the right id.
    cancels: Arc<Mutex<HashMap<u64, u64>>>,
    next_wire_id: Arc<AtomicU64>,
}

impl Shared {
    fn submit(&self, req: &FitRequest) -> Result<u64> {
        let wire_id = self.next_wire_id.fetch_add(1, Ordering::Relaxed);
        self.inflight.lock().expect("inflight map poisoned").insert(wire_id, req.id);
        let mut wire_req = req.clone();
        wire_req.id = wire_id;
        write_line(&self.writer, &wire_req.to_json().to_string())?;
        Ok(wire_id)
    }

    /// Send a raw frame verbatim — no wire-id remapping. The map-reduce
    /// driver (PROTOCOL.md §10) uses this for `partial_fit` /
    /// `centroid_sync`, whose ids it manages itself; the replies arrive
    /// as [`ClientEvent::Notice`] frames.
    fn send_frame(&self, frame: &Json) -> Result<()> {
        write_line(&self.writer, &frame.to_string())?;
        Ok(())
    }

    fn send_op(&self, op: &str) -> Result<()> {
        let mut m = std::collections::BTreeMap::new();
        m.insert("op".to_string(), Json::Str(op.into()));
        write_line(&self.writer, &Json::Obj(m).to_string())?;
        Ok(())
    }

    /// Send a cancel for the most recent in-flight submission carrying
    /// the submitter id `id`; `Ok(None)` when nothing matches locally
    /// (already answered, or never submitted) — no frame is sent then.
    fn send_cancel(&self, id: u64) -> Result<Option<u64>> {
        let wire_id = {
            let inflight = self.inflight.lock().expect("inflight map poisoned");
            inflight.iter().filter(|&(_, &orig)| orig == id).map(|(&w, _)| w).max()
        };
        let Some(wire_id) = wire_id else { return Ok(None) };
        self.cancels.lock().expect("cancel map poisoned").insert(wire_id, id);
        let mut m = std::collections::BTreeMap::new();
        m.insert("op".to_string(), Json::Str("cancel".into()));
        m.insert("id".to_string(), Json::Num(wire_id as f64));
        write_line(&self.writer, &Json::Obj(m).to_string())?;
        Ok(Some(wire_id))
    }

    /// Count of submitted-but-unanswered jobs.
    fn inflight_len(&self) -> usize {
        self.inflight.lock().expect("inflight map poisoned").len()
    }

    fn classify(&self, j: Json) -> ClientEvent {
        let op = j.get("op").and_then(|v| v.as_str().map(str::to_string)).ok();
        if let Some(op) = op {
            return match op.as_str() {
                "pong" => ClientEvent::Pong {
                    proto: j.get("proto").and_then(|v| v.as_usize()).unwrap_or(0) as u64,
                },
                "stats" => match ShardStats::from_json(&j) {
                    Ok(s) => ClientEvent::Stats(s),
                    Err(_) => ClientEvent::Notice(j),
                },
                "cancelled" => {
                    let wire_id = j.get("id").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
                    let cancelled = matches!(j.get("cancelled"), Ok(Json::Bool(true)));
                    // Restore via the cancel map (immune to the job's own
                    // reply racing ahead and clearing `inflight`).
                    let id = self
                        .cancels
                        .lock()
                        .expect("cancel map poisoned")
                        .remove(&wire_id)
                        .unwrap_or(wire_id);
                    ClientEvent::Cancelled { id, cancelled }
                }
                _ => ClientEvent::Notice(j),
            };
        }
        let status = j.get("status").and_then(|v| v.as_str().map(str::to_string)).ok();
        if status.as_deref() == Some("error") {
            return ClientEvent::ProtocolError(j);
        }
        match FitResponse::from_wire_json(&j) {
            Ok(mut resp) => {
                let orig = self
                    .inflight
                    .lock()
                    .expect("inflight map poisoned")
                    .remove(&resp.id);
                match orig {
                    Some(orig) => {
                        resp.id = orig;
                        ClientEvent::Response(resp)
                    }
                    // A reply we never asked for: surface it, don't guess.
                    None => ClientEvent::Notice(j),
                }
            }
            Err(_) => ClientEvent::Notice(j),
        }
    }
}

/// The receive half after [`ClientConn::split`]: the sole reader of the
/// socket. See [`ClientConn`] for the blocking single-owner surface.
pub struct ClientReceiver {
    reader: LineReader<Stream>,
    shared: Shared,
}

impl ClientReceiver {
    /// Block for the next server frame. [`ClientEvent::Eof`] is terminal.
    pub fn next_event(&mut self) -> Result<ClientEvent> {
        match self.reader.next_event() {
            LineEvent::Line(bytes) => {
                let text = std::str::from_utf8(&bytes)
                    .map_err(|_| Error::Parse("server sent non-UTF-8 line".into()))?;
                Ok(self.shared.classify(Json::parse(text.trim())?))
            }
            LineEvent::Oversized => Err(Error::Parse("server sent an oversized line".into())),
            LineEvent::Tick => Ok(ClientEvent::Tick),
            LineEvent::Eof => Ok(ClientEvent::Eof),
            LineEvent::Error(e) => Err(Error::Io(e)),
        }
    }
}

/// The send half after [`ClientConn::split`]; cloneable writes share one
/// line lock, so frames never tear.
pub struct ClientSender {
    shared: Shared,
}

impl ClientSender {
    /// Submit one job (remapped onto a connection-unique wire id); the
    /// paired receiver yields its [`ClientEvent::Response`] later.
    pub fn submit(&self, req: &FitRequest) -> Result<u64> {
        self.shared.submit(req)
    }

    /// Send a raw protocol frame verbatim (no id remapping) — the
    /// map-reduce driver's `partial_fit` / `centroid_sync` path
    /// (PROTOCOL.md §10). Replies to ops the classifier does not know
    /// arrive as [`ClientEvent::Notice`].
    pub fn send_frame(&self, frame: &Json) -> Result<()> {
        self.shared.send_frame(frame)
    }

    /// Request a `stats` reply (arrives as [`ClientEvent::Stats`]).
    pub fn request_stats(&self) -> Result<()> {
        self.shared.send_op("stats")
    }

    /// Request a `metrics` snapshot (PROTOCOL.md §6); the reply arrives
    /// as a [`ClientEvent::Notice`] whose `op` is `"metrics"` — the
    /// cluster front's fleet-wide scrape path (PROTOCOL.md §11).
    pub fn request_metrics(&self) -> Result<()> {
        self.shared.send_op("metrics")
    }

    /// Request a `pong` (arrives as [`ClientEvent::Pong`]).
    pub fn request_ping(&self) -> Result<()> {
        self.shared.send_op("ping")
    }

    /// Forward a cancel for submitter id `id` (most recent submission
    /// wins); `Ok(false)` when nothing was in flight locally and no frame
    /// was sent. The ack arrives as [`ClientEvent::Cancelled`].
    pub fn request_cancel(&self, id: u64) -> Result<bool> {
        Ok(self.shared.send_cancel(id)?.is_some())
    }

    /// Ask the daemon to drain and exit (PROTOCOL.md §6 `shutdown`).
    pub fn request_shutdown(&self) -> Result<()> {
        self.shared.send_op("shutdown")
    }

    /// Announce a graceful connection close (PROTOCOL.md §6 `bye`).
    pub fn send_bye(&self) -> Result<()> {
        self.shared.send_op("bye")
    }

    /// Submitted-but-unanswered jobs on this connection.
    pub fn inflight(&self) -> usize {
        self.shared.inflight_len()
    }
}

/// A blocking protocol connection to one daemon. For concurrent use
/// (separate submit and collect threads, as the cluster front needs),
/// [`ClientConn::split`] divides it into a [`ClientSender`] and a
/// [`ClientReceiver`] sharing one id-remap table.
pub struct ClientConn {
    receiver: ClientReceiver,
    sender: ClientSender,
    greeting: Json,
    /// Frames read past while waiting for a specific control reply.
    pending: VecDeque<ClientEvent>,
}

impl ClientConn {
    /// Connect to `host:port` or `unix:<path>`, read and check the
    /// greeting, and send the `{"proto":1}` handshake (PROTOCOL.md §2).
    pub fn connect(addr: &str) -> Result<ClientConn> {
        let stream = Stream::connect(addr)?;
        stream.set_blocking().map_err(Error::Io)?;
        let writer = stream.try_clone_stream().map_err(Error::Io)?;
        let killer = Arc::new(stream.try_clone_stream().map_err(Error::Io)?);
        let shared = Shared {
            writer: Arc::new(Mutex::new(writer)),
            killer,
            inflight: Arc::new(Mutex::new(HashMap::new())),
            cancels: Arc::new(Mutex::new(HashMap::new())),
            next_wire_id: Arc::new(AtomicU64::new(1)),
        };
        let mut reader = LineReader::new(stream);
        let greeting = match reader.next_event() {
            LineEvent::Line(bytes) => {
                let text = std::str::from_utf8(&bytes)
                    .map_err(|_| Error::Parse("greeting is not valid UTF-8".into()))?;
                Json::parse(text.trim())?
            }
            LineEvent::Eof => {
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    format!("{addr}: server closed before greeting"),
                )))
            }
            _ => return Err(Error::Parse(format!("{addr}: no greeting line"))),
        };
        let kind = greeting.get("kpynq").and_then(|v| v.as_str().map(str::to_string)).ok();
        if kind.as_deref() != Some("serve") {
            return Err(Error::Parse(format!("{addr}: not a kpynq serve daemon")));
        }
        let proto = greeting.get("proto")?.as_usize()? as u64;
        if proto != PROTO_VERSION {
            return Err(Error::Config(format!(
                "{addr}: server speaks protocol revision {proto}, this build speaks {PROTO_VERSION}"
            )));
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("proto".to_string(), Json::Num(PROTO_VERSION as f64));
        write_line(&shared.writer, &Json::Obj(m).to_string())?;
        Ok(ClientConn {
            receiver: ClientReceiver { reader, shared: shared.clone() },
            sender: ClientSender { shared },
            greeting,
            pending: VecDeque::new(),
        })
    }

    /// [`ClientConn::connect`] with the bounded doubling-backoff retry
    /// loop a [`ReconnectPolicy`] describes — the supervisor's readiness
    /// wait for a daemon that is still binding its socket, and the remote
    /// fleet's link re-establishment. `give_up` may veto further attempts
    /// early (e.g. when the child process already exited). Backoff sleeps
    /// never exceed `policy.total_wait` in sum; once that budget is spent
    /// the loop stops retrying even with attempts remaining.
    pub fn connect_with_backoff(
        addr: &str,
        policy: &ReconnectPolicy,
        mut give_up: impl FnMut() -> Option<String>,
    ) -> Result<ClientConn> {
        // The budget tracks backoff *sleeps* only (the documented bound):
        // charging the dials' own latency against it would collapse
        // `attempts` retries into one for a black-holed host whose
        // connect blocks for the OS timeout.
        let mut slept = Duration::ZERO;
        let mut delay = policy.base_delay;
        let mut last_err = None;
        for attempt in 0..policy.attempts.max(1) {
            if let Some(reason) = give_up() {
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionRefused,
                    format!("{addr}: giving up reconnect: {reason}"),
                )));
            }
            match ClientConn::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last_err = Some(e),
            }
            if attempt + 1 < policy.attempts.max(1) {
                let remaining = policy.total_wait.saturating_sub(slept);
                if remaining.is_zero() {
                    break; // total-wait budget spent: stop retrying
                }
                let nap = delay.min(remaining);
                std::thread::sleep(nap);
                slept += nap;
                delay = (delay * 2).min(policy.max_delay);
            }
        }
        Err(last_err.unwrap_or_else(|| {
            Error::Config(format!("{addr}: connect_with_backoff needs at least one attempt"))
        }))
    }

    /// A handle that can force this connection's socket closed from any
    /// thread (see [`LinkShutdown`]). Works before and after
    /// [`ClientConn::split`].
    pub fn shutdown_handle(&self) -> LinkShutdown {
        LinkShutdown { stream: Arc::clone(&self.sender.shared.killer) }
    }

    /// The server's greeting line (PROTOCOL.md §2), as parsed JSON.
    pub fn greeting(&self) -> &Json {
        &self.greeting
    }

    /// Set (or clear) the socket read timeout. With a timeout, blocking
    /// calls return an error instead of waiting forever — the safety net
    /// tests and health checks use.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.receiver.reader.get_ref().set_read_timeout_dur(d).map_err(Error::Io)
    }

    /// Split into independently owned send/receive halves (one id-remap
    /// table between them) — the shape the cluster front threads need.
    pub fn split(self) -> (ClientSender, ClientReceiver) {
        // `pending` only fills through the blocking helpers below, which
        // consume `&mut self`; a conn that is split immediately after
        // connect has nothing buffered to lose.
        debug_assert!(self.pending.is_empty(), "split after blocking reads loses frames");
        (self.sender, self.receiver)
    }

    /// Submit one job; returns the wire id it travels under.
    pub fn submit(&mut self, req: &FitRequest) -> Result<u64> {
        self.sender.submit(req)
    }

    /// Send a raw protocol frame verbatim (see [`ClientSender::send_frame`]).
    pub fn send_frame(&self, frame: &Json) -> Result<()> {
        self.sender.send_frame(frame)
    }

    /// Submitted-but-unanswered jobs on this connection.
    pub fn inflight(&self) -> usize {
        self.sender.inflight()
    }

    /// Block for the next frame (buffered frames first).
    pub fn next_event(&mut self) -> Result<ClientEvent> {
        if let Some(ev) = self.pending.pop_front() {
            return Ok(ev);
        }
        self.receiver.next_event()
    }

    /// Block until the next job response; control replies and notices
    /// read along the way are buffered for [`ClientConn::next_event`].
    pub fn recv_response(&mut self) -> Result<FitResponse> {
        // Scan anything already buffered first.
        if let Some(i) = self
            .pending
            .iter()
            .position(|ev| matches!(ev, ClientEvent::Response(_)))
        {
            match self.pending.remove(i) {
                Some(ClientEvent::Response(r)) => return Ok(r),
                _ => unreachable!("position() found a response"),
            }
        }
        loop {
            match self.receiver.next_event()? {
                ClientEvent::Response(r) => return Ok(r),
                ClientEvent::Eof => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed while responses were outstanding",
                    )))
                }
                ClientEvent::Tick => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "read timeout while waiting for a response",
                    )))
                }
                other => self.pending.push_back(other),
            }
        }
    }

    /// Liveness round-trip: send `ping`, block for the `pong`, return the
    /// server's protocol revision.
    pub fn ping(&mut self) -> Result<u64> {
        self.sender.request_ping()?;
        self.wait_for(|ev| match ev {
            ClientEvent::Pong { proto } => Some(*proto),
            _ => None,
        })
    }

    /// `stats` round-trip (PROTOCOL.md §6).
    pub fn stats(&mut self) -> Result<ShardStats> {
        self.sender.request_stats()?;
        self.wait_for(|ev| match ev {
            ClientEvent::Stats(s) => Some(*s),
            _ => None,
        })
    }

    /// Cancel the most recent in-flight job submitted with id `id` and
    /// block for the ack: `Ok(true)` means the server pulled it from its
    /// queue (the job's own reply then arrives as shed, "cancelled by
    /// client"); `Ok(false)` means it was too late — or nothing by that
    /// id was in flight, in which case no frame is even sent.
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        if self.sender.shared.send_cancel(id)?.is_none() {
            return Ok(false);
        }
        self.wait_for(|ev| match ev {
            ClientEvent::Cancelled { cancelled, .. } => Some(*cancelled),
            _ => None,
        })
    }

    /// `{"op":"trace"}` round-trip (PROTOCOL.md §11): destructively drain
    /// the server's span ring, returning the full reply object
    /// (`events` array + `dropped` count).
    pub fn drain_trace(&mut self) -> Result<Json> {
        self.sender.shared.send_op("trace")?;
        self.wait_for(|ev| match ev {
            ClientEvent::Notice(j)
                if matches!(j.get("op").and_then(|v| v.as_str()), Ok("trace")) =>
            {
                Some(j.clone())
            }
            _ => None,
        })
    }

    /// `{"op":"metrics"}` round-trip (PROTOCOL.md §6): snapshot the
    /// server's metrics registry (counters / gauges / histograms).
    pub fn metrics(&mut self) -> Result<Json> {
        self.sender.shared.send_op("metrics")?;
        self.wait_for(|ev| match ev {
            ClientEvent::Notice(j)
                if matches!(j.get("op").and_then(|v| v.as_str()), Ok("metrics")) =>
            {
                Some(j.clone())
            }
            _ => None,
        })
    }

    /// Ask the daemon to drain and exit (PROTOCOL.md §6 `shutdown`).
    pub fn request_shutdown(&mut self) -> Result<()> {
        self.sender.request_shutdown()
    }

    /// Graceful close: send `bye`, then drain to EOF, returning any job
    /// responses that were still in flight.
    pub fn bye(mut self) -> Result<Vec<FitResponse>> {
        self.sender.send_bye()?;
        let mut responses: Vec<FitResponse> = self
            .pending
            .drain(..)
            .filter_map(|ev| match ev {
                ClientEvent::Response(r) => Some(r),
                _ => None,
            })
            .collect();
        loop {
            match self.receiver.next_event()? {
                ClientEvent::Response(r) => responses.push(r),
                ClientEvent::Eof => return Ok(responses),
                ClientEvent::Tick => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "read timeout while draining after bye",
                    )))
                }
                _ => {}
            }
        }
    }

    fn wait_for<T>(&mut self, mut pick: impl FnMut(&ClientEvent) -> Option<T>) -> Result<T> {
        loop {
            let ev = self.receiver.next_event()?;
            if let Some(v) = pick(&ev) {
                return Ok(v);
            }
            match ev {
                ClientEvent::Eof => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "server closed while waiting for a control reply",
                    )))
                }
                ClientEvent::Tick => {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "read timeout while waiting for a control reply",
                    )))
                }
                other => self.pending.push_back(other),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::net::{Daemon, DaemonHandle, NetConfig};
    use crate::serve::{JobStatus, ServeConfig, ServeReport};
    use std::time::Instant;

    fn start_daemon(serve: ServeConfig) -> (String, DaemonHandle, std::thread::JoinHandle<ServeReport>) {
        let daemon = Daemon::bind("127.0.0.1:0", NetConfig::default(), serve).expect("bind");
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let thread = std::thread::spawn(move || daemon.run().expect("daemon run"));
        (addr, handle, thread)
    }

    fn job(id: u64, seed: u64) -> FitRequest {
        FitRequest {
            id,
            max_points: 400,
            kmeans: crate::kmeans::KMeansConfig { k: 3, seed, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn client_remaps_colliding_ids_and_restores_them() {
        let (addr, handle, thread) = start_daemon(ServeConfig { workers: 2, ..Default::default() });
        let mut c = ClientConn::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        assert_eq!(c.greeting().get("proto").unwrap().as_usize().unwrap() as u64, PROTO_VERSION);
        // Two submissions with the SAME caller id — the remap must keep
        // both alive on the wire and restore id 7 on both replies.
        let w1 = c.submit(&job(7, 1)).unwrap();
        let w2 = c.submit(&job(7, 2)).unwrap();
        assert_ne!(w1, w2, "wire ids are connection-unique");
        assert_eq!(c.inflight(), 2);
        let a = c.recv_response().unwrap();
        let b = c.recv_response().unwrap();
        assert_eq!((a.id, b.id), (7, 7));
        assert_eq!(a.status, JobStatus::Ok, "{}", a.detail);
        assert_ne!(
            a.summary.unwrap().assignments_fnv,
            b.summary.unwrap().assignments_fnv,
            "different seeds, different clusterings — replies were not conflated"
        );
        assert_eq!(c.inflight(), 0);
        assert_eq!(c.ping().unwrap(), PROTO_VERSION);
        let stats = c.stats().unwrap();
        assert_eq!(stats.submitted, 2);
        handle.shutdown();
        thread.join().unwrap();
    }

    #[test]
    fn cancel_of_a_finished_or_unknown_job_is_false() {
        let (addr, handle, thread) = start_daemon(ServeConfig { workers: 1, ..Default::default() });
        let mut c = ClientConn::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        assert!(!c.cancel(99).unwrap(), "nothing in flight: no wire traffic, false");
        c.submit(&job(1, 3)).unwrap();
        let r = c.recv_response().unwrap();
        assert_eq!(r.status, JobStatus::Ok, "{}", r.detail);
        assert!(!c.cancel(1).unwrap(), "already answered: false");
        handle.shutdown();
        thread.join().unwrap();
    }

    #[test]
    fn bye_drains_inflight_responses() {
        let (addr, handle, thread) = start_daemon(ServeConfig { workers: 1, ..Default::default() });
        let mut c = ClientConn::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        c.submit(&job(4, 4)).unwrap();
        c.submit(&job(5, 5)).unwrap();
        let mut responses = c.bye().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2, "bye delivers every owed reply before EOF");
        assert_eq!(responses[0].id, 4);
        assert_eq!(responses[1].id, 5);
        handle.shutdown();
        let report = thread.join().unwrap();
        assert_eq!(report.completed, 2);
        assert_eq!(report.dropped_replies, 0);
    }

    fn quick_policy(attempts: u32, total: Duration) -> ReconnectPolicy {
        ReconnectPolicy {
            attempts,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(4),
            total_wait: total,
        }
    }

    #[test]
    fn connect_with_backoff_gives_up_on_request() {
        let err = ClientConn::connect_with_backoff(
            "127.0.0.1:1",
            &quick_policy(10, Duration::from_secs(1)),
            || Some("child exited".into()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("child exited"), "{err}");
        // And without a veto it retries, then reports the connect error.
        let err = ClientConn::connect_with_backoff(
            "127.0.0.1:1",
            &quick_policy(2, Duration::from_secs(1)),
            || None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("127.0.0.1:1"), "{err}");
    }

    #[test]
    fn connect_with_backoff_never_sleeps_past_the_total_wait_bound() {
        // Far more attempts than the budget can fund: without the
        // total-wait clamp, ~10k attempts at the 4 ms cap would sleep for
        // tens of seconds. Port 1 refuses instantly on loopback, so the
        // elapsed time is dominated by the backoff sleeps the policy
        // controls — the bound plus scheduling slack is the whole story.
        let total = Duration::from_millis(200);
        let started = Instant::now();
        let err =
            ClientConn::connect_with_backoff("127.0.0.1:1", &quick_policy(10_000, total), || None)
                .unwrap_err();
        let elapsed = started.elapsed();
        assert!(err.to_string().contains("127.0.0.1:1"), "{err}");
        assert!(
            elapsed < total + Duration::from_secs(5),
            "total-wait bound not enforced: slept {elapsed:?} against a {total:?} budget"
        );
    }

    #[test]
    fn reconnect_policy_validates_and_defaults_to_the_readiness_shape() {
        let d = ReconnectPolicy::default();
        d.validate().unwrap();
        assert_eq!(d.attempts, 45);
        assert_eq!(d.base_delay, Duration::from_millis(20));
        assert_eq!(d.max_delay, Duration::from_millis(250));
        assert_eq!(d.total_wait, Duration::from_secs(10));
        assert!(ReconnectPolicy { attempts: 0, ..d.clone() }.validate().is_err());
        assert!(ReconnectPolicy { base_delay: Duration::ZERO, ..d.clone() }.validate().is_err());
        assert!(ReconnectPolicy {
            max_delay: Duration::from_millis(1),
            ..d.clone()
        }
        .validate()
        .is_err());
        assert!(ReconnectPolicy { total_wait: Duration::ZERO, ..d }.validate().is_err());
    }

    #[test]
    fn shutdown_handle_forces_both_halves_down() {
        let (addr, handle, thread) = start_daemon(ServeConfig { workers: 1, ..Default::default() });
        let mut c = ClientConn::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let killer = c.shutdown_handle();
        killer.shutdown();
        killer.shutdown(); // idempotent
        // The reader observes EOF (or a reset error) instead of blocking.
        match c.next_event() {
            Ok(ClientEvent::Eof) | Err(_) => {}
            Ok(other) => panic!("expected EOF after forced shutdown, got {other:?}"),
        }
        handle.shutdown();
        thread.join().unwrap();
    }
}
