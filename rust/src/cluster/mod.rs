//! `kpynq::cluster` — cross-process shards behind one serving endpoint.
//!
//! PR 2 sharded one process (worker threads with private engine banks);
//! PR 3 made the NDJSON job model a normative wire protocol
//! (PROTOCOL.md) and put a daemon on it. This subsystem is the next
//! scale-out rung on the ROADMAP: `kpynq cluster --shards N` turns N
//! independent `kpynq serve --listen unix:…` daemons — each a whole
//! process with its own admission queue and warm engine banks — into one
//! serving surface, the map-reduce shape the related k-means scale-out
//! work uses (simplified map-reduce over processing elements; an AccD/
//! KPynq-style host coordinator dispatching distance work to workers —
//! here each "worker" is an entire daemon). Four pieces:
//!
//! * [`client`] — [`client::ClientConn`]: the first *client*-side
//!   implementation of PROTOCOL.md in the tree (greeting + handshake,
//!   id remapping, typed control frames, bounded reconnect-with-backoff),
//!   built on the same `serve::codec` framing the daemon uses.
//! * [`supervisor`] — [`supervisor::Supervisor`]: spawns and owns the
//!   shard child processes, waits for protocol-level readiness, respawns
//!   crashes within a budget, reaps zombies.
//! * [`router`] — [`router::Router`]: the fan-out policy. BatchKey
//!   affinity keeps same-shape jobs on one shard so the lockstep
//!   micro-batcher still coalesces across processes; everything else
//!   goes to the least-loaded live shard (by the `stats` frame's
//!   `queue_depth` plus the exact local in-flight count).
//! * [`remote`] — [`remote::RemoteFleet`]: the supervisor's stand-in for
//!   **multi-host** clusters (`remote_shards` config / `--remote`): the
//!   front attaches to already-running daemons over ordinary
//!   [`client::ClientConn`] links — a remote front is just another
//!   revision-1 client (PROTOCOL.md §9) — with link loss recovered by
//!   reconnect-under-[`ReconnectPolicy`] instead of respawn.
//! * [`front`] — [`front::Cluster`]: the front door. It reuses
//!   `serve::net`'s listener and connection protocol via the
//!   `net::FrontCore` trait, so external clients see one ordinary
//!   daemon; behind it, tickets fan out to shards and replies fan back
//!   in with client ids restored, shard crashes are recovered with
//!   in-flight work requeued, and the final [`crate::serve::ServeReport`]
//!   merges the shards' counters.
//!
//! The contract is the serving guarantee one level up: **cluster-served
//! results are bit-identical to single-daemon results are bit-identical
//! to direct engine runs** — asserted end to end (FNV fingerprints
//! included) by `rust/tests/cluster.rs`, which also kills a shard
//! mid-stream and checks every reply still arrives exactly once.
//! Cluster-layer contracts live in DESIGN.md §2; the wire surface is
//! unchanged from PROTOCOL.md.

pub mod client;
pub mod front;
pub mod mapreduce;
pub mod remote;
pub mod router;
pub mod supervisor;

use std::path::PathBuf;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::ServeConfig;

pub use client::{ClientConn, ClientEvent, LinkShutdown, ReconnectPolicy, ShardStats};
pub use front::{Cluster, ClusterHandle};
pub use mapreduce::{fit_sliced, MapReduceFit};
pub use remote::RemoteFleet;
pub use router::Router;
pub use supervisor::Supervisor;

/// How the front turns one client job into shard work (PROTOCOL.md §10).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FitMode {
    /// Request-parallel (the original mode): each job is routed whole to
    /// one shard; throughput scales with *concurrent* jobs.
    #[default]
    Request,
    /// Data-parallel map-reduce: each job's *points* are sliced across
    /// every shard; the front reduces per-cluster partial sums into new
    /// centroids each iteration ([`MapReduceFit`]). A single fit scales
    /// with shard count, and the result stays bit-identical to a solo fit.
    MapReduce,
}

impl FitMode {
    pub fn name(self) -> &'static str {
        match self {
            FitMode::Request => "request",
            FitMode::MapReduce => "map-reduce",
        }
    }

    pub fn from_name(name: &str) -> Result<FitMode> {
        match name {
            "request" => Ok(FitMode::Request),
            "map-reduce" => Ok(FitMode::MapReduce),
            other => Err(Error::Config(format!(
                "unknown fit_mode '{other}' (expected 'request' or 'map-reduce')"
            ))),
        }
    }
}

/// Cluster shape (the `[cluster]` config section + `kpynq cluster` flags).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Shard daemon count (local mode; ignored when [`remote_shards`]
    /// is non-empty).
    ///
    /// [`remote_shards`]: ClusterConfig::remote_shards
    pub shards: usize,
    /// **Remote mode.** When non-empty, the front attaches to these
    /// already-running daemons (`host:port` / `unix:<path>`, one per
    /// shard, in shard order) instead of spawning local children: the
    /// supervisor is skipped entirely, and `shards`, `socket_dir` and
    /// `program` are ignored. Link loss is recovered by reconnecting
    /// under [`reconnect`]; teardown says `bye`, never `shutdown` — the
    /// daemons belong to whoever started them (PROTOCOL.md §6).
    ///
    /// [`reconnect`]: ClusterConfig::reconnect
    pub remote_shards: Vec<String>,
    /// The (re)connect shape shared by shard-readiness waits (local
    /// mode) and link re-establishment (remote mode).
    pub reconnect: ReconnectPolicy,
    /// Hung-link watchdog window: a live shard whose link has answered
    /// nothing (not even the monitor's ~4/s stats polls) for this long
    /// is killed/force-closed so the normal crash recovery requeues its
    /// work. Generous by default (30 s) and deliberately so: under
    /// sustained `block`-policy backpressure a healthy shard's
    /// connection reader can legitimately go quiet while its queue
    /// drains — a watchdog kill there wastes (re-run) work but never
    /// loses or duplicates a reply. Tests shrink it to fault-inject
    /// stalls quickly.
    pub health_timeout: Duration,
    /// Per-shard pool shape (each local shard gets its own
    /// `[serve]`-shaped pool: workers, queue, batching, shed policy). In
    /// remote mode the remote daemons own their real pool shape; this is
    /// the operator's estimate, used only to size the front's admission
    /// bound and the informational greeting keys.
    pub serve: ServeConfig,
    /// Directory for the shards' `unix:` listener sockets (local mode).
    pub socket_dir: PathBuf,
    /// Respawns (local mode) / reconnects (remote mode) allowed per
    /// shard before it is abandoned and routed around.
    pub max_restarts: u32,
    /// The `kpynq` binary to exec as shards (local mode; defaults to the
    /// current executable).
    pub program: PathBuf,
    /// How client jobs map onto shards: [`FitMode::Request`] routes each
    /// job whole to one shard; [`FitMode::MapReduce`] slices every job's
    /// points across all shards (PROTOCOL.md §10).
    pub fit_mode: FitMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            remote_shards: Vec::new(),
            reconnect: ReconnectPolicy::default(),
            health_timeout: Duration::from_secs(30),
            serve: ServeConfig::default(),
            socket_dir: default_socket_dir(),
            max_restarts: 3,
            program: supervisor::default_program(),
            fit_mode: FitMode::default(),
        }
    }
}

impl ClusterConfig {
    /// Effective shard count: the remote address list's length in remote
    /// mode, `shards` otherwise.
    pub fn shard_count(&self) -> usize {
        if self.remote_shards.is_empty() {
            self.shards
        } else {
            self.remote_shards.len()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.remote_shards.is_empty() {
            if self.shards == 0 {
                return Err(Error::Config("cluster shards must be positive".into()));
            }
        } else if self.remote_shards.iter().any(|a| a.trim().is_empty()) {
            return Err(Error::Config("cluster remote_shards entries must be non-empty".into()));
        }
        if self.health_timeout.is_zero() {
            return Err(Error::Config("cluster health timeout must be positive".into()));
        }
        self.reconnect.validate()?;
        self.serve.validate()
    }
}

/// Default shard-socket directory: per-process under the system temp dir
/// (Unix sockets want short paths; `sun_path` caps out around 104 bytes).
pub fn default_socket_dir() -> PathBuf {
    std::env::temp_dir().join(format!("kpynq-cluster-{}", std::process::id()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_config_validates() {
        ClusterConfig::default().validate().unwrap();
        assert!(ClusterConfig { shards: 0, ..Default::default() }.validate().is_err());
        let bad_serve = ClusterConfig {
            serve: ServeConfig { workers: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(bad_serve.validate().is_err());
    }

    #[test]
    fn remote_mode_overrides_shards_and_validates_addresses() {
        let remote = ClusterConfig {
            shards: 0, // ignored in remote mode — and not an error
            remote_shards: vec!["hosta:7071".into(), "unix:/tmp/b.sock".into()],
            ..Default::default()
        };
        remote.validate().unwrap();
        assert_eq!(remote.shard_count(), 2);
        assert_eq!(ClusterConfig::default().shard_count(), 2, "local mode uses `shards`");
        let blank = ClusterConfig {
            remote_shards: vec!["hosta:7071".into(), "  ".into()],
            ..Default::default()
        };
        assert!(blank.validate().is_err());
        let bad_policy = ClusterConfig {
            remote_shards: vec!["hosta:7071".into()],
            reconnect: ReconnectPolicy { attempts: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(bad_policy.validate().is_err());
        let bad_watchdog =
            ClusterConfig { health_timeout: Duration::ZERO, ..Default::default() };
        assert!(bad_watchdog.validate().is_err());
    }

    #[test]
    fn fit_mode_names_round_trip() {
        for mode in [FitMode::Request, FitMode::MapReduce] {
            assert_eq!(FitMode::from_name(mode.name()).unwrap(), mode);
        }
        assert!(FitMode::from_name("mapreduce").is_err());
        assert_eq!(FitMode::default(), FitMode::Request);
    }

    #[test]
    fn default_socket_dir_is_process_scoped() {
        let d = default_socket_dir();
        assert!(d.to_string_lossy().contains("kpynq-cluster-"));
    }
}
