//! `kpynq::cluster` — cross-process shards behind one serving endpoint.
//!
//! PR 2 sharded one process (worker threads with private engine banks);
//! PR 3 made the NDJSON job model a normative wire protocol
//! (PROTOCOL.md) and put a daemon on it. This subsystem is the next
//! scale-out rung on the ROADMAP: `kpynq cluster --shards N` turns N
//! independent `kpynq serve --listen unix:…` daemons — each a whole
//! process with its own admission queue and warm engine banks — into one
//! serving surface, the map-reduce shape the related k-means scale-out
//! work uses (simplified map-reduce over processing elements; an AccD/
//! KPynq-style host coordinator dispatching distance work to workers —
//! here each "worker" is an entire daemon). Four pieces:
//!
//! * [`client`] — [`client::ClientConn`]: the first *client*-side
//!   implementation of PROTOCOL.md in the tree (greeting + handshake,
//!   id remapping, typed control frames, bounded reconnect-with-backoff),
//!   built on the same `serve::codec` framing the daemon uses.
//! * [`supervisor`] — [`supervisor::Supervisor`]: spawns and owns the
//!   shard child processes, waits for protocol-level readiness, respawns
//!   crashes within a budget, reaps zombies.
//! * [`router`] — [`router::Router`]: the fan-out policy. BatchKey
//!   affinity keeps same-shape jobs on one shard so the lockstep
//!   micro-batcher still coalesces across processes; everything else
//!   goes to the least-loaded live shard (by the `stats` frame's
//!   `queue_depth` plus the exact local in-flight count).
//! * [`front`] — [`front::Cluster`]: the front door. It reuses
//!   `serve::net`'s listener and connection protocol via the
//!   `net::FrontCore` trait, so external clients see one ordinary
//!   daemon; behind it, tickets fan out to shards and replies fan back
//!   in with client ids restored, shard crashes are recovered with
//!   in-flight work requeued, and the final [`crate::serve::ServeReport`]
//!   merges the shards' counters.
//!
//! The contract is the serving guarantee one level up: **cluster-served
//! results are bit-identical to single-daemon results are bit-identical
//! to direct engine runs** — asserted end to end (FNV fingerprints
//! included) by `rust/tests/cluster.rs`, which also kills a shard
//! mid-stream and checks every reply still arrives exactly once.
//! Cluster-layer contracts live in DESIGN.md §2; the wire surface is
//! unchanged from PROTOCOL.md.

pub mod client;
pub mod front;
pub mod router;
pub mod supervisor;

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::serve::ServeConfig;

pub use client::{ClientConn, ClientEvent, ShardStats};
pub use front::{Cluster, ClusterHandle};
pub use router::Router;
pub use supervisor::Supervisor;

/// Cluster shape (the `[cluster]` config section + `kpynq cluster` flags).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Shard daemon count.
    pub shards: usize,
    /// Per-shard pool shape (each shard gets its own `[serve]`-shaped
    /// pool: workers, queue, batching, shed policy).
    pub serve: ServeConfig,
    /// Directory for the shards' `unix:` listener sockets.
    pub socket_dir: PathBuf,
    /// Respawns allowed per shard before it is abandoned and routed
    /// around.
    pub max_restarts: u32,
    /// The `kpynq` binary to exec as shards (defaults to the current
    /// executable).
    pub program: PathBuf,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            serve: ServeConfig::default(),
            socket_dir: default_socket_dir(),
            max_restarts: 3,
            program: supervisor::default_program(),
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::Config("cluster shards must be positive".into()));
        }
        self.serve.validate()
    }
}

/// Default shard-socket directory: per-process under the system temp dir
/// (Unix sockets want short paths; `sun_path` caps out around 104 bytes).
pub fn default_socket_dir() -> PathBuf {
    std::env::temp_dir().join(format!("kpynq-cluster-{}", std::process::id()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_config_validates() {
        ClusterConfig::default().validate().unwrap();
        assert!(ClusterConfig { shards: 0, ..Default::default() }.validate().is_err());
        let bad_serve = ClusterConfig {
            serve: ServeConfig { workers: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(bad_serve.validate().is_err());
    }

    #[test]
    fn default_socket_dir_is_process_scoped() {
        let d = default_socket_dir();
        assert!(d.to_string_lossy().contains("kpynq-cluster-"));
    }
}
