//! Remote shards: unsupervised daemon links on other hosts.
//!
//! The supervisor ([`super::supervisor`]) owns *processes* — it can spawn
//! them, SIGKILL them and reap their exit statuses. A multi-host cluster
//! has none of that: the shards are `kpynq serve --listen` daemons
//! somebody else started on other machines, and the only thing the front
//! holds is an ordinary protocol connection to each (PROTOCOL.md §9: a
//! remote front is an ordinary revision-1 client — there is no
//! cluster-to-shard dialect). [`RemoteFleet`] is therefore the
//! supervisor's shape with every process verb translated into a link
//! verb:
//!
//! | supervisor (local children)      | remote fleet (unsupervised links) |
//! |----------------------------------|-----------------------------------|
//! | spawn + readiness wait           | [`ClientConn::connect_with_backoff`] under the shared [`ReconnectPolicy`] |
//! | respawn a crashed child          | [`RemoteFleet::reconnect`] to the same address |
//! | SIGKILL (watchdog / chaos)       | [`RemoteFleet::force_close`] — socket shutdown via [`LinkShutdown`] |
//! | abandon past the restart budget  | abandon past the reconnect budget |
//! | reap exited children             | nothing — link EOF is the only death signal |
//!
//! The resulting link-state machine is: **connected** → (loss observed:
//! EOF, write error, garbled frame, watchdog force-close) →
//! **reconnecting** (the monitor runs the bounded [`ReconnectPolicy`]
//! loop inline, exactly like a local respawn) → **connected** again with
//! a bumped generation, or **dead** once the policy budget or the
//! per-link reconnect budget is spent — at which point the front requeues
//! the link's unanswered tickets onto the survivors and routes around it,
//! the same recovery path a crashed local shard takes (DESIGN.md §2).
//!
//! One deliberate asymmetry with the supervisor: its watchdog/chaos
//! kills respawn **budget-free** (`killed_by_supervisor`), because a
//! respawn execs a *fresh process* — the kill itself is the cure, so
//! charging it could spiral a slow-but-healthy shard into abandonment.
//! A remote reconnect heals nothing: it re-dials the **same daemon**,
//! wedged or not. If force-closes were budget-free here, a
//! wedged-but-reachable peer would loop force-close → reconnect →
//! requeue-onto-itself forever and the "dead" state would be
//! unreachable for exactly the failure the watchdog exists to catch. So
//! remote reconnects **always consume budget**; a remote that trips the
//! watchdog `max_restarts` times is abandoned and its work re-homes to
//! the survivors — which costs little, since abandoning a remote kills
//! no process: the daemon keeps serving its other clients, this front
//! merely routes around it.
//!
//! Ownership is the other asymmetry: on cluster teardown, local children
//! are drained with `{"op":"shutdown"}` (PROTOCOL.md §6) because the
//! cluster started them; remote daemons belong to whoever launched them,
//! so the front says `{"op":"bye"}` and leaves them serving.

use crate::error::{Error, Result};
use crate::obs::metrics::names;
use crate::obs::{self, Counter};

use super::client::{ClientConn, LinkShutdown, ReconnectPolicy};

/// One remote link's bookkeeping (the `ShardProc` analogue).
struct RemoteLink {
    /// The daemon's address, `host:port` or `unix:<path>` — reconnects
    /// always dial the same place; remote membership is static.
    addr: String,
    /// Bumped on every successful (re)connect; stale loss reports from an
    /// earlier incarnation of the link are ignored by generation.
    generation: u64,
    /// Reconnects performed so far. Every loss counts — including
    /// fleet-initiated force-closes, see the module docs for why the
    /// supervisor's budget-free kill rule does not transfer here.
    reconnects: u32,
    /// Past its reconnect budget (or unreachable): routed around for good.
    abandoned: bool,
    /// Force-close handle for the current incarnation's socket.
    shutdown: LinkShutdown,
}

/// Owns the unsupervised links of one remote-shards cluster.
pub struct RemoteFleet {
    policy: ReconnectPolicy,
    /// Reconnects allowed per link before it is abandoned (the remote
    /// reading of the cluster's `max_restarts`).
    max_reconnects: u32,
    links: Vec<RemoteLink>,
    /// Per-fleet reconnect count (a detached `obs::Counter`, not a global
    /// registry entry: two fleets in one process — tests — must not merge).
    reconnects_total: Counter,
}

impl RemoteFleet {
    /// Dial every address and complete the PROTOCOL.md §2 greeting +
    /// handshake on each; returns the fleet plus one ready connection per
    /// shard (in address order). Any unreachable daemon fails the whole
    /// start — a half-up cluster is refused, not served — and, since
    /// nothing was spawned, there is nothing to tear down: the
    /// already-opened connections simply drop (the daemons see an EOF
    /// with nothing in flight).
    pub fn connect(
        addrs: &[String],
        policy: ReconnectPolicy,
        max_reconnects: u32,
    ) -> Result<(RemoteFleet, Vec<ClientConn>)> {
        if addrs.is_empty() {
            return Err(Error::Config("remote fleet needs at least one shard address".into()));
        }
        policy.validate()?;
        let mut links = Vec::with_capacity(addrs.len());
        let mut conns = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let conn = ClientConn::connect_with_backoff(addr, &policy, || None)
                .map_err(|e| Error::Config(format!("remote shard {addr}: {e}")))?;
            links.push(RemoteLink {
                addr: addr.clone(),
                generation: 0,
                reconnects: 0,
                abandoned: false,
                shutdown: conn.shutdown_handle(),
            });
            conns.push(conn);
        }
        Ok((
            RemoteFleet { policy, max_reconnects, links, reconnects_total: Counter::new() },
            conns,
        ))
    }

    /// The address link `index` dials.
    pub fn addr(&self, index: usize) -> &str {
        &self.links[index].addr
    }

    /// Current link generation of shard `index`.
    pub fn generation(&self, index: usize) -> u64 {
        self.links[index].generation
    }

    /// Total successful reconnects over the fleet's lifetime (the remote
    /// reading of the report's `shard_restarts`).
    pub fn reconnects_total(&self) -> u64 {
        self.reconnects_total.get()
    }

    /// Force link `index`'s socket closed (watchdog / chaos hook). The
    /// loss is observed through the normal path — the link's reader sees
    /// EOF and reports it — and the ensuing reconnect consumes budget
    /// like any other (see the module docs: re-dialing cannot heal a
    /// wedged peer, so a budget-free close would livelock on it).
    pub fn force_close(&mut self, index: usize) {
        self.links[index].shutdown.shutdown();
    }

    /// Stop driving link `index` for good: its budget is spent or its
    /// daemon is unreachable; the front requeues its work and routes
    /// around it from now on.
    pub fn abandon(&mut self, index: usize) {
        let l = &mut self.links[index];
        obs::log::warn("cluster.remote", &format!("abandoning shard {index} ({})", l.addr));
        l.abandoned = true;
        l.shutdown.shutdown();
    }

    /// Re-establish a lost link with the shared [`ReconnectPolicy`] and
    /// return a ready connection to the same daemon. Fails once the
    /// link's reconnect budget is exhausted or the daemon stays
    /// unreachable past the policy budget — the caller then abandons the
    /// link and requeues its work onto the survivors.
    pub fn reconnect(&mut self, index: usize) -> Result<ClientConn> {
        {
            let l = &self.links[index];
            if l.abandoned {
                return Err(Error::Config(format!("remote shard {index} ({}) was abandoned", l.addr)));
            }
            if l.reconnects >= self.max_reconnects {
                return Err(Error::Config(format!(
                    "remote shard {index} ({}) exceeded its reconnect budget ({})",
                    l.addr, self.max_reconnects
                )));
            }
        }
        // Make sure the dead incarnation's socket is fully closed before
        // dialing again (idempotent when the peer already closed it).
        self.links[index].shutdown.shutdown();
        let addr = self.links[index].addr.clone();
        let conn = ClientConn::connect_with_backoff(&addr, &self.policy, || None)?;
        let l = &mut self.links[index];
        l.reconnects += 1;
        l.generation += 1;
        l.shutdown = conn.shutdown_handle();
        self.reconnects_total.inc();
        // The process-wide registry keeps the named metric; per-fleet
        // accounting (the report's `shard_restarts`) stays local above.
        obs::global().counter(names::CLUSTER_REMOTE_RECONNECTS).inc();
        obs::log::info(
            "cluster.remote",
            &format!("reconnected shard {index} ({}) generation {}", l.addr, l.generation),
        );
        Ok(conn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn fast_policy() -> ReconnectPolicy {
        ReconnectPolicy {
            attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            total_wait: Duration::from_millis(50),
        }
    }

    #[test]
    fn empty_fleet_and_unreachable_daemons_are_refused() {
        assert!(RemoteFleet::connect(&[], fast_policy(), 3).is_err());
        let err = RemoteFleet::connect(&["127.0.0.1:1".to_string()], fast_policy(), 3)
            .unwrap_err()
            .to_string();
        assert!(err.contains("remote shard 127.0.0.1:1"), "{err}");
        // A bad policy is rejected before any dialing happens.
        let bad = ReconnectPolicy { attempts: 0, ..fast_policy() };
        assert!(RemoteFleet::connect(&["127.0.0.1:1".to_string()], bad, 3).is_err());
    }
}
