//! The cluster front door: one listener, N shard daemons behind it.
//!
//! [`Cluster`] reuses `serve::net`'s accept loop and connection protocol
//! wholesale — its (crate-private) `ClusterCore` is just another
//! `net::FrontCore` — so an
//! external client cannot tell a cluster from a single daemon: same
//! greeting shape, same control frames, same error replies, one endpoint
//! (PROTOCOL.md). What changes is what happens behind `submit`:
//!
//! * **Fan-out.** Every accepted request is remapped onto a
//!   cluster-unique ticket and routed by [`Router`] policy — BatchKey
//!   affinity first, least-queue-depth fallback — onto one shard's
//!   forwarding link (a split [`ClientConn`]: a writer thread draining a
//!   command channel, a reader thread pumping replies back).
//! * **Fan-in.** Shard replies carry the ticket; the core restores the
//!   external client's own id and delivers to the owning connection,
//!   folding every response into the cluster's `ResponseAccumulator` on
//!   the way — the same exactly-one-reply-per-job contract the session
//!   gives in-process (DESIGN.md §2).
//! * **Supervision.** A monitor thread owns the shard *host* — the
//!   [`Supervisor`] when the shards are spawned local children, the
//!   [`super::remote::RemoteFleet`] when they are already-running
//!   daemons on other hosts (`remote_shards` config / `--remote`). A
//!   shard that crashes (link EOF, write error, or a reaped child) is
//!   respawned — or its link re-dialed under the shared
//!   [`super::client::ReconnectPolicy`] — within its budget, and every
//!   ticket it had not answered is requeued onto the new incarnation or
//!   the survivors. Requeueing re-*runs* jobs, which is safe precisely
//!   because of the serving guarantee: a fit is a deterministic function
//!   of its request, so the re-run's reply is bit-identical to the one
//!   the dead shard would have sent, and each ticket still yields
//!   exactly one reply.
//! * **Cancel forwarding.** `{"op":"cancel"}` resolves the ticket's
//!   owning shard and round-trips the cancel there, so the ack keeps the
//!   single-daemon meaning (PROTOCOL.md §6).
//! * **Map-reduce mode.** With `fit_mode = map-reduce`
//!   ([`super::FitMode::MapReduce`]), a job is not routed whole to one
//!   shard: its *points* are sliced across every shard and the front
//!   runs the iteration barrier itself via [`MapReduceFit`]
//!   (PROTOCOL.md §10) — one fit scales with shard count, and the reply
//!   is still bit-identical to a solo run.
//!
//! ```no_run
//! use kpynq::cluster::{Cluster, ClusterConfig};
//! use kpynq::serve::NetConfig;
//!
//! let cluster = Cluster::start(
//!     "127.0.0.1:7071",
//!     NetConfig::default(),
//!     ClusterConfig { shards: 4, ..Default::default() },
//! ).unwrap();
//! println!("cluster front on {}", cluster.local_addr());
//! let report = cluster.run().unwrap(); // blocks until {"op":"shutdown"}
//! println!("{}", report.render());
//! ```

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::obs::metrics::{merge_snapshot_labeled, names};
use crate::obs::profile::Phase;
use crate::obs::{mint_trace_id, Counter, Histogram, Registry, SpanEvent, TraceRing};
use crate::serve::cache::{self, ResultCache};
use crate::serve::job::{FitRequest, FitResponse, FitSummary, JobStatus};
use crate::serve::net::{advertised_backends, Daemon, DaemonHandle, FrontCore, NetConfig};
use crate::serve::queue::QueueStats;
use crate::serve::report::{tenants_json, ResponseAccumulator, TenantAcc, OVERFLOW_TENANT};
use crate::serve::{ServeConfig, ServeReport};
use crate::util::json::Json;

use super::client::{ClientConn, ClientEvent, ReconnectPolicy};
use super::mapreduce::MapReduceFit;
use super::remote::RemoteFleet;
use super::router::{Router, DEAD};
use super::supervisor::{Supervisor, SupervisorConfig};
use super::{ClusterConfig, FitMode};

/// Monitor poll period: health sweep + per-shard `stats` refresh.
const POLL: Duration = Duration::from_millis(250);
/// How long a forwarded cancel waits for the owning shard's ack.
const CANCEL_WAIT: Duration = Duration::from_secs(2);
/// How long the final per-shard stats sweep waits per shard.
const FINAL_STATS_WAIT: Duration = Duration::from_secs(2);
/// Grace for shard daemons to exit after their `shutdown` frame.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(10);

/// `ClusterRoute.shard` before dispatch has picked one.
const UNROUTED: usize = usize::MAX;

/// Commands a shard link's writer thread forwards onto the wire.
enum ShardCmd {
    /// A job whose id is already the cluster ticket.
    Submit(FitRequest),
    /// Cancel by cluster ticket.
    Cancel(u64),
    Stats,
    /// Scrape the shard's metrics registry (fleet merge, PROTOCOL.md §11).
    Metrics,
    /// Drain-and-exit frame for shards the cluster owns (local children).
    Shutdown,
    /// Graceful goodbye for shards it does not (remote daemons).
    Bye,
}

/// What the monitor needs from whatever owns the shards' lifecycles: the
/// [`Supervisor`] (spawned local children) and the [`RemoteFleet`]
/// (unsupervised links to daemons on other hosts) are the two
/// implementations, so one monitor loop drives both modes — crash
/// recovery, the hung-link watchdog, the chaos hook and teardown all
/// behave identically whether a "respawn" execs a process or re-dials a
/// socket (DESIGN.md §2).
trait ShardHost: Send {
    /// Replace dead shard `index` with a fresh incarnation (respawned
    /// child / re-dialed link) and return a ready connection. An error
    /// means the shard is gone for good — the caller abandons it.
    fn respawn(&mut self, index: usize) -> Result<ClientConn>;
    /// Current incarnation of shard `index` (stale-report guard).
    fn generation(&self, index: usize) -> u64;
    /// Stop driving shard `index` for good.
    fn abandon(&mut self, index: usize);
    /// Force shard `index` down — SIGKILL locally, socket shutdown
    /// remotely — so its link EOFs into the normal recovery path. Budget
    /// accounting is the host's call: the supervisor respawns its own
    /// kills for free (a fresh process is the cure), the remote fleet
    /// charges them (re-dialing a wedged daemon cures nothing — see
    /// `cluster::remote`).
    fn kill(&mut self, index: usize);
    /// Sweep for shards that died without their link noticing (local
    /// children that exited before serving; remote links have no such
    /// channel and return nothing).
    fn reap_exited(&mut self) -> Vec<(usize, u64)>;
    /// Total respawns/reconnects over the cluster's lifetime.
    fn restarts_total(&self) -> u64;
    /// Whether the shards are processes this cluster owns. Owned shards
    /// are drained with `{"op":"shutdown"}` at teardown and waited on;
    /// unowned remote daemons get `{"op":"bye"}` and keep serving
    /// whoever else they serve (PROTOCOL.md §6).
    fn owns_shards(&self) -> bool;
    /// Post-drain teardown (reap children / drop links).
    fn shutdown(self: Box<Self>, grace: Duration);
}

impl ShardHost for Supervisor {
    fn respawn(&mut self, index: usize) -> Result<ClientConn> {
        Supervisor::respawn(self, index)
    }
    fn generation(&self, index: usize) -> u64 {
        Supervisor::generation(self, index)
    }
    fn abandon(&mut self, index: usize) {
        Supervisor::abandon(self, index)
    }
    fn kill(&mut self, index: usize) {
        Supervisor::kill(self, index)
    }
    fn reap_exited(&mut self) -> Vec<(usize, u64)> {
        Supervisor::reap_exited(self)
    }
    fn restarts_total(&self) -> u64 {
        Supervisor::restarts_total(self)
    }
    fn owns_shards(&self) -> bool {
        true
    }
    fn shutdown(self: Box<Self>, grace: Duration) {
        Supervisor::shutdown(*self, grace)
    }
}

impl ShardHost for RemoteFleet {
    fn respawn(&mut self, index: usize) -> Result<ClientConn> {
        RemoteFleet::reconnect(self, index)
    }
    fn generation(&self, index: usize) -> u64 {
        RemoteFleet::generation(self, index)
    }
    fn abandon(&mut self, index: usize) {
        RemoteFleet::abandon(self, index)
    }
    fn kill(&mut self, index: usize) {
        RemoteFleet::force_close(self, index)
    }
    fn reap_exited(&mut self) -> Vec<(usize, u64)> {
        Vec::new() // link EOF is the only death signal for a remote peer
    }
    fn restarts_total(&self) -> u64 {
        RemoteFleet::reconnects_total(self)
    }
    fn owns_shards(&self) -> bool {
        false
    }
    fn shutdown(self: Box<Self>, _grace: Duration) {
        // Nothing to reap: the byes are already sent, and the daemons
        // belong to whoever started them.
    }
}

enum MonitorMsg {
    /// A link observed its shard dead (EOF / write error), or the reaper
    /// found an exited child. Stale generations are ignored.
    ShardDown { shard: usize, generation: u64 },
    /// Chaos hook: SIGKILL a shard (tests, `ClusterHandle::kill_shard`).
    KillShard(usize),
    /// Stop supervising and reap the (already shutdown-signalled) shards.
    Finalize,
}

/// One shard's forwarding state: the command channel into its writer
/// thread plus the shared bookkeeping its reader thread maintains.
struct ShardLink {
    generation: u64,
    alive: bool,
    tx: mpsc::Sender<ShardCmd>,
    /// Tickets forwarded and not yet answered (exact, locally counted).
    local_depth: Arc<AtomicUsize>,
    /// Last `queue_depth` the shard reported (PROTOCOL.md §6 `stats`).
    reported_depth: Arc<AtomicUsize>,
    /// ticket → the (ticket-rewritten) request, for requeue on crash.
    inflight: Arc<Mutex<HashMap<u64, FitRequest>>>,
    last_stats: Arc<Mutex<super::client::ShardStats>>,
    /// FIFO of synchronous stats requests (single link ⇒ replies ordered).
    stats_waiters: Arc<Mutex<VecDeque<mpsc::Sender<super::client::ShardStats>>>>,
    /// FIFO of synchronous metrics scrapes (same ordering argument).
    metrics_waiters: Arc<Mutex<VecDeque<mpsc::Sender<Json>>>>,
    /// When the link last heard *anything* from the shard — the hung-shard
    /// watchdog's signal (see [`ClusterConfig::health_timeout`]).
    last_heard: Arc<Mutex<Instant>>,
}

impl ShardLink {
    fn depth(&self) -> usize {
        if !self.alive {
            return DEAD;
        }
        self.local_depth
            .load(Ordering::SeqCst)
            .max(self.reported_depth.load(Ordering::SeqCst))
    }
}

/// Where one in-flight ticket's reply must go.
struct ClusterRoute {
    client_id: u64,
    reply: mpsc::Sender<FitResponse>,
    shard: usize,
    /// The request's tenant label, restored onto the reply in `deliver`
    /// (shards never see the front's tenant accounting).
    tenant: String,
    /// The request fingerprint (PROTOCOL.md §8), when cacheable:
    /// `deliver` stores the finished result under it.
    fingerprint: Option<u64>,
}

/// The fan-out/fan-in core behind the cluster's front door — the
/// `net::FrontCore` the shared accept loop drives.
///
/// Lock order (to stay deadlock-free): `links` may be held while taking
/// `router` or a link's leaf locks (`inflight`, `stats_waiters`), never
/// while taking `routes` or `acc`; `routes` and `acc` are taken alone.
pub(crate) struct ClusterCore {
    serve: ServeConfig,
    shard_count: usize,
    links: Mutex<Vec<ShardLink>>,
    routes: Mutex<HashMap<u64, ClusterRoute>>,
    router: Mutex<Router>,
    next_ticket: AtomicU64,
    /// `cluster.jobs.submitted` — lives in the front's metrics registry.
    submitted: Counter,
    /// `cluster.requeues`: tickets re-dispatched after a shard death.
    requeues: Counter,
    /// `cluster.shard_restarts`: successful respawns/reconnects.
    restarts: Counter,
    /// Front-observed per-job latency histograms (`obs::metrics`), fed in
    /// [`ClusterCore::deliver`] as each routed reply fans back in.
    queue_wait_ms: Histogram,
    latency_ms: Histogram,
    /// Per-front metrics registry: two fronts in one process (tests) must
    /// not merge counters.
    registry: Arc<Registry>,
    /// Front-side trace span ring (PROTOCOL.md §11): admit → dispatch →
    /// reply, plus per-epoch reduce barriers in map-reduce mode.
    ring: Arc<TraceRing>,
    acc: Mutex<ResponseAccumulator>,
    /// Per-tenant accounting table, fed in `deliver` (the `tenants`
    /// object of the `stats` reply, PROTOCOL.md §6). Capped at
    /// `max_tracked_tenants`; overflow lands in [`OVERFLOW_TENANT`].
    tenants: Mutex<BTreeMap<String, TenantAcc>>,
    /// Front-side fingerprint-keyed result cache (PROTOCOL.md §8),
    /// consulted in `submit` before any shard dispatch — a cache hit
    /// never crosses a shard link. Works in both fit modes: map-reduce
    /// replies are bit-identical to solo runs, so they replay the same.
    cache: Mutex<ResultCache>,
    pending_cancels: Mutex<HashMap<u64, mpsc::Sender<bool>>>,
    /// Outstanding (submitted, unanswered) jobs, bounded by
    /// `admission_cap`: past the cap, `submit` blocks the submitting
    /// connection's reader — the same TCP-backpressure shape the single
    /// daemon's Block policy gives (DESIGN.md §2). Without this the
    /// front would buffer unbounded requests in memory while the shard
    /// queues are full.
    admission: Mutex<usize>,
    admission_free: Condvar,
    admission_cap: usize,
    /// Hung-link watchdog window (see [`ClusterConfig::health_timeout`]).
    health_timeout: Duration,
    /// How client jobs map onto shards (see [`super::FitMode`]).
    fit_mode: FitMode,
    /// Shard daemon addresses in shard order. The map-reduce driver dials
    /// its own dedicated per-shard links instead of sharing the
    /// forwarding links — `partial_fit` state is connection-scoped
    /// (PROTOCOL.md §10), so a fit must own the connection it lives on.
    mapreduce_addrs: Vec<String>,
    reconnect: ReconnectPolicy,
    /// Re-dispatches allowed per shard within one map-reduce fit.
    max_restarts: u32,
    started: Instant,
}

impl ClusterCore {
    fn new(cfg: &ClusterConfig) -> ClusterCore {
        let shards = cfg.shard_count();
        // Aggregate capacity of the fleet: what fits in the shard queues
        // plus what the workers can be executing at once. (In remote mode
        // `cfg.serve` is the operator's *estimate* of the remote pool
        // shape — the bound is still finite either way, which is what
        // matters for front-door memory.)
        let per_shard = cfg.serve.queue_capacity + cfg.serve.workers * cfg.serve.max_batch;
        let mapreduce_addrs = if cfg.remote_shards.is_empty() {
            (0..shards)
                .map(|i| {
                    format!("unix:{}", cfg.socket_dir.join(format!("shard-{i}.sock")).display())
                })
                .collect()
        } else {
            cfg.remote_shards.clone()
        };
        let registry = Arc::new(Registry::new());
        let cache = Mutex::new(ResultCache::new(cfg.serve.cache_capacity, &registry));
        ClusterCore {
            serve: cfg.serve.clone(),
            shard_count: shards,
            links: Mutex::new(Vec::with_capacity(shards)),
            routes: Mutex::new(HashMap::new()),
            router: Mutex::new(Router::new()),
            next_ticket: AtomicU64::new(1),
            submitted: registry.counter(names::CLUSTER_JOBS_SUBMITTED),
            requeues: registry.counter(names::CLUSTER_REQUEUES),
            restarts: registry.counter(names::CLUSTER_SHARD_RESTARTS),
            queue_wait_ms: registry.histogram(names::SERVE_QUEUE_WAIT_MS),
            latency_ms: registry.histogram(names::SERVE_LATENCY_MS),
            registry,
            ring: Arc::new(TraceRing::default()),
            acc: Mutex::new(ResponseAccumulator::default()),
            tenants: Mutex::new(BTreeMap::new()),
            cache,
            pending_cancels: Mutex::new(HashMap::new()),
            admission: Mutex::new(0),
            admission_free: Condvar::new(),
            admission_cap: (shards * per_shard).max(1),
            health_timeout: cfg.health_timeout,
            fit_mode: cfg.fit_mode,
            mapreduce_addrs,
            reconnect: cfg.reconnect.clone(),
            max_restarts: cfg.max_restarts,
            started: Instant::now(),
        }
    }

    /// Map-reduce dispatch (PROTOCOL.md §10): run the whole sliced fit
    /// right here — on the submitting connection's reader thread, the
    /// same inline-compute shape the shard side uses — over dedicated
    /// per-shard links, and deliver the assembled response. Jobs
    /// pipelined on one client connection therefore serialize; concurrent
    /// client connections run concurrent map-reduce fits. The route's
    /// shard stays [`UNROUTED`] for the fit's whole life, so a forwarded
    /// cancel answers `false` — map-reduce fits are not cancellable
    /// mid-iteration.
    fn dispatch_mapreduce(&self, ticket: u64, req: FitRequest) {
        let started = Instant::now();
        let backend = req.backend_name.clone();
        let trace_id = req.trace_id.clone();
        let mut mr = MapReduceFit::new(req, self.mapreduce_addrs.clone());
        mr.reconnect = self.reconnect.clone();
        mr.shard_timeout = self.health_timeout;
        mr.redispatch_budget = self.max_restarts.max(1);
        // Per-epoch reduce barriers land in the front's span ring
        // (PROTOCOL.md §11) under the job's trace id.
        mr.trace = Some((Arc::clone(&self.ring), trace_id.clone()));
        let mut resp = match mr.run() {
            Ok(fit) => FitResponse {
                id: ticket,
                status: JobStatus::Ok,
                detail: String::new(),
                backend,
                worker: 0,
                batch_size: 1,
                queue_seconds: 0.0,
                service_seconds: started.elapsed().as_secs_f64(),
                summary: Some(FitSummary::of(&fit)),
                fit: Some(fit),
                report: None,
                trace_id: String::new(),
                tenant: String::new(),
                cached: false,
            },
            Err(e) => FitResponse::failed(ticket, &backend, 0, 0, 0.0, &e),
        };
        resp.trace_id = trace_id;
        self.deliver(resp);
    }

    /// Route one ticketed request onto a live shard (recording it for
    /// requeue) — or answer `failed` when no shard is routable.
    fn dispatch(&self, ticket: u64, req: FitRequest) {
        let target = {
            let links = self.links.lock().expect("links poisoned");
            let depths: Vec<usize> = links.iter().map(ShardLink::depth).collect();
            match self.router.lock().expect("router poisoned").route(&req, &depths) {
                Some(s) => {
                    links[s]
                        .inflight
                        .lock()
                        .expect("inflight poisoned")
                        .insert(ticket, req.clone());
                    links[s].local_depth.fetch_add(1, Ordering::SeqCst);
                    Some((s, links[s].tx.clone()))
                }
                None => None,
            }
        };
        match target {
            Some((shard, tx)) => {
                if let Some(route) =
                    self.routes.lock().expect("routes poisoned").get_mut(&ticket)
                {
                    route.shard = shard;
                }
                if !req.trace_id.is_empty() {
                    self.ring.push(
                        SpanEvent::new(&req.trace_id, "dispatch")
                            .num("ticket", ticket as f64)
                            .num("shard", shard as f64),
                    );
                }
                // A send failure means the writer just died; the request
                // is already in `inflight`, so crash recovery requeues it.
                let _ = tx.send(ShardCmd::Submit(req));
            }
            None => {
                let err = Error::Config("no live shards to route to".into());
                let resp = FitResponse::failed(ticket, &req.backend_name, 0, 0, 0.0, &err);
                self.deliver(resp);
            }
        }
    }

    /// Fan-in: restore the external client's id, deliver, account. The
    /// route is taken *first* and only routed replies are observed: a
    /// crashed shard's reply can race its own requeue (the re-run already
    /// answered and removed the route), and counting that duplicate would
    /// inflate `completed` past `submitted`. A routeless reply is simply
    /// ignored — the ticket's one real answer was already delivered.
    fn deliver(&self, mut resp: FitResponse) {
        let route = self.routes.lock().expect("routes poisoned").remove(&resp.id);
        if let Some(ClusterRoute { client_id, reply, tenant, fingerprint, .. }) = route {
            self.acc.lock().expect("accumulator poisoned").observe(&resp);
            self.queue_wait_ms.record_ms(resp.queue_seconds * 1e3);
            self.latency_ms.record_ms(resp.latency_seconds() * 1e3);
            // Per-phase solver timings (profiling runs only) — same
            // labeled series the single daemon's router feeds.
            if let Some(p) = resp.summary.as_ref().and_then(|s| s.phases) {
                for ph in Phase::ALL {
                    self.registry
                        .histogram_with(names::FIT_PHASE_MS, &[("phase", ph.name())])
                        .record_ms(p.get(ph));
                }
            }
            // Seed the front's result cache with freshly computed
            // successes (replayed hits never re-insert — PROTOCOL.md §8).
            if let Some(fp) = fingerprint {
                if resp.status == JobStatus::Ok {
                    self.cache.lock().expect("result cache poisoned").insert(fp, &resp);
                }
            }
            resp.tenant = tenant;
            if !resp.tenant.is_empty() {
                // Cardinality cap (PROTOCOL.md §3): same `~other` overflow
                // rule the single daemon's router applies.
                let label = {
                    let table = self.tenants.lock().expect("tenant table poisoned");
                    if table.contains_key(&resp.tenant)
                        || table.len() < self.serve.max_tracked_tenants
                    {
                        resp.tenant.clone()
                    } else {
                        OVERFLOW_TENANT.to_string()
                    }
                };
                let t = label.as_str();
                self.registry
                    .histogram_with(names::SERVE_LATENCY_MS, &[("tenant", t)])
                    .record_ms(resp.latency_seconds() * 1e3);
                if resp.status == JobStatus::Shed {
                    let name = if resp.detail.contains("deadline") {
                        names::SERVE_QUEUE_SHED_DEADLINE
                    } else {
                        names::SERVE_QUEUE_SHED_FULL
                    };
                    self.registry.counter_with(name, &[("tenant", t)]).inc();
                }
                self.tenants
                    .lock()
                    .expect("tenant table poisoned")
                    .entry(label)
                    .or_default()
                    .observe(&resp);
            }
            if !resp.trace_id.is_empty() {
                self.ring.push(
                    SpanEvent::new(&resp.trace_id, "reply")
                        .num("ticket", resp.id as f64)
                        .attr("status", Json::Str(resp.status.name().into()))
                        .num("latency_ms", resp.latency_seconds() * 1e3),
                );
            }
            resp.id = client_id;
            if reply.send(resp).is_err() {
                self.acc.lock().expect("accumulator poisoned").count_dropped_reply();
            }
            // Exactly one admission slot per ticket frees here (the route
            // existing proves this is the ticket's first and only answer).
            let mut n = self.admission.lock().expect("admission poisoned");
            *n = n.saturating_sub(1);
            self.admission_free.notify_one();
        }
    }

    fn finish_cancel(&self, ticket: u64, cancelled: bool) {
        if let Some(w) = self.pending_cancels.lock().expect("cancels poisoned").remove(&ticket) {
            let _ = w.send(cancelled);
        }
    }

    /// Mark a shard dead if `generation` is current; `false` means the
    /// report is stale (a newer incarnation is already installed).
    fn mark_dead(&self, shard: usize, generation: u64) -> bool {
        let mut links = self.links.lock().expect("links poisoned");
        let link = &mut links[shard];
        if link.generation != generation || !link.alive {
            return false;
        }
        link.alive = false;
        true
    }

    /// Install a fresh link for `shard`, returning the dead incarnation's
    /// unanswered work for requeueing.
    fn install_link(&self, shard: usize, link: ShardLink) -> Vec<(u64, FitRequest)> {
        let old = {
            let mut links = self.links.lock().expect("links poisoned");
            std::mem::replace(&mut links[shard], link)
        };
        old.inflight.lock().expect("inflight poisoned").drain().collect()
    }

    /// Drain a permanently dead shard's unanswered work.
    fn take_inflight(&self, shard: usize) -> Vec<(u64, FitRequest)> {
        let links = self.links.lock().expect("links poisoned");
        links[shard].local_depth.store(0, Ordering::SeqCst);
        links[shard].inflight.lock().expect("inflight poisoned").drain().collect()
    }

    fn requeue(&self, orphans: Vec<(u64, FitRequest)>) {
        for (ticket, req) in orphans {
            self.requeues.inc();
            self.dispatch(ticket, req);
        }
    }

    /// Ask every live shard for a `stats` refresh (fire-and-forget; the
    /// reader threads update the depth gauges as replies arrive).
    fn poll_stats(&self) {
        let links = self.links.lock().expect("links poisoned");
        for l in links.iter().filter(|l| l.alive) {
            let _ = l.tx.send(ShardCmd::Stats);
        }
    }

    /// Send every live shard one teardown frame (monitor-side — recovery
    /// is already off when this runs): `{"op":"shutdown"}` for owned
    /// local children, `{"op":"bye"}` for remote daemons that are not
    /// ours to drain (PROTOCOL.md §6).
    fn broadcast(&self, cmd: impl Fn() -> ShardCmd) {
        let links = self.links.lock().expect("links poisoned");
        for l in links.iter().filter(|l| l.alive) {
            let _ = l.tx.send(cmd());
        }
    }

    /// The wire-facing `queue_depth` (PROTOCOL.md §6): per shard, the
    /// last *reported* queued count clamped by the exact local count of
    /// unanswered forwards. The clamp keeps the ~4 Hz poll's staleness
    /// honest in both directions: a drained shard reads 0 immediately
    /// (local is exact), and executing-but-not-queued forwards never
    /// inflate the figure the way the raw placement signal
    /// ([`ShardLink::depth`], a max) deliberately does.
    fn queue_depth_total(&self) -> usize {
        let links = self.links.lock().expect("links poisoned");
        links
            .iter()
            .filter(|l| l.alive)
            .map(|l| {
                l.reported_depth
                    .load(Ordering::SeqCst)
                    .min(l.local_depth.load(Ordering::SeqCst))
            })
            .sum()
    }

    /// Alive shards whose link has heard nothing for longer than
    /// `timeout` despite the monitor's ongoing stats polling — the
    /// wedged-but-not-dead case EOF detection cannot see.
    fn stalled_shards(&self, timeout: Duration) -> Vec<usize> {
        let links = self.links.lock().expect("links poisoned");
        links
            .iter()
            .enumerate()
            .filter(|(_, l)| {
                l.alive
                    && l.last_heard.lock().expect("last_heard poisoned").elapsed() > timeout
            })
            .map(|(i, _)| i)
            .collect()
    }

    fn shards_alive(&self) -> usize {
        self.links.lock().expect("links poisoned").iter().filter(|l| l.alive).count()
    }

    /// Post-drain teardown: final per-shard stats sweep, shard shutdown
    /// frames, monitor join, report assembly. Runs after the accept loop
    /// has joined every connection — all tickets are answered by now.
    fn finalize(
        &self,
        monitor_tx: mpsc::Sender<MonitorMsg>,
        monitor: std::thread::JoinHandle<u64>,
    ) -> ServeReport {
        // Final stats sweep (cause-level shed counters live shard-side).
        let mut sweeps = Vec::new();
        {
            let links = self.links.lock().expect("links poisoned");
            for l in links.iter().filter(|l| l.alive) {
                let (tx, rx) = mpsc::channel();
                l.stats_waiters.lock().expect("waiters poisoned").push_back(tx);
                let _ = l.tx.send(ShardCmd::Stats);
                sweeps.push((rx, Arc::clone(&l.last_stats)));
            }
        }
        let mut partials = Vec::with_capacity(sweeps.len());
        for (rx, last) in sweeps {
            let stats = rx
                .recv_timeout(FINAL_STATS_WAIT)
                .unwrap_or_else(|_| *last.lock().expect("stats poisoned"));
            partials.push(stats);
        }
        // Hand teardown to the monitor: *it* must send the shard teardown
        // frames (`shutdown` for owned children, `bye` for remote peers)
        // after it stops recovering, or the resulting link EOFs would
        // look like crashes and resurrect the shards being drained.
        let _ = monitor_tx.send(MonitorMsg::Finalize);
        let restarts = monitor.join().unwrap_or(0);

        let acc = std::mem::take(&mut *self.acc.lock().expect("accumulator poisoned"));
        let mut report = acc.into_report(
            self.submitted.get(),
            &[],
            QueueStats::default(),
            self.started.elapsed().as_secs_f64(),
        );
        report.workers = self.shard_count * self.serve.workers;
        report.shard_restarts = restarts;
        for s in &partials {
            report.shed_full += s.shed_full;
            report.shed_deadline += s.shed_deadline;
            report.peak_queue_depth = report.peak_queue_depth.max(s.peak_queue_depth);
        }
        report
    }
}

impl FrontCore for ClusterCore {
    fn submit(&self, req: FitRequest, reply: &mpsc::Sender<FitResponse>) -> u64 {
        // Backpressure: block this submitter until the fleet has room
        // (every answered ticket frees one slot in `deliver`).
        {
            let mut n = self.admission.lock().expect("admission poisoned");
            while *n >= self.admission_cap {
                n = self.admission_free.wait(n).expect("admission poisoned");
            }
            *n += 1;
        }
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        self.submitted.inc();
        let client_id = req.id;
        let fingerprint = cache::fingerprint_of(&req);
        self.routes.lock().expect("routes poisoned").insert(
            ticket,
            ClusterRoute {
                client_id,
                reply: reply.clone(),
                shard: UNROUTED,
                tenant: req.tenant.clone(),
                fingerprint,
            },
        );
        let mut req = req;
        req.id = ticket;
        // The front is where a job's trace id is settled (PROTOCOL.md
        // §11): the client's own when supplied, else minted here. The
        // shard-bound frame carries it, so the shard's session joins the
        // same trace instead of minting a second id.
        if req.trace_id.is_empty() {
            req.trace_id = mint_trace_id();
        }
        self.ring.push(
            SpanEvent::new(&req.trace_id, "admit")
                .num("id", client_id as f64)
                .num("ticket", ticket as f64),
        );
        // Result cache (PROTOCOL.md §8): a hit replays the finished reply
        // through `deliver` — same id restoration, accounting and
        // admission-slot release as a shard-computed response — without
        // ever crossing a shard link.
        if let Some(fp) = fingerprint {
            let hit = self
                .cache
                .lock()
                .expect("result cache poisoned")
                .lookup(fp, &req);
            if let Some(resp) = hit {
                self.deliver(resp);
                return ticket;
            }
        }
        match self.fit_mode {
            FitMode::Request => self.dispatch(ticket, req),
            FitMode::MapReduce => self.dispatch_mapreduce(ticket, req),
        }
        ticket
    }

    fn cancel(&self, ticket: u64) -> bool {
        let shard = match self.routes.lock().expect("routes poisoned").get(&ticket) {
            Some(r) if r.shard != UNROUTED => r.shard,
            _ => return false, // answered already, or not yet dispatched
        };
        let (vtx, vrx) = mpsc::channel();
        self.pending_cancels.lock().expect("cancels poisoned").insert(ticket, vtx);
        let sent = {
            let links = self.links.lock().expect("links poisoned");
            links[shard].alive && links[shard].tx.send(ShardCmd::Cancel(ticket)).is_ok()
        };
        let verdict = if sent { vrx.recv_timeout(CANCEL_WAIT).unwrap_or(false) } else { false };
        self.pending_cancels.lock().expect("cancels poisoned").remove(&ticket);
        verdict
    }

    fn greeting_fields(&self, m: &mut BTreeMap<String, Json>) {
        m.insert(
            "workers".to_string(),
            Json::Num((self.shard_count * self.serve.workers) as f64),
        );
        m.insert("max_batch".to_string(), Json::Num(self.serve.max_batch as f64));
        m.insert("backends".to_string(), Json::Arr(advertised_backends()));
        m.insert("shards".to_string(), Json::Num(self.shard_count as f64));
    }

    fn stats_fields(&self, m: &mut BTreeMap<String, Json>) {
        m.insert("submitted".to_string(), Json::Num(self.submitted.get() as f64));
        m.insert("queue_depth".to_string(), Json::Num(self.queue_depth_total() as f64));
        m.insert("shards".to_string(), Json::Num(self.shard_count as f64));
        m.insert("shards_alive".to_string(), Json::Num(self.shards_alive() as f64));
        let (mut shed_full, mut shed_deadline, mut peak) = (0u64, 0u64, 0usize);
        let mut lanes = [0usize; crate::serve::Priority::LEVELS];
        {
            let links = self.links.lock().expect("links poisoned");
            for l in links.iter() {
                let s = *l.last_stats.lock().expect("stats poisoned");
                shed_full += s.shed_full;
                shed_deadline += s.shed_deadline;
                peak = peak.max(s.peak_queue_depth);
                for (total, lane) in lanes.iter_mut().zip(s.queue_lanes.iter()) {
                    *total += lane;
                }
            }
        }
        m.insert("shed_full".to_string(), Json::Num(shed_full as f64));
        m.insert("shed_deadline".to_string(), Json::Num(shed_deadline as f64));
        m.insert("peak_queue_depth".to_string(), Json::Num(peak as f64));
        m.insert("uptime_ms".to_string(), Json::Num(self.started.elapsed().as_millis() as f64));
        m.insert(
            "queue_lanes".to_string(),
            Json::Arr(lanes.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        m.insert(
            "tenants".to_string(),
            tenants_json(&self.tenants.lock().expect("tenant table poisoned")),
        );
    }

    fn drain_trace(&self) -> Json {
        self.ring.drain_json()
    }

    fn peek_trace(&self) -> Json {
        self.ring.peek_json()
    }

    /// Fleet-wide snapshot (PROTOCOL.md §11): the front's own registry
    /// tagged `shard="front"`, plus every live shard's registry scraped
    /// over its link and tagged `shard="<index>"`. A shard that misses
    /// its reply window is simply absent from this scrape — the next one
    /// catches it, and Prometheus tolerates a gap far better than a
    /// stalled endpoint.
    fn metrics(&self) -> Json {
        self.registry.gauge(names::SERVE_QUEUE_DEPTH).set(self.queue_depth_total() as i64);
        let mut merged = Json::Obj(BTreeMap::new());
        merge_snapshot_labeled(&mut merged, &self.registry.snapshot(), "shard", "front");
        let mut scrapes = Vec::new();
        {
            let links = self.links.lock().expect("links poisoned");
            for (i, l) in links.iter().enumerate().filter(|(_, l)| l.alive) {
                let (tx, rx) = mpsc::channel();
                l.metrics_waiters.lock().expect("waiters poisoned").push_back(tx);
                let _ = l.tx.send(ShardCmd::Metrics);
                scrapes.push((i, rx));
            }
        }
        for (i, rx) in scrapes {
            if let Ok(snap) = rx.recv_timeout(FINAL_STATS_WAIT) {
                merge_snapshot_labeled(&mut merged, &snap, "shard", &i.to_string());
            }
        }
        merged
    }

    fn cache_control(&self, clear: bool) -> Json {
        let mut c = self.cache.lock().expect("result cache poisoned");
        let cleared = clear.then(|| c.clear());
        cache::cache_json(c.len(), c.capacity(), cleared)
    }
}

/// Split one ready [`ClientConn`] into a shard link: a writer thread
/// draining the command channel and a reader thread pumping replies into
/// the core. Both report shard death to the monitor and exit.
fn spawn_link(
    shard: usize,
    generation: u64,
    conn: ClientConn,
    core: Arc<ClusterCore>,
    monitor_tx: mpsc::Sender<MonitorMsg>,
) -> ShardLink {
    let (tx, rx) = mpsc::channel::<ShardCmd>();
    let (sender, mut receiver) = conn.split();
    let local_depth = Arc::new(AtomicUsize::new(0));
    let reported_depth = Arc::new(AtomicUsize::new(0));
    let inflight: Arc<Mutex<HashMap<u64, FitRequest>>> = Arc::new(Mutex::new(HashMap::new()));
    let last_stats = Arc::new(Mutex::new(super::client::ShardStats::default()));
    let stats_waiters: Arc<Mutex<VecDeque<mpsc::Sender<super::client::ShardStats>>>> =
        Arc::new(Mutex::new(VecDeque::new()));
    let metrics_waiters: Arc<Mutex<VecDeque<mpsc::Sender<Json>>>> =
        Arc::new(Mutex::new(VecDeque::new()));
    let last_heard = Arc::new(Mutex::new(Instant::now()));

    {
        let monitor_tx = monitor_tx.clone();
        let core = Arc::clone(&core);
        std::thread::spawn(move || {
            for cmd in rx {
                let sent = match cmd {
                    ShardCmd::Submit(req) => sender.submit(&req).map(|_| ()),
                    ShardCmd::Cancel(ticket) => match sender.request_cancel(ticket) {
                        // The job's reply won the race and nothing was
                        // sent — no ack will ever come back, so resolve
                        // the waiter now instead of letting it time out
                        // (which would stall the client's whole
                        // connection for CANCEL_WAIT).
                        Ok(false) => {
                            core.finish_cancel(ticket, false);
                            Ok(())
                        }
                        Ok(true) => Ok(()),
                        Err(e) => Err(e),
                    },
                    ShardCmd::Stats => sender.request_stats(),
                    ShardCmd::Metrics => sender.request_metrics(),
                    ShardCmd::Shutdown => sender.request_shutdown(),
                    ShardCmd::Bye => sender.send_bye(),
                };
                if sent.is_err() {
                    let _ = monitor_tx.send(MonitorMsg::ShardDown { shard, generation });
                    return;
                }
            }
        });
    }
    {
        let local_depth = Arc::clone(&local_depth);
        let reported_depth = Arc::clone(&reported_depth);
        let inflight = Arc::clone(&inflight);
        let last_stats = Arc::clone(&last_stats);
        let stats_waiters = Arc::clone(&stats_waiters);
        let metrics_waiters = Arc::clone(&metrics_waiters);
        let last_heard = Arc::clone(&last_heard);
        std::thread::spawn(move || loop {
            let event = match receiver.next_event() {
                Ok(ev) => ev,
                Err(_) => {
                    let _ = monitor_tx.send(MonitorMsg::ShardDown { shard, generation });
                    return;
                }
            };
            *last_heard.lock().expect("last_heard poisoned") = Instant::now();
            match event {
                ClientEvent::Response(resp) => {
                    if inflight.lock().expect("inflight poisoned").remove(&resp.id).is_some() {
                        local_depth.fetch_sub(1, Ordering::SeqCst);
                    }
                    core.deliver(resp);
                }
                ClientEvent::Stats(s) => {
                    reported_depth.store(s.queue_depth, Ordering::SeqCst);
                    *last_stats.lock().expect("stats poisoned") = s;
                    if let Some(w) = stats_waiters.lock().expect("waiters poisoned").pop_front() {
                        let _ = w.send(s);
                    }
                }
                ClientEvent::Cancelled { id, cancelled } => {
                    if cancelled {
                        // The shard removed the job from its queue; that
                        // ack is a promise the job will never execute
                        // (PROTOCOL.md §6). Make it crash-proof: answer
                        // the ticket's single shed reply from here and
                        // drop it from the requeue set, so a shard death
                        // after the ack cannot re-run a job the client
                        // was told is cancelled. The shard's own shed
                        // reply then arrives routeless and is ignored.
                        if inflight.lock().expect("inflight poisoned").remove(&id).is_some() {
                            local_depth.fetch_sub(1, Ordering::SeqCst);
                        }
                        core.deliver(FitResponse::shed(id, "cancelled by client", 0.0));
                    }
                    core.finish_cancel(id, cancelled);
                }
                ClientEvent::Eof => {
                    let _ = monitor_tx.send(MonitorMsg::ShardDown { shard, generation });
                    return;
                }
                ClientEvent::Notice(j)
                    if matches!(j.get("op").and_then(|v| v.as_str()), Ok("metrics")) =>
                {
                    // A fleet-scrape reply (PROTOCOL.md §11); FIFO pairing
                    // with the requester, like the stats waiters.
                    if let Some(w) =
                        metrics_waiters.lock().expect("waiters poisoned").pop_front()
                    {
                        let _ = w.send(j);
                    }
                }
                _ => {} // pongs, notices, protocol errors: nothing owed
            }
        });
    }
    ShardLink {
        generation,
        alive: true,
        tx,
        local_depth,
        reported_depth,
        inflight,
        last_stats,
        stats_waiters,
        metrics_waiters,
        last_heard,
    }
}

/// Monitor main loop: owns the [`ShardHost`] (supervisor or remote
/// fleet); recovers crashed shards / lost links, executes chaos kills,
/// polls health/stats, and finally tears everything down. Returns the
/// total restart/reconnect count.
fn monitor_main(
    mut host: Box<dyn ShardHost>,
    core: Arc<ClusterCore>,
    rx: mpsc::Receiver<MonitorMsg>,
    monitor_tx: mpsc::Sender<MonitorMsg>,
) -> u64 {
    let mut last_poll = Instant::now();
    loop {
        match rx.recv_timeout(POLL) {
            Ok(MonitorMsg::ShardDown { shard, generation }) => {
                recover(host.as_mut(), &core, &monitor_tx, shard, generation);
            }
            Ok(MonitorMsg::KillShard(shard)) => {
                // The kill is observed through the normal crash path: the
                // link's reader sees EOF and files a ShardDown.
                host.kill(shard);
            }
            Ok(MonitorMsg::Finalize) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Recovery is off from here on. Drain shards we own with
                // `shutdown` (their link EOFs must read as teardown, not
                // as crashes); say `bye` to remote daemons we do not —
                // they keep serving whoever else they serve.
                if host.owns_shards() {
                    core.broadcast(|| ShardCmd::Shutdown);
                } else {
                    core.broadcast(|| ShardCmd::Bye);
                }
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                for (shard, generation) in host.reap_exited() {
                    recover(host.as_mut(), &core, &monitor_tx, shard, generation);
                }
                core.poll_stats();
                // Hung-shard watchdog: a shard that is up (as a process
                // or a connected peer) but has answered nothing — not
                // even these stats polls — for the health-timeout window
                // is killed/force-closed so its EOF drives the normal
                // recovery path. Repeat kills of an already-dead link are
                // harmless; the generation guard deduplicates the
                // recoveries. Staleness is only trusted while polling
                // has been continuous — right after a long blocking
                // recovery, shards get one tick to answer the resumed
                // poll before being judged.
                if last_poll.elapsed() <= 2 * POLL {
                    for shard in core.stalled_shards(core.health_timeout) {
                        host.kill(shard);
                    }
                }
                last_poll = Instant::now();
            }
        }
    }
    let restarts = host.restarts_total();
    host.shutdown(SHUTDOWN_GRACE);
    restarts
}

/// One shard-crash (or link-loss) recovery: respawn/reconnect within
/// budget and requeue the dead incarnation's unanswered tickets; past
/// budget, requeue to survivors and route around the abandoned shard
/// from now on.
fn recover(
    host: &mut dyn ShardHost,
    core: &Arc<ClusterCore>,
    monitor_tx: &mpsc::Sender<MonitorMsg>,
    shard: usize,
    generation: u64,
) {
    if !core.mark_dead(shard, generation) {
        return; // stale report: a newer incarnation is already up
    }
    crate::obs::log::warn("cluster", &format!("shard {shard} down (generation {generation})"));
    core.router.lock().expect("router poisoned").forget_shard(shard);
    let orphans = match host.respawn(shard) {
        Ok(conn) => {
            core.restarts.inc();
            crate::obs::log::info(
                "cluster",
                &format!("shard {shard} recovered (generation {})", host.generation(shard)),
            );
            let link = spawn_link(
                shard,
                host.generation(shard),
                conn,
                Arc::clone(core),
                monitor_tx.clone(),
            );
            core.install_link(shard, link)
        }
        Err(e) => {
            crate::obs::log::error(
                "cluster",
                &format!("shard {shard} abandoned (respawn budget spent): {e}"),
            );
            host.abandon(shard);
            core.take_inflight(shard)
        }
    };
    core.requeue(orphans);
}

/// A started-but-not-yet-serving cluster (the `Daemon` analogue one
/// layer up): the shard fleet is up and linked, the front listener is
/// bound; [`Cluster::run`] blocks until shutdown and returns the merged
/// report.
pub struct Cluster {
    daemon: Daemon,
    core: Arc<ClusterCore>,
    monitor: std::thread::JoinHandle<u64>,
    monitor_tx: mpsc::Sender<MonitorMsg>,
}

/// Remote control for a running cluster: graceful shutdown plus the
/// shard-kill chaos hook the crash-recovery tests drive.
#[derive(Clone)]
pub struct ClusterHandle {
    daemon: DaemonHandle,
    monitor_tx: mpsc::Sender<MonitorMsg>,
}

impl ClusterHandle {
    /// Begin a graceful drain of the whole cluster (front + shards).
    pub fn shutdown(&self) {
        self.daemon.shutdown();
    }

    /// Take one shard down (fault injection): SIGKILL for a supervised
    /// local child, a forced socket shutdown for a remote link. Either
    /// way the shard is restarted/re-dialed and its in-flight jobs are
    /// requeued — external clients still receive every reply exactly
    /// once.
    pub fn kill_shard(&self, shard: usize) {
        let _ = self.monitor_tx.send(MonitorMsg::KillShard(shard));
    }
}

impl Cluster {
    /// Bind the front listener, bring up the shard fleet — spawn and
    /// link `cfg.shards` local daemons, or (when `cfg.remote_shards` is
    /// non-empty) attach to the already-running daemons it names,
    /// skipping the supervisor entirely — and start the monitor.
    /// Everything is torn down if any step fails — no half-up cluster.
    pub fn start(listen: &str, net: NetConfig, cfg: ClusterConfig) -> Result<Cluster> {
        cfg.validate()?;
        // Bind first: an unusable front address should fail before any
        // child process (or remote link) exists.
        let daemon = Daemon::bind(listen, net, cfg.serve.clone())?;
        let (host, conns) = if cfg.remote_shards.is_empty() {
            let sup_cfg = SupervisorConfig {
                program: cfg.program.clone(),
                socket_dir: cfg.socket_dir.clone(),
                serve: cfg.serve.clone(),
                max_restarts: cfg.max_restarts,
                reconnect: cfg.reconnect.clone(),
            };
            let (supervisor, conns) = Supervisor::spawn(sup_cfg, cfg.shards)?;
            (Box::new(supervisor) as Box<dyn ShardHost>, conns)
        } else {
            let (fleet, conns) = RemoteFleet::connect(
                &cfg.remote_shards,
                cfg.reconnect.clone(),
                cfg.max_restarts,
            )?;
            (Box::new(fleet) as Box<dyn ShardHost>, conns)
        };
        let (monitor_tx, monitor_rx) = mpsc::channel();
        let core = Arc::new(ClusterCore::new(&cfg));
        {
            let mut links = core.links.lock().expect("links poisoned");
            for (i, conn) in conns.into_iter().enumerate() {
                links.push(spawn_link(i, 0, conn, Arc::clone(&core), monitor_tx.clone()));
            }
        }
        let monitor = {
            let core = Arc::clone(&core);
            let monitor_tx = monitor_tx.clone();
            std::thread::spawn(move || monitor_main(host, core, monitor_rx, monitor_tx))
        };
        Ok(Cluster { daemon, core, monitor, monitor_tx })
    }

    /// The front door's bound address, in `Daemon::bind` notation.
    pub fn local_addr(&self) -> String {
        self.daemon.local_addr()
    }

    /// The bound `GET /metrics` scrape address, when the front's
    /// `NetConfig` asked for one — a scrape here answers the merged
    /// fleet snapshot, labeled by shard (PROTOCOL.md §11).
    pub fn metrics_addr(&self) -> Option<String> {
        self.daemon.metrics_addr()
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { daemon: self.daemon.handle(), monitor_tx: self.monitor_tx.clone() }
    }

    /// Serve until a `{"op":"shutdown"}` frame or a
    /// [`ClusterHandle::shutdown`]: drain every front connection,
    /// collect final shard stats, drain and reap the shard daemons, and
    /// return the merged cluster [`ServeReport`] (front counters +
    /// fan-in accounting + shard shed counters + restart count).
    pub fn run(self) -> Result<ServeReport> {
        let Cluster { daemon, core, monitor, monitor_tx } = self;
        let fin = Arc::clone(&core);
        daemon.run_with(core, move || Ok(fin.finalize(monitor_tx, monitor)))
    }
}
